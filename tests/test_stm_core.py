"""Unit tests: clock, locks, bloom, VLT, modes, heuristics, EBR."""
import threading

import pytest

from repro.configs.paper_stm import MultiverseParams
from repro.core import heuristics as heur
from repro.core import modes as M
from repro.core.bloom import BloomTable
from repro.core.clock import AtomicInt, GlobalClock
from repro.core.ebr import EBR, TxRetireBuffer
from repro.core.locks import LockState, LockTable, UNLOCKED
from repro.core.vlt import DELETED_TS, VLT, VersionList, VListNode


def test_atomic_int_cas_and_increment():
    a = AtomicInt(5)
    assert a.cas(5, 7) and a.load() == 7
    assert not a.cas(5, 9)
    assert a.increment() == 8


def test_clock_concurrent_increments():
    c = GlobalClock(0)
    n, t = 200, 8

    def bump():
        for _ in range(n):
            c.increment()

    ths = [threading.Thread(target=bump) for _ in range(t)]
    [x.start() for x in ths]
    [x.join() for x in ths]
    assert c.load() == n * t


def test_lock_table_validate_semantics():
    lt = LockTable(8)
    idx = lt.index(1234)
    st = lt.read(idx)
    assert lt.validate(st, r_clock=1, tid=0)
    assert lt.try_lock(idx, st, tid=3)
    held = lt.read(idx)
    assert held.locked and held.tid == 3
    # another thread: conflict
    assert not lt.validate(held, r_clock=10, tid=0)
    # owner revalidates fine
    assert lt.validate(held, r_clock=10, tid=3)
    lt.unlock(idx, version=9)
    st = lt.read(idx)
    assert not st.locked and st.version == 9
    assert not lt.validate(st, r_clock=9, tid=0)   # version >= rclock
    assert lt.validate(st, r_clock=10, tid=0)


def test_lock_and_flag_blocks_validate():
    lt = LockTable(8)
    idx = lt.index(7)
    st = lt.lock_and_flag(idx, tid=1)
    assert lt.read(idx).flag
    assert not lt.validate(lt.read(idx), r_clock=100, tid=0)
    lt.unlock(idx)
    assert not lt.read(idx).flag


def test_same_index_for_all_tables():
    lt = LockTable(10)
    for addr in (0, 1, 99, 12345, 1 << 40):
        assert 0 <= lt.index(addr) < (1 << 10)


def test_bloom_membership_and_reset():
    b = BloomTable(4, 64)
    assert not b.contains(2, 42)
    assert b.try_add(2, 42)
    assert b.contains(2, 42)
    assert not b.try_add(2, 42)          # already present
    b.reset(2)
    assert not b.contains(2, 42)


def test_vlt_insert_get_and_newest_ts():
    v = VLT(4)
    vl = VersionList(VListNode(None, 5, "x", False))
    v.insert(1, 100, vl)
    assert v.get(1, 100) is vl
    assert v.get(1, 101) is None
    vl.head = VListNode(vl.head, 9, "y", False)
    assert v.bucket_newest_ts(1) == 9
    # TBD and deleted versions are ignored for the heuristic
    vl.head = VListNode(vl.head, 50, "z", True)
    assert v.bucket_newest_ts(1) == 9
    head = v.take_bucket(1)
    assert head is not None and v.get(1, 100) is None


def test_mode_cycle():
    assert M.get_mode(0) == M.MODE_Q
    assert M.get_mode(1) == M.MODE_QTOU
    assert M.get_mode(2) == M.MODE_U
    assert M.get_mode(3) == M.MODE_UTOQ
    assert M.get_mode(4) == M.MODE_Q
    assert M.writers_must_version(M.MODE_U)
    assert not M.writers_must_version(M.MODE_Q)
    assert M.readers_assume_versioned(M.MODE_U)
    assert M.unversioning_enabled(M.MODE_Q)


def test_heuristics_k1_k2_k3():
    p = MultiverseParams(k1=5, k2=2, k3=4)
    assert not heur.should_go_versioned(p, 4)
    assert heur.should_go_versioned(p, 5)
    # K3: versioned txns always CAS after k3 attempts
    assert heur.should_attempt_mode_cas(p, versioned=True, attempts=4,
                                        read_cnt=0, min_mode_u_reads=None)
    # K2 requires min-mode-U-read-count evidence for unversioned txns
    assert not heur.should_attempt_mode_cas(p, versioned=False, attempts=3,
                                            read_cnt=10,
                                            min_mode_u_reads=None)
    assert heur.should_attempt_mode_cas(p, versioned=False, attempts=3,
                                        read_cnt=10, min_mode_u_reads=8)
    assert not heur.should_attempt_mode_cas(p, versioned=False, attempts=3,
                                            read_cnt=5, min_mode_u_reads=8)


def test_sticky_clearing_after_s_small_txns():
    p = MultiverseParams(s=3)
    ann = heur.ThreadAnnouncement()
    ann.sticky_mode_u = True
    # first commit after CAS sets the small-txn threshold (size/S)
    assert not heur.sticky_cleared(p, ann, 300)   # threshold = 100
    cleared = False
    for _ in range(3):
        cleared = heur.sticky_cleared(p, ann, 50)
    assert cleared


def test_unversion_threshold_l_p():
    p = MultiverseParams(l=4, p=0.5)
    u = heur.UnversionThreshold(p)
    for d in ([10], [20], [30], [40]):
        assert u.threshold() is None or True
        u.observe_round(d)
    # sorted desc [40,30,20,10], prefix half = [40,30] -> 35
    assert u.threshold() == pytest.approx(35.0)


def test_ebr_revocable_retires():
    ebr = EBR(2)
    buf = TxRetireBuffer(ebr)
    node = VListNode(None, 1, "a", False)
    buf.retire_on_commit(node)
    buf.abort()                      # revoked
    assert ebr.limbo_size == 0 and not node.freed
    buf.retire_on_commit(node)
    buf.commit()
    assert ebr.limbo_size == 1
    for _ in range(4):
        ebr.advance_and_reclaim()
    assert node.freed and ebr.freed_count == 1


def test_ebr_pinned_reader_blocks_reclaim():
    ebr = EBR(2)
    ebr.pin(0)
    node = VListNode(None, 1, "a", False)
    ebr.retire(node)
    for _ in range(4):
        ebr.advance_and_reclaim()
    assert not node.freed             # reader still pinned
    ebr.unpin(0)
    for _ in range(4):
        ebr.advance_and_reclaim()
    assert node.freed
