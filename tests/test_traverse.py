"""Frontier-at-a-time traversal + packed-VLT version gather.

Four layers of assurance:

  * unit: ``traverse_bulk`` preserves DFS emission order, honors
    ``limit``, threads per-item state, and never touches the Python
    stack for depth (a degenerate tree deeper than the recursion limit
    traverses fine);
  * parity (the batch-vs-scalar satellite): ``extbst.range_query`` and
    chained ``HashMap.size_query`` match hand-rolled scalar traversals
    on ALL six backends;
  * kernel: the ``version_select`` Pallas kernel agrees with the numpy
    twin (``core.vlt.np_version_select``) element-for-element, ragged
    sizes included;
  * mirror: a versioned bulk read resolves a recently-written word's
    snapshot past through ``PackedVLT.select`` (one gather, no scalar
    version-list walk), and rows the mirror cannot represent (colliding
    buckets, non-int payloads) fail closed to the scalar fallback.
"""
import random
import sys

import numpy as np
import pytest

from repro.api import run
from repro.core.engine.traverse import chase_bulk, traverse_bulk
from repro.core.vlt import (
    EMPTY_TS,
    PackedVLT,
    VListNode,
    np_version_select,
)
from repro.structs import ExternalBST, HashMap

from tests._backends import ALL_BACKENDS, make_test_tm


# ---------------------------------------------------------------------------
# unit: ordering, limit, state, depth
# ---------------------------------------------------------------------------


def test_traverse_bulk_preserves_dfs_order_and_limit():
    """A hand-built binary tree on the raw heap: emission must be exactly
    the in-order walk, and ``limit`` must truncate it mid-traversal."""
    tm = make_test_tm("tl2", n_threads=1)
    tm.alloc(1)                              # burn address 0 (NULL)
    # node layout: [0]=value, [1]=left, [2]=right (0 = null)
    def node(v, l=0, r=0):
        base = tm.alloc(3, 0)
        tm.run(lambda tx: (tx.write(base, v), tx.write(base + 1, l),
                           tx.write(base + 2, r)))
        return base
    #        4
    #      2   6
    #     1 3 5 7
    n1, n3, n5, n7 = node(1), node(3), node(5), node(7)
    n2, n6 = node(2, n1, n3), node(6, n5, n7)
    n4 = node(4, n2, n6)

    def expand(state, w, emit, push):
        if int(w[1]):
            push(w[1], 3, state + 1)
        emit((int(w[0]), state))
        if int(w[2]):
            push(w[2], 3, state + 1)

    out = run(tm, lambda tx: traverse_bulk(tx, [(n4, 3, 0)], expand))
    assert [v for v, _ in out] == [1, 2, 3, 4, 5, 6, 7]
    assert [d for _, d in out] == [2, 1, 2, 0, 2, 1, 2]   # depth state
    # NOTE the emit-between-pushes above is in-order traversal; limit
    # stops at the resolved prefix, never emitting out of order
    out = run(tm, lambda tx: traverse_bulk(tx, [(n4, 3, 0)], expand,
                                           limit=4))
    assert [v for v, _ in out] == [1, 2, 3, 4]
    tm.stop()


def test_chase_bulk_counts_rounds():
    tm = make_test_tm("tl2", n_threads=1)
    tm.alloc(1)                              # burn address 0 (NULL)
    # three chains of length 1, 3, 5 — cells: [0]=next
    def chain(n):
        addrs = [tm.alloc(1, 0) for _ in range(n)]
        for a, b in zip(addrs, addrs[1:]):
            tm.run(lambda tx, a=a, b=b: tx.write(a, b))
        return addrs[0]
    heads = [chain(1), chain(3), chain(5)]
    seen = []

    def advance(cur, vals):
        seen.append(cur.size)
        nxt = np.asarray(vals, np.int64)
        return nxt[nxt != 0]

    rounds = run(tm, lambda tx: chase_bulk(tx, heads, advance))
    assert rounds == 5                       # longest chain
    assert seen == [3, 2, 2, 1, 1]           # lockstep attrition
    tm.stop()


def test_extbst_range_query_survives_depth_past_recursion_limit():
    """Sorted inserts build a degenerate (linked-list) BST; the iterative
    frontier walk must traverse deeper than the Python recursion limit
    allows (the old recursive DFS could not)."""
    tm = make_test_tm("tl2", n_threads=1)
    s = ExternalBST(tm)
    n = 300
    for k in range(n):
        run(tm, lambda tx, k=k: s.insert(tx, k, -k), tid=0)

    def stack_depth():
        f, d = sys._getframe(), 0
        while f:
            d += 1
            f = f.f_back
        return d

    old = sys.getrecursionlimit()
    # leave ~150 frames of headroom — far less than the tree's ~300
    # levels, so a recursive walk would blow the stack here
    sys.setrecursionlimit(stack_depth() + 150)
    try:
        out = run(tm, lambda tx: s.range_query(tx, 0, n), tid=0)
    finally:
        sys.setrecursionlimit(old)
    assert [int(k) for k, _ in out] == list(range(n))
    tm.stop()


def test_traversal_readset_dedup_across_rounds():
    """Repeated frontier visits must not inflate the read set: a second
    walk of the same chain re-proves the same (idx, version) pairs and
    appends NOTHING, while a plain read_bulk outside the traversal
    keeps the historical append-always behavior (flag restored)."""
    tm = make_test_tm("tl2", n_threads=1)
    tm.alloc(1)                              # burn address 0 (NULL)
    addrs = [tm.alloc(1, 0) for _ in range(5)]
    for a, b in zip(addrs, addrs[1:]):
        run(tm, lambda tx, a=a, b=b: tx.write(a, b))
    head = addrs[0]

    def advance(cur, vals):
        nxt = np.asarray(vals, np.int64)
        return nxt[nxt != 0]

    def body(tx):
        d = tx._ctx
        chase_bulk(tx, [head], advance)
        n1 = len(d.read_set)
        assert n1 > 0
        chase_bulk(tx, [head], advance)      # SAME chain again
        assert len(d.read_set) == n1         # deduped across rounds
        # traverse_bulk dedups too (same walk, span-1 items)
        out = traverse_bulk(
            tx, [(head, 1)],
            lambda s, w, emit, push: (emit(int(w[0])),
                                      push(int(w[0]), 1)
                                      if int(w[0]) else None))
        assert len(out) == 5
        assert len(d.read_set) == n1
        assert not d.dedup_read_set          # flag restored on exit
        tx.read_bulk([head])                 # plain batch: appends again
        assert len(d.read_set) == n1 + 1
    run(tm, body)
    tm.stop()


# ---------------------------------------------------------------------------
# parity: batch traversal == scalar traversal, all six backends
# ---------------------------------------------------------------------------


def _scalar_bst_range(s, tx, lo, count):
    """The pre-traversal-layer recursive DFS, as the parity oracle."""
    out = []
    root = tx.read(s.root_ptr)
    if root == 0:
        return out

    def dfs(node):
        if tx.read(node):
            k = tx.read(node + 1)
            if k >= lo:
                out.append((int(k), int(tx.read(node + 4))))
                if len(out) >= count:
                    return True
            return False
        if lo < tx.read(node + 1):
            if dfs(tx.read(node + 2)):
                return True
        return dfs(tx.read(node + 3))

    dfs(root)
    return out


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_extbst_range_query_batch_matches_scalar(backend):
    tm = make_test_tm(backend, n_threads=1)
    s = ExternalBST(tm)
    keys = random.Random(5).sample(range(5000), 140)
    for k in keys:
        run(tm, lambda tx, k=k: s.insert(tx, k, k * 2), tid=0)
    for lo, count in ((0, 1000), (2500, 40), (4999, 5), (6000, 10)):
        batch = run(tm, lambda tx: s.range_query(tx, lo, count), tid=0)
        scalar = run(tm, lambda tx: _scalar_bst_range(s, tx, lo, count),
                     tid=0)
        assert [(int(k), int(v)) for k, v in batch] == scalar
    tm.stop()


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_hashmap_size_query_batch_matches_scalar(backend):
    """16 buckets x 120 keys -> every bucket chains several nodes deep,
    so the lockstep chain chase is genuinely exercised per backend."""
    tm = make_test_tm(backend, n_threads=1)
    h = HashMap(tm, n_buckets=16)
    keys = random.Random(9).sample(range(10000), 120)
    for k in keys:
        run(tm, lambda tx, k=k: h.insert(tx, k, k), tid=0)

    def scalar_size(tx):
        total = 0
        for b in range(h.n_buckets):
            node = int(tx.read(h.table + b))
            while node:
                total += 1
                node = int(tx.read(node + 2))
        return total

    assert run(tm, h.size_query, tid=0) == \
        run(tm, scalar_size, tid=0) == len(keys)
    # after deletions the chains shorten mid-list; parity must hold
    for k in keys[::3]:
        run(tm, lambda tx, k=k: h.delete(tx, k), tid=0)
    assert run(tm, h.size_query, tid=0) == \
        run(tm, scalar_size, tid=0) == len(keys) - len(keys[::3])
    tm.stop()


# ---------------------------------------------------------------------------
# kernel twin agreement (version_select)
# ---------------------------------------------------------------------------


def test_version_select_kernel_matches_numpy_twin():
    import jax.numpy as jnp

    from repro.kernels import version_select as VS

    rng = np.random.default_rng(3)
    for n in (1, 7, 130, 512):
        ts = rng.integers(0, 1000, size=(n, 4)).astype(np.int64)
        ts[rng.random((n, 4)) < 0.3] = EMPTY_TS
        data = rng.integers(-5000, 5000, size=(n, 4)).astype(np.int64)
        for clock in (1, 500, 999):
            want_v, want_ok = np_version_select(ts, data, clock)
            rel = np.clip(ts - clock, -(1 << 31) + 1, (1 << 31) - 1)
            tile = min(256, 1 << (n - 1).bit_length()) if n > 1 else 1
            pad = (-n) % tile
            relj = jnp.asarray(rel, jnp.int32)
            dj = jnp.asarray(data)
            if pad:
                relj = jnp.pad(relj, ((0, pad), (0, 0)),
                               constant_values=VS.PAD_TS)
                dj = jnp.pad(dj, ((0, pad), (0, 0)))
            got_v, got_ok = VS.version_select_flat(relj, dj, 0, tile=tile,
                                                  interpret=True)
            got_v = np.asarray(got_v)[:n]
            got_ok = np.asarray(got_ok)[:n] != 0
            np.testing.assert_array_equal(want_ok, got_ok)
            np.testing.assert_array_equal(want_v[want_ok], got_v[got_ok])


def test_ops_version_select_pads_ragged_batches():
    from repro.kernels import ops

    rng = np.random.default_rng(11)
    for n in (1, 7, 63, 300):
        ts = rng.integers(0, 100, size=(n, 4)).astype(np.int64)
        data = rng.integers(0, 100, size=(n, 4)).astype(np.int64)
        vals, ok = ops.version_select(ts, data, 50)
        want_v, want_ok = np_version_select(ts, data, 50)
        np.testing.assert_array_equal(ok, want_ok)
        np.testing.assert_array_equal(vals[ok], want_v[want_ok])


def test_ops_version_select_exact_beyond_int32():
    """Payloads past int32 must come back exact (the wrapper must not
    let the x64-disabled jax path truncate them silently)."""
    from repro.kernels import ops

    big = (1 << 40) + 123
    ts = np.array([[5, 3], [9, 1]], np.int64)
    data = np.array([[big, 7], [-big, 8]], np.int64)
    vals, ok = ops.version_select(ts, data, 6)
    assert ok.tolist() == [True, True]
    assert vals.tolist() == [big, 8]      # row1: ts=9 rejected -> 8


# ---------------------------------------------------------------------------
# packed VLT mirror
# ---------------------------------------------------------------------------


def test_packed_vlt_select_fails_closed():
    """Way overflow, non-int payloads and torn rows must all fail select
    (-> scalar fallback), never return a wrong value; a single bucket
    collision is now SERVED by the second way (counted in way_hits)."""
    m = PackedVLT(8, depth=2)
    m.seed(3, 100, VListNode(None, 5, 42, False))
    vals, ok = m.select(np.array([3]), np.array([100]), 10)
    assert ok.tolist() == [True] and int(vals[0]) == 42
    # deeper than the mirror: version history beyond `depth` drops off
    m.publish(3, 100, 7, 43)
    m.publish(3, 100, 9, 44)
    vals, ok = m.select(np.array([3]), np.array([100]), 6)   # needs ts=5
    assert ok.tolist() == [False]
    vals, ok = m.select(np.array([3]), np.array([100]), 8)   # ts=7 -> 43
    assert ok.tolist() == [True] and int(vals[0]) == 43
    # a second address colliding into the bucket claims way 2: BOTH stay
    # vectorizable (the 2-way satellite), and the stat counts the hit
    m.seed(3, 200, VListNode(None, 6, 1, False))
    vals, ok = m.select(np.array([3, 3]), np.array([100, 200]), 100)
    assert ok.tolist() == [True, True]
    assert vals.tolist() == [44, 1]
    assert m.way_hits[1] == 1
    # publishes keep routing to the right way
    m.publish(3, 200, 12, 2)
    vals, ok = m.select(np.array([3]), np.array([200]), 100)
    assert ok.tolist() == [True] and int(vals[0]) == 2
    assert m.way_hits[1] == 2
    # a THIRD collider overflows both ways: unmirrored -> fail closed
    m.seed(3, 300, VListNode(None, 6, 9, False))
    _, ok = m.select(np.array([3]), np.array([300]), 100)
    assert ok.tolist() == [False]
    for addr, want in ((100, 44), (200, 2)):     # existing ways untouched
        vals, ok = m.select(np.array([3]), np.array([addr]), 100)
        assert ok.tolist() == [True] and int(vals[0]) == want
    # non-int payload poisons its way at publish time
    m.seed(4, 300, VListNode(None, 2, 7, False))
    m.publish(4, 300, 6, "not-an-int")
    _, ok = m.select(np.array([4]), np.array([300]), 100)
    assert ok.tolist() == [False]
    # torn row (odd seqlock) fails stability
    m.seed(5, 400, VListNode(None, 2, 9, False))
    m._seq[5] += 1
    _, ok = m.select(np.array([5]), np.array([400]), 100)
    assert ok.tolist() == [False]


def test_versioned_bulk_read_resolves_past_via_mirror():
    """The deterministic snapshot-past scenario of test_read_bulk, now
    asserting the RECENTLY-WRITTEN word resolves through the packed-VLT
    gather (one vectorized select) rather than the scalar version-list
    walk."""
    tm = make_test_tm("multiverse", n_threads=2, start_bg=False)
    base = tm.alloc(300, 7)
    target = base + 5
    run(tm, lambda t: t.write(base + 299, 7), tid=0)   # warm the clock
    tx = tm.begin(1)
    tx._ctx.versioned = True                 # seed the version list
    assert tx.read(target) == 7
    tm.commit(tx)
    tm.clock.increment()
    tx = tm.begin(1)
    tx._ctx.versioned = True                 # snapshot BEFORE the write
    run(tm, lambda t: t.write(target, 99), tid=0)
    assert tm.peek(target) == 99
    idx_t = tm.locks.index(target)
    addrs = [a for a in range(base, base + 300)
             if a == target or tm.locks.index(a) != idx_t]
    hits0 = tm.raw.policy.stats_version_gather_hits
    vals = tx.read_bulk(addrs)
    tm.commit(tx)
    assert int(vals[addrs.index(target)]) == 7        # the snapshot past
    assert tm.raw.policy.stats_version_gather_hits == hits0 + 1
    assert tm.raw.stats()["version_gather_hits"] >= 1
    tm.stop()


def test_mirror_lock_gate_defers_in_flight_commits_to_scalar():
    """While a writer HOLDS the address lock (its commit could still
    publish below a reader's snapshot), the mirror must refuse to serve
    the address — the scalar traverse owns that window.  The bulk read
    must still return the committed snapshot value, just not via the
    mirror (hits counter unchanged)."""
    from repro.api import AbortTx

    tm = make_test_tm("multiverse", n_threads=2, start_bg=False)
    base = tm.alloc(64, 7)
    target = base + 3
    run(tm, lambda t: t.write(base + 63, 7), tid=0)    # warm the clock
    tx = tm.begin(1)
    tx._ctx.versioned = True                 # seed the version list
    assert tx.read(target) == 7
    tm.commit(tx)
    # writer tid 0: encounter-locks target with an uncommitted TBD write
    wtx = None
    for _ in range(3):                       # deferred clock may abort once
        wtx = tm.begin(0)
        try:
            wtx.write(target, 99)
            break
        except AbortTx:
            wtx = None
    assert wtx is not None
    # versioned reader: its snapshot is at/below the writer's, so the
    # pending TBD is correctly skippable and the read must return 7 —
    # through the SCALAR traverse, because the lock gate excludes the
    # locked address from the mirror
    rtx = tm.begin(1)
    rtx._ctx.versioned = True
    hits0 = tm.raw.policy.stats_version_gather_hits
    vals = rtx.read_bulk([target, base + 10])
    tm.commit(rtx)
    assert int(vals[0]) == 7 and int(vals[1]) == 7
    assert tm.raw.policy.stats_version_gather_hits == hits0
    tm.abort(wtx)
    tm.stop()
