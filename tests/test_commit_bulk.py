"""The batched commit pipeline (PR 5).

Five layers of assurance:

  * kernel: the ``scatter_write`` Pallas kernel agrees with its numpy
    twin (``np_write_back``) element-for-element, ragged sizes and
    beyond-int32 payloads included;
  * parity: a write set large enough to engage every bulk step
    (``try_lock_bulk`` sweep, heap scatter, ``unlock_bulk``) commits to
    exactly the state the scalar loop produces, on ALL six backends —
    including read-own-writes mid-transaction;
  * all-or-nothing: a bulk lock acquire that hits a conflict acquires
    NOTHING (no partial-hold window, no heap mutation), on both the
    commit-time (TL2) and encounter-time (DCTL) paths;
  * rollback: an encounter-time bulk write that aborts restores the
    undo log exactly and leaves no locks held;
  * normalization (the release-locks fix): two addresses colliding into
    one lock word release it exactly ONCE on commit and on rollback —
    a second per-address unlock could stomp a lock another thread had
    since claimed.
"""
import numpy as np
import pytest

from repro.api import AbortTx, make_tm, run
from repro.configs.paper_stm import MultiverseParams
from repro.core.engine import commit as C
from repro.core.engine.validation import BULK_MIN

from tests._backends import ALL_BACKENDS, WORD_BACKENDS, make_test_tm

N = BULK_MIN + 44          # comfortably past the bulk threshold


def _word_tm(backend, n_threads=2, lock_bits=10):
    return make_tm(backend, n_threads,
                   params=MultiverseParams(k1=50, k2=200, k3=200,
                                           lock_table_bits=lock_bits),
                   array_heap=True)


# ---------------------------------------------------------------------------
# kernel twin agreement (scatter_write)
# ---------------------------------------------------------------------------


def test_scatter_kernel_matches_numpy_twin():
    from repro.kernels import scatter_write as SW

    rng = np.random.default_rng(7)
    for h, n in ((64, 16), (512, 512), (1000, 128)):
        heap = rng.integers(-100, 100, size=h).astype(np.int32)
        addrs = rng.choice(h, size=n, replace=False).astype(np.int32)
        vals = rng.integers(-100, 100, size=n).astype(np.int32)
        want = SW.np_write_back(heap, addrs, vals)
        tile = min(512, 1 << (n - 1).bit_length()) if n > 1 else 1
        pad = (-n) % tile
        a, v = addrs, vals
        if pad:
            a = np.pad(addrs, (0, pad), constant_values=h)  # dropped
            v = np.pad(vals, (0, pad))
        got = np.asarray(SW.scatter_write_flat(heap, a, v, tile=tile,
                                               interpret=True))
        np.testing.assert_array_equal(got, want)


def test_ops_write_back_pads_ragged_batches():
    from repro.kernels import ops
    from repro.kernels.scatter_write import np_write_back

    rng = np.random.default_rng(13)
    heap = rng.integers(0, 100, size=300).astype(np.int64)
    for n in (1, 7, 63, 300):
        addrs = rng.choice(300, size=n, replace=False)
        vals = rng.integers(0, 100, size=n).astype(np.int64)
        got = ops.write_back(heap, addrs, vals)
        np.testing.assert_array_equal(got, np_write_back(heap, addrs,
                                                         vals))
    # empty batch: unchanged copy
    np.testing.assert_array_equal(
        ops.write_back(heap, np.zeros(0, np.int64), np.zeros(0, np.int64)),
        heap)


def test_ops_write_back_exact_beyond_int32():
    """Payloads past int32 must land exact (the wrapper must not let the
    x64-disabled jax path truncate them silently)."""
    from repro.kernels import ops

    big = (1 << 40) + 123
    heap = np.arange(16, dtype=np.int64)
    out = ops.write_back(heap, np.array([3, 5]),
                         np.array([big, -big], np.int64))
    assert out[3] == big and out[5] == -big
    # big values already IN the heap must survive a small-value scatter
    heap2 = np.array([big, 1, 2], np.int64)
    out2 = ops.write_back(heap2, np.array([1]), np.array([7], np.int64))
    assert out2.tolist() == [big, 7, 2]


def test_ops_write_back_rejects_beyond_int32_addresses():
    """ADDRESSES past int32 must not truncate through the kernel's int32
    cast and scatter to the wrong word (or vanish): such batches route
    to the numpy twin, where an out-of-range address raises."""
    from repro.kernels import ops

    heap = np.arange(16, dtype=np.int64)
    with pytest.raises(IndexError):
        ops.write_back(heap, np.array([(1 << 31) + 5], np.int64),
                       np.array([1], np.int64))


def test_scatter_paths_reject_negative_addresses():
    """A negative address wraps under numpy/jax fancy indexing and would
    silently overwrite (or read) a word near the end of the heap; every
    scatter/gather bulk path must raise instead, mutating nothing."""
    import jax.numpy as jnp

    from repro.core.engine.arrayheap import ArrayHeap
    from repro.kernels.scatter_write import np_write_back

    h = ArrayHeap(8)
    h.alloc(8, 5)
    with pytest.raises(IndexError):
        h.scatter(np.array([2, -1]), np.array([9, 9]))
    assert h[2] == 5 and h[7] == 5            # nothing written
    with pytest.raises(IndexError):
        h.gather(np.array([0, -3]))
    with pytest.raises(IndexError):
        np_write_back(np.zeros(8, np.int64), np.array([-3]),
                      np.array([1]))
    with pytest.raises(IndexError):
        C.scatter_row(jnp.arange(8), np.array([-1]),
                      np.array([1], np.int64))


# ---------------------------------------------------------------------------
# parity: bulk == scalar commit, all six backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_write_bulk_commits_like_scalar(backend):
    """The same rotate-a-block update, once through ``tx.write_bulk``
    (bulk lock sweep + scatter at N >= BULK_MIN) and once through the
    scalar ``tx.write`` loop: identical heap afterwards, and mid-txn
    reads see the batch's own writes."""
    def build(tm):
        base = tm.alloc(N, 0)
        run(tm, lambda tx: tx.write_bulk(range(base, base + N),
                                         list(range(N))), tid=0)
        return base

    def rotate_bulk(tm, base):
        def tx_body(tx):
            vals = np.asarray(tx.read_bulk(range(base, base + N)),
                              np.int64)
            tx.write_bulk(range(base, base + N), np.roll(vals, 1))
            # read-own-writes: the batch's values are visible mid-txn
            assert int(tx.read(base)) == N - 1
            assert int(tx.read(base + 1)) == 0
        run(tm, tx_body, tid=0)

    def rotate_scalar(tm, base):
        def tx_body(tx):
            vals = [int(v) for v in tx.read_bulk(range(base, base + N))]
            for i in range(N):
                tx.write(base + i, vals[(i - 1) % N])
        run(tm, tx_body, tid=0)

    if backend == "mvstore":
        tm_b = make_test_tm(backend, n_threads=1)
        tm_s = make_test_tm(backend, n_threads=1)
    else:
        tm_b, tm_s = _word_tm(backend), _word_tm(backend)
    try:
        base_b, base_s = build(tm_b), build(tm_s)
        rotate_bulk(tm_b, base_b)
        rotate_scalar(tm_s, base_s)
        got = [int(tm_b.peek(base_b + i)) for i in range(N)]
        want = [int(tm_s.peek(base_s + i)) for i in range(N)]
        assert got == want == [(i - 1) % N for i in range(N)]
    finally:
        tm_b.stop()
        tm_s.stop()


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_write_bulk_duplicate_addresses_last_write_wins(backend):
    """``write_bulk`` promises ``for a, v: write(a, v)`` semantics, so a
    duplicated address must keep the LAST value on every backend — the
    encounter-time scatter paths collapse duplicates explicitly (a raw
    fancy-index scatter keeps an unspecified writer)."""
    tm = make_test_tm(backend, n_threads=1) if backend == "mvstore" \
        else _word_tm(backend)
    try:
        base = tm.alloc(N, 0)
        addrs = list(range(base, base + N)) + [base + 5, base + 5]
        vals = list(range(N)) + [777, 888]
        run(tm, lambda tx: tx.write_bulk(addrs, vals), tid=0)
        assert int(tm.peek(base + 5)) == 888
        assert int(tm.peek(base + 4)) == 4
    finally:
        tm.stop()


@pytest.mark.parametrize("backend", WORD_BACKENDS)
def test_write_bulk_engages_bulk_lock_path(backend):
    """At N >= BULK_MIN on the array heap, the write locks really are
    claimed (released at commit) — pinned via the lock table's held_by
    while the transaction is still open."""
    tm = _word_tm(backend)
    try:
        base = tm.alloc(N, 7)
        raw = tm.raw
        run(tm, lambda tx: tx.write(base, 7), tid=0)  # settle the clock
        tx = tm.begin(0)
        try:
            tx.write_bulk(range(base, base + N), [1] * N)
        except AbortTx:      # deferred-clock first-write abort: retry
            tm.abort(tx)
            tx = tm.begin(0)
            tx.write_bulk(range(base, base + N), [1] * N)
        if backend in ("tl2", "norec"):
            assert len(tx._ctx.write_map) == N     # buffered until commit
            assert len(raw.locks.held_by(0)) == 0
        else:
            assert len(raw.locks.held_by(0)) > 0   # encounter-time claims
            assert len(tx._ctx.undo) == N
        tm.commit(tx)
        assert len(raw.locks.held_by(0)) == 0
        assert all(int(tm.peek(base + i)) == 1 for i in range(N))
    finally:
        tm.stop()


# ---------------------------------------------------------------------------
# all-or-nothing conflict behavior
# ---------------------------------------------------------------------------


def test_bulk_acquire_all_or_nothing_on_conflict():
    """TL2 commit-time bulk acquire: when ONE lock in the batch is held
    by another thread, the sweep must acquire NOTHING and the commit
    must abort with the heap untouched."""
    tm = _word_tm("tl2")
    try:
        raw = tm.raw
        base = tm.alloc(N, 7)
        # tid 1 holds the lock covering the LAST address
        victim_idx = raw.locks.index(base + N - 1)
        st = raw.locks.read(victim_idx)
        assert raw.locks.try_lock(victim_idx, st, tid=1)
        before = [int(tm.peek(base + i)) for i in range(N)]
        with pytest.raises(AbortTx):
            with tm.txn(tid=0) as tx:
                tx.write_bulk(range(base, base + N), [9] * N)
        assert len(raw.locks.held_by(0)) == 0      # nothing acquired
        assert [int(tm.peek(base + i)) for i in range(N)] == before
        raw.locks.unlock(victim_idx)
    finally:
        tm.stop()


def test_encounter_bulk_write_conflict_aborts_clean():
    """DCTL encounter-time bulk write: a conflicting batch aborts with
    no locks held and no words written (the scalar loop would have
    locked and written a prefix, then rolled it back — same end state,
    which this pins)."""
    tm = _word_tm("dctl")
    try:
        raw = tm.raw
        base = tm.alloc(N, 7)
        victim_idx = raw.locks.index(base + N // 2)
        st = raw.locks.read(victim_idx)
        assert raw.locks.try_lock(victim_idx, st, tid=1)
        with pytest.raises(AbortTx):
            with tm.txn(tid=0) as tx:
                tx.write_bulk(range(base, base + N), [9] * N)
        assert len(raw.locks.held_by(0)) == 0
        assert all(int(tm.peek(base + i)) == 7 for i in range(N))
        raw.locks.unlock(victim_idx)
    finally:
        tm.stop()


# ---------------------------------------------------------------------------
# encounter-time bulk rollback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ("dctl", "tinystm", "multiverse"))
def test_bulk_rollback_restores_undo_exactly(backend):
    """A bulk-written batch that aborts mid-transaction must scatter the
    undo log back exactly (first-write-wins pre-images included) and
    release every lock at a bumped clock."""
    tm = _word_tm(backend)
    try:
        raw = tm.raw
        base = tm.alloc(N, 0)
        run(tm, lambda tx: tx.write_bulk(range(base, base + N),
                                         list(range(N))), tid=0)
        # bump past the setup commit's versions so the single-attempt
        # txn below cannot hit the deferred clock's first-write abort
        raw.clock.increment()
        clock0 = raw.clock.load()

        class Boom(Exception):
            pass

        with pytest.raises(Boom):
            with tm.txn(tid=0) as tx:
                # scalar write first: ITS pre-image must win over the
                # bulk batch's later gather of the already-dirty word
                tx.write(base + 3, -5)
                tx.write_bulk(range(base, base + N), [-1] * N)
                assert int(tx.read(base + 3)) == -1
                raise Boom()
        assert [int(tm.peek(base + i)) for i in range(N)] == \
            list(range(N))
        assert len(raw.locks.held_by(0)) == 0
        assert raw.clock.load() > clock0           # deferred-clock bump
    finally:
        tm.stop()


# ---------------------------------------------------------------------------
# snapshot extension: bump BEFORE revalidate (serializability)
# ---------------------------------------------------------------------------


def test_extension_bumps_clock_before_revalidating():
    """``extend_and_relock`` must advance the deferred clock FIRST and
    revalidate at the old ``r_clock`` SECOND.  The reverse order has a
    serializability hole: a foreign transaction that locks, overwrites a
    read-set address, and releases at the pre-bump clock — entirely
    between the revalidation and the bump — publishes at a version the
    extended snapshot (``r_clock = C+1``) accepts under V_LT, so the
    stale read is NEVER caught and the commit succeeds.  This test
    injects exactly that foreign commit inside ``clock.increment`` (the
    first instant of the extension under the fixed order, the unguarded
    window under the old one) and requires the transaction to abort.
    """
    tm = _word_tm("dctl")
    try:
        raw = tm.raw
        base = tm.alloc(N, 0)
        x = tm.alloc(1, 42)
        # leaves every batch word's version == the current clock, so the
        # next bulk claim is version-blocked and takes the extension
        run(tm, lambda tx: tx.write_bulk(range(base, base + N),
                                         [1] * N), tid=0)
        tx = tm.begin(0)
        assert int(tx.read(x)) == 42           # x joins the read set
        orig_inc = raw.clock.increment
        x_idx = raw.locks.index(x)

        def racing_increment():
            # foreign tid 1: lock x's word, overwrite it, release at the
            # CURRENT (pre-bump) clock — the deferred-clock publish
            raw.clock.increment = orig_inc     # fire exactly once
            st = raw.locks.read(x_idx)
            assert raw.locks.try_lock(x_idx, st, tid=1)
            raw.heap[x] = 99
            raw.locks.unlock(x_idx, raw.clock.load())
            return orig_inc()

        raw.clock.increment = racing_increment
        try:
            with pytest.raises(AbortTx):
                tx.write_bulk(range(base, base + N), [2] * N)
                tm.commit(tx)
            tm.abort(tx)
        finally:
            raw.clock.increment = orig_inc
        # the foreign write survives; the doomed batch wrote nothing
        assert int(tm.peek(x)) == 99
        assert all(int(tm.peek(base + i)) == 1 for i in range(N))
        assert len(raw.locks.held_by(0)) == 0
    finally:
        tm.stop()


@pytest.mark.parametrize("backend", ("dctl", "tinystm", "multiverse"))
def test_scalar_write_extends_past_own_commit(backend):
    """Back-to-back SCALAR write transactions must not abort on their own
    previous commit.  Under the deferred clock a commit leaves its lock
    words at version == the current clock, so the next transaction's
    encounter-time validate (``version < r_clock``) fails with nothing
    conflicting; the scalar path used to eat one abort-and-replay per
    commit where the bulk path snapshot-extends.  Single-attempt
    transactions (no retry loop) pin that the extension now serves the
    scalar path too — any abort surfaces as an uncaught AbortTx."""
    tm = _word_tm(backend)
    try:
        raw = tm.raw
        a = tm.alloc(1, 0)
        b = tm.alloc(1, 0)
        for k, addr in enumerate((a, b, a), start=1):
            tx = tm.begin(0)
            tx.write(addr, k)               # must not raise AbortTx
            tm.commit(tx)
        assert int(tm.peek(a)) == 3
        assert int(tm.peek(b)) == 2
        assert len(raw.locks.held_by(0)) == 0
    finally:
        tm.stop()


def test_scalar_extension_bumps_clock_before_revalidating():
    """Scalar twin of ``test_extension_bumps_clock_before_revalidating``:
    ``extend_snapshot`` must advance the deferred clock FIRST and
    revalidate at the old ``r_clock`` SECOND, for exactly the bulk
    path's reason — a foreign commit landing entirely between a
    revalidate-then-bump pair publishes at the pre-bump clock, which
    the extended snapshot then accepts as valid forever.  The foreign
    commit is injected inside ``clock.increment`` (the first instant of
    the extension under the fixed order) and must force an abort."""
    tm = _word_tm("dctl")
    try:
        raw = tm.raw
        w = tm.alloc(1, 0)
        x = tm.alloc(1, 42)
        # distinct lock words, so w's claim cannot see x's foreign lock
        assert raw.locks.index(w) != raw.locks.index(x)
        # leaves w's version == the current clock, so the next scalar
        # write is version-blocked and takes the extension
        run(tm, lambda tx: tx.write(w, 1), tid=0)
        tx = tm.begin(0)
        assert int(tx.read(x)) == 42           # x joins the read set
        orig_inc = raw.clock.increment
        x_idx = raw.locks.index(x)

        def racing_increment():
            # foreign tid 1: lock x's word, overwrite it, release at the
            # CURRENT (pre-bump) clock — the deferred-clock publish
            raw.clock.increment = orig_inc     # fire exactly once
            st = raw.locks.read(x_idx)
            assert raw.locks.try_lock(x_idx, st, tid=1)
            raw.heap[x] = 99
            raw.locks.unlock(x_idx, raw.clock.load())
            return orig_inc()

        raw.clock.increment = racing_increment
        try:
            with pytest.raises(AbortTx):
                tx.write(w, 2)
                tm.commit(tx)
            tm.abort(tx)
        finally:
            raw.clock.increment = orig_inc
        # the foreign write survives; the doomed write landed nothing
        assert int(tm.peek(x)) == 99
        assert int(tm.peek(w)) == 1
        assert len(raw.locks.held_by(0)) == 0
    finally:
        tm.stop()


# ---------------------------------------------------------------------------
# lock-index normalization (the release_locks fix)
# ---------------------------------------------------------------------------


def _colliding_addrs(locks, base, n, count=2):
    """Find `count` addresses in [base, base+n) sharing one lock index."""
    seen = {}
    for a in range(base, base + n):
        idx = locks.index(a)
        seen.setdefault(idx, []).append(a)
        if len(seen[idx]) >= count:
            return idx, seen[idx][:count]
    raise AssertionError("no collision found — shrink the lock table")


@pytest.mark.parametrize("backend", ("multiverse", "dctl"))
@pytest.mark.parametrize("path", ("commit", "rollback"))
def test_colliding_addresses_release_once(backend, path):
    """Two addresses sharing a lock word must release it exactly once on
    commit AND on rollback.  Releasing per heap address used to unlock
    the shared word twice; after the first release another thread can
    legitimately claim it, and the second release stomps their lock."""
    tm = _word_tm(backend, lock_bits=4)    # 16 words: collisions certain
    try:
        raw = tm.raw
        base = tm.alloc(64, 7)
        # versions start at the clock: bump so a single-attempt txn
        # cannot hit the deferred clock's first-write abort
        raw.clock.increment()
        idx, (a1, a2) = _colliding_addrs(raw.locks, base, 64)
        released = []
        orig_unlock = raw.locks.unlock
        orig_bulk = raw.locks.unlock_bulk

        def counting_unlock(i, version=None):
            released.append(int(i))
            orig_unlock(i, version)

        def counting_bulk(idxs, version=None):
            released.extend(int(i) for i in np.asarray(idxs))
            orig_bulk(idxs, version)

        raw.locks.unlock = counting_unlock
        raw.locks.unlock_bulk = counting_bulk
        try:
            if path == "commit":
                run(tm, lambda tx: (tx.write(a1, 1), tx.write(a2, 2)),
                    tid=0, max_retries=50)
            else:
                with pytest.raises(AbortTx):
                    with tm.txn(tid=0) as tx:
                        tx.write(a1, 1)
                        tx.write(a2, 2)
                        raise AbortTx()
        finally:
            raw.locks.unlock = orig_unlock
            raw.locks.unlock_bulk = orig_bulk
        # the colliding word was released exactly once per release pass
        # (retries each release once; never twice back-to-back)
        assert released.count(idx) >= 1
        for i in range(len(released) - 1):
            assert not (released[i] == idx and released[i + 1] == idx), \
                "shared lock word released twice in one pass"
        st = raw.locks.read(idx)
        assert not st.locked
    finally:
        tm.stop()


def test_publish_bulk_matches_scalar_publish():
    """PackedVLT.publish_bulk == a loop of scalar publishes: same rows,
    same seqlocks even, same select results."""
    from repro.core.vlt import PackedVLT, VListNode

    def seeded():
        m = PackedVLT(32, depth=3)
        for b, a, v in ((1, 10, 100), (1, 11, 110), (9, 20, 200)):
            m.seed(b, a, VListNode(None, 1, v, False))
        return m

    buckets = np.array([1, 1, 9, 5])
    addrs = np.array([10, 11, 20, 99])
    datas = [101, 111, 201, 5]
    m_bulk, m_scalar = seeded(), seeded()
    m_bulk.publish_bulk(buckets, addrs, 7, datas)
    for b, a, v in zip(buckets, addrs, datas):
        m_scalar.publish(int(b), int(a), 7, v)
    np.testing.assert_array_equal(m_bulk._ts, m_scalar._ts)
    np.testing.assert_array_equal(m_bulk._data, m_scalar._data)
    np.testing.assert_array_equal(m_bulk._addr, m_scalar._addr)
    assert (m_bulk._seq % 2 == 0).all()
    q_idx = np.array([1, 1, 9])
    q_addr = np.array([10, 11, 20])
    for clock, want in ((100, [101, 111, 201]), (7, [100, 110, 200])):
        vb, okb = m_bulk.select(q_idx, q_addr, clock)
        vs, oks = m_scalar.select(q_idx, q_addr, clock)
        assert okb.tolist() == oks.tolist() == [True] * 3
        assert vb.tolist() == vs.tolist() == want
