"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 2, 2, 32),      # MHA
    (2, 256, 4, 2, 64),      # GQA 2:1
    (1, 512, 8, 1, 64),      # MQA
    (2, 128, 4, 4, 128),     # MXU-aligned head dim
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, D, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    o = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    G = H // KV
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), G, 1).reshape(B * H, S, D)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), G, 1).reshape(B * H, S, D)
    r = ref.flash_attention_ref(qr, kr, vr, causal=causal)
    r = r.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_matches_blockwise_xla():
    """The XLA blockwise lowering (dry-run path) and the Pallas kernel
    implement the same schedule: they must agree."""
    from repro.models.attention import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, D = 2, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    o1 = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    o2 = blockwise_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    # and the unrolled probe variant is numerically identical in structure
    o3 = blockwise_attention(q, k, v, causal=True, block_q=64, block_k=64,
                             unroll=True)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o3),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 8, 4, 16),
    (2, 128, 4, 16, 8, 32),
    (1, 256, 2, 32, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    xh = (jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5
          ).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B_ = (jax.random.normal(ks[3], (B, S, N), jnp.float32) * 0.5
          ).astype(dtype)
    C_ = (jax.random.normal(ks[4], (B, S, N), jnp.float32) * 0.5
          ).astype(dtype)
    y, _ = ops.ssd_scan(xh, dt, A, B_, C_, chunk=chunk)
    yr, _ = ref.ssd_scan_ref(xh, dt, A, B_, C_)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


def test_ssd_xla_chunked_matches_sequential_ref():
    from repro.models.mamba import ssd_chunk_scan
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P, N = 2, 128, 4, 16, 8
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, N)) * 0.5
    C_ = jax.random.normal(ks[4], (B, S, N)) * 0.5
    for unroll in (False, True):
        y, st = ssd_chunk_scan(xh, dt, A, B_, C_, chunk=32, unroll=unroll)
        yr, str_ = ref.ssd_scan_ref(xh, dt, A, B_, C_)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("R,shape", [(2, (64,)), (4, (8, 16)),
                                     (8, (4, 4, 8)), (3, (100,))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_snapshot_select_sweep(R, shape, dtype):
    key = jax.random.PRNGKey(4)
    if dtype == jnp.int32:
        ring = jax.random.randint(key, (R,) + shape, 0, 100, jnp.int32)
    else:
        ring = jax.random.normal(key, (R,) + shape, jnp.float32
                                 ).astype(dtype)
    ts = jnp.asarray(np.random.RandomState(0).permutation(R) * 3 - 1,
                     jnp.int32)
    for clock in (-1, 0, 2, 5, 100):
        val, ok = ops.snapshot_select(ring, ts, jnp.int32(clock))
        vr, okr = ref.snapshot_select_ref(
            ring.reshape(R, -1), ts, clock)
        assert bool(ok) == bool(okr)
        if bool(okr):
            np.testing.assert_array_equal(
                np.asarray(val).ravel(), np.asarray(vr))


@pytest.mark.parametrize("shape", [(64,), (24, 16), (3, 5, 8)])
@pytest.mark.parametrize("with_ring", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_adamw_sweep(shape, with_ring, dtype):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    p = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    g = jax.random.normal(ks[1], shape, jnp.float32)
    m = jax.random.normal(ks[2], shape, jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], shape, jnp.float32)) * 0.01
    ring = jnp.zeros((3,) + shape, dtype) if with_ring else None
    kw = dict(lr=jnp.float32(3e-3), scale=jnp.float32(0.7), b1=0.9,
              b2=0.95, eps=1e-8, wd=0.1)
    p2, m2, v2, r2 = ops.fused_adamw(p, g, m, v, ring, 2,
                                     count=jnp.int32(3), **kw)
    cnt = jnp.float32(3)
    pr, mr, vr2, rr = ref.fused_adamw_ref(
        p.reshape(-1), g.reshape(-1), m.reshape(-1), v.reshape(-1),
        ring.reshape(3, -1) if with_ring else None, 2,
        b1c=1 - 0.9 ** cnt, b2c=1 - 0.95 ** cnt, **kw)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(p2.reshape(-1), np.float32),
                               np.asarray(pr, np.float32), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(m2.reshape(-1)), np.asarray(mr),
                               rtol=1e-5, atol=1e-5)
    if with_ring:
        np.testing.assert_allclose(
            np.asarray(r2.reshape(3, -1), np.float32),
            np.asarray(rr, np.float32), rtol=tol, atol=tol)
        # untouched slots stay zero
        assert float(jnp.abs(r2[0]).sum()) == 0.0


# ---------------------------------------------------------------------------
# bulk read-set validation kernel vs the scalar Python validator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", [0, 1, 2])          # V_LT / V_LE / V_EQ
@pytest.mark.parametrize("n", [1, 7, 512, 1000])
def test_validate_readset_kernel_matches_scalar(mode, n):
    """The Pallas kernel, the numpy twin and the word-at-a-time scalar
    validator must agree on every (lock word, read entry) combination."""
    from repro.core.engine import validation as V
    from repro.core.engine.arrayheap import ArrayLockTable
    from repro.core.locks import LockState

    rng = np.random.default_rng(17 * mode + n)
    lt = ArrayLockTable(9)
    for idx in rng.integers(0, 1 << 9, 150):
        lt.store(int(idx), LockState(
            bool(rng.integers(2)), int(rng.integers(0, 30)),
            int(rng.integers(-2, 4)), bool(rng.integers(2))))
    read_set = [(int(i), int(rng.integers(0, 30)))
                for i in rng.integers(0, 1 << 9, n)]
    idxs = np.array([e[0] for e in read_set], np.int64)
    seen = np.array([e[1] for e in read_set], np.int64)
    ver, own, meta = lt.gather(idxs)
    for r_clock, tid in [(0, 0), (15, 1), (29, -1)]:
        scalar = V.revalidate_scalar(lt, read_set, r_clock, tid, mode)
        via_np = V.np_validate(ver, own, meta, seen, r_clock, tid, mode)
        via_kernel = ops.validate_readset(ver, own, meta, seen, r_clock,
                                          tid, mode)
        assert scalar == via_np == via_kernel, (mode, n, r_clock, tid)


def test_validate_readset_kernel_elementwise_mask():
    """Per-element mask parity (not just the AND): each lane of the kernel
    must equal the scalar predicate for its lock word."""
    from repro.core.engine import validation as V
    from repro.kernels import validate as vk
    from repro.core.locks import LockState

    states = []
    for locked in (False, True):
        for tid in (-2, 0, 1):
            for flag in (False, True):
                for version in (0, 3, 7):
                    states.append(LockState(locked, version, tid, flag))
    ver = jnp.asarray([s.version for s in states], jnp.int32)
    own = jnp.asarray([s.tid for s in states], jnp.int32)
    meta = jnp.asarray([int(s.locked) | (int(s.flag) << 1)
                        for s in states], jnp.int32)
    seen = jnp.asarray([s.version if i % 2 == 0 else s.version + 1
                        for i, s in enumerate(states)], jnp.int32)
    pad = (-len(states)) % 8
    pd = vk.PAD

    def prep(x, fill):
        return jnp.pad(x, (0, pad), constant_values=fill)

    for mode in (0, 1, 2):
        mask = vk.validate_readset_flat(
            prep(ver, pd["ver"]), prep(own, pd["own"]),
            prep(meta, pd["meta"]), prep(seen, pd["seen"]),
            r_clock=5, tid=0, mode=mode, tile=8, interpret=True)
        for i, s in enumerate(states):
            want = V.check_entry(s, int(seen[i]), 5, 0, mode)
            assert bool(mask[i]) == want, (mode, i, s)
        assert bool(jnp.all(mask[len(states):] == 1))   # padding all-valid


def test_validate_readset_survives_64bit_clock():
    """Lock versions exceed int32 in long runs (the packed word gives the
    version 46 bits); ops.validate_readset rebases to r_clock before the
    int32 kernel, so it must agree with the int64 numpy twin out there."""
    from repro.core.engine import validation as V

    big = (1 << 31) + 12345
    ver = np.asarray([big, big + 1, big - 1, big - 3], np.int64)
    own = np.full(4, -1, np.int32)
    meta = np.zeros(4, np.int32)
    seen = ver.copy()
    for mode, r_clock in [(0, big), (0, big + 2), (1, big), (2, big + 2)]:
        want = V.np_validate(ver, own, meta, seen, r_clock, 0, mode)
        got = ops.validate_readset(ver, own, meta, seen, r_clock, 0, mode)
        assert got == want, (mode, r_clock, got, want)
    # stale entry at a 64-bit clock: version == r_clock fails V_LT
    assert not ops.validate_readset(
        np.asarray([big], np.int64), own[:1], meta[:1],
        np.asarray([big], np.int64), big, 0, 0)
