"""Crash matrix: every fault point x pipeline x backend must recover.

The PR's test centerpiece.  Each case injects a simulated crash at one
named fault point (``repro.reliability.faultpoints``) inside one commit
pipeline — solo commit, group commit, or the MVStore fused publish —
then runs recovery and asserts the recovered state IS the
committed-prefix reference:

  * heap equals the reference — every transaction that finished commit,
    plus the crashed one iff its commit record (``publish_started``) was
    written (roll forward), and excluding it otherwise (roll back);
  * the lock table is empty (orphaned locks released);
  * no torn PackedVLT mirror rows;
  * the clock never went backwards.

``test_crash_quick_*`` is the 6-case smoke subset CI selects with
``-k "crash and quick"``.
"""
import os

import numpy as np
import pytest

from repro.api.substrate import run
from repro.core.baselines import DCTL, TL2, TinySTM
from repro.core.engine.groupcommit import CommitBatcher
from repro.core.stm import Multiverse
from repro.reliability import faultpoints as FP
from repro.reliability.recovery import (check_engine_invariants,
                                        check_store_invariants,
                                        recover_engine, recover_handle)

N = 300          # >= BULK_MIN so the bulk claim/scatter paths (and their
#                  fault points) are actually on the commit path

WORD_BACKENDS = {
    "multiverse": lambda n: Multiverse(n, start_bg=False),
    "tl2": TL2,
    "dctl": DCTL,
    "tinystm": TinySTM,
}

POINTS = ("pre_claim", "post_claim", "pre_clock_tick",
          "pre_scatter", "post_scatter", "pre_release")


@pytest.fixture(autouse=True)
def _no_leftover_schedule():
    yield
    FP.uninstall()
    FP.reset_thread()


def _committed_write(tm, base):
    def w0(tx):
        tx.write_bulk(np.arange(base, base + N), list(range(N)))
    run(tm, w0, tid=0)


def _crashing_write(tm, tid):
    def w1(tx):
        tx.write_bulk(np.arange(N), [v + 1000 for v in range(N)])
    run(tm, w1, tid=tid)


def _heap_prefix(tm, n):
    return [tm.peek(i) for i in range(n)]


def _assert_recovered(tm, dead, clock0, *, expect_committed,
                      expect_rolled_back):
    """Run recovery, then assert the committed-prefix invariants."""
    rep = recover_engine(tm, dead)
    violations = check_engine_invariants(tm, clock_at_least=clock0)
    assert violations == [], violations
    got = _heap_prefix(tm, N)
    assert got == (expect_committed if not expect_rolled_back
                   else [v for v in range(N)])
    return rep


def _run_solo_case(backend, point):
    tm = WORD_BACKENDS[backend](2)
    base = tm.alloc(N, 0)
    assert base == 0
    _committed_write(tm, base)
    clock0 = tm.clock.load() if hasattr(tm.clock, "load") else 0
    sched = FP.install(FP.FaultSchedule([FP.Fault(point, 1, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        _crashing_write(tm, tid=1)
    FP.uninstall()
    assert sched.fired and sched.fired[0][0] == point
    d = tm.ctx(1) if hasattr(tm, "ctx") else tm.raw.ctx(1)
    decided = d.publish_started
    rep = _assert_recovered(
        tm, [1], clock0,
        expect_committed=[v + 1000 for v in range(N)],
        expect_rolled_back=not decided)
    if decided:
        assert rep.rolled_forward == [1]
    else:
        assert rep.rolled_back == [1] or rep.released_locks >= 0
    # the store stays usable: the next transaction commits normally
    def w2(tx):
        tx.write_bulk(np.arange(8), [7] * 8)
    run(tm, w2, tid=1)
    assert _heap_prefix(tm, 8) == [7] * 8


# ---------------------------------------------------------------------------
# solo commit pipeline: every backend x every commit-path fault point
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", sorted(WORD_BACKENDS))
@pytest.mark.parametrize("point", POINTS)
def test_crash_solo_commit(backend, point):
    # encounter backends reach claim/scatter points via write_bulk, the
    # buffered ones via commit: every point is on some backend's path —
    # a point NOT on this backend's path simply never fires, which the
    # schedule journal makes explicit
    tm = WORD_BACKENDS[backend](2)
    base = tm.alloc(N, 0)
    _committed_write(tm, base)
    clock0 = tm.clock.load()
    sched = FP.install(FP.FaultSchedule([FP.Fault(point, 1, "kill")]))
    crashed = False
    try:
        _crashing_write(tm, tid=1)
    except FP.SimulatedCrash:
        crashed = True
    FP.uninstall()
    if not crashed:
        # off-path point for this backend: nothing fired, nothing broke
        assert sched.fired == []
        assert check_engine_invariants(tm, clock_at_least=clock0) == []
        return
    decided = tm.ctx(1).publish_started
    _assert_recovered(
        tm, [1], clock0,
        expect_committed=[v + 1000 for v in range(N)],
        expect_rolled_back=not decided)


# ---------------------------------------------------------------------------
# group commit pipeline
# ---------------------------------------------------------------------------


def _run_group_case(backend, point):
    cls = WORD_BACKENDS[backend]
    tm = cls(4)
    n_members = 3
    base = tm.alloc(n_members * N, 0)
    txs = []
    for t in range(n_members):
        tx = tm.begin(t)
        a = np.arange(base + t * N, base + (t + 1) * N)
        tx.write_bulk(a, [t * 10000 + i for i in range(N)])
        txs.append(tx)
    clock0 = tm.clock.load()
    batcher = CommitBatcher(tm)
    for tx in txs:
        batcher.add(tx)
    sched = FP.install(FP.FaultSchedule([FP.Fault(point, 1, "kill")]))
    crashed = False
    try:
        batcher.commit_all()
    except FP.SimulatedCrash:
        crashed = True
    FP.uninstall()
    if not crashed:
        pytest.skip(f"{point} not on the {backend} group path")
    rep = recover_engine(tm, list(range(n_members)))
    violations = check_engine_invariants(tm, clock_at_least=clock0)
    assert violations == [], violations
    got = np.array([tm.peek(base + i) for i in range(n_members * N)])
    decided = [tm.ctx(t).publish_started for t in range(n_members)]
    exp = np.concatenate([
        np.arange(N) + t * 10000 if decided[t] else np.zeros(N, np.int64)
        for t in range(n_members)])
    assert np.array_equal(got, exp)
    assert rep.dead_tids == [0, 1, 2]


@pytest.mark.parametrize("point", POINTS)
def test_crash_group_buffered(point):
    _run_group_case("tl2", point)


@pytest.mark.parametrize("point", ("pre_clock_tick", "pre_release"))
def test_crash_group_encounter(point):
    _run_group_case("dctl", point)


# ---------------------------------------------------------------------------
# MVStore fused publish
# ---------------------------------------------------------------------------


MV_POINTS = ("pre_clock_tick", "pre_scatter", "post_scatter", "pre_release")


def _run_mvstore_case(point):
    from repro.api.mvhandle import MVStoreHandle
    h = MVStoreHandle(n_threads=2, versioned="all", start_bg=False)
    h.alloc(32, 0)

    def w0(tx):
        tx.write_bulk(np.arange(32), list(range(32)))
    run(h, w0, tid=0)
    clock0 = h.clock
    sched = FP.install(FP.FaultSchedule([FP.Fault(point, 1, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        def w1(tx):
            tx.write_bulk(np.arange(32), [v + 100 for v in range(32)])
        run(h, w1, tid=1)
    FP.uninstall()
    assert sched.fired and sched.fired[0][0] == point
    rep = recover_handle(h)
    violations = check_store_invariants(h, clock_at_least=clock0)
    assert violations == [], violations
    vals, ok = h.snapshot_bulk(np.arange(32))
    assert ok
    exp = ([v + 100 for v in range(32)] if rep.completed_install
           else list(range(32)))
    assert list(np.asarray(vals)) == exp
    # the fused-publish donation race is healed: a crash past the fused
    # call strands readers on deleted buffers, and completing the
    # install is the ONLY way forward — pin the direction
    if point in ("post_scatter", "pre_release"):
        assert rep.completed_install
    # store stays usable
    def w2(tx):
        tx.write_bulk(np.arange(8), [7] * 8)
    run(h, w2, tid=0)
    vals, _ = h.snapshot_bulk(np.arange(8))
    assert list(np.asarray(vals)) == [7] * 8
    h.stop()


@pytest.mark.parametrize("point", MV_POINTS)
def test_crash_mvstore_fused(point):
    _run_mvstore_case(point)


# ---------------------------------------------------------------------------
# ShardStore cross-shard epoch publish
# ---------------------------------------------------------------------------

# fire order for a 2-write-shard epoch publish: pre_claim(1),
# post_claim(1), pre_clock_tick(1) = the EPOCH tick, then per write
# shard the solo publish's pre_clock_tick/pre_scatter/post_scatter/
# pre_release, and finally the epoch-level pre_release as its 3rd fire.
# expect_forward: None = crash before the record exists (clean unwind),
# False = record parked but publish_started unset (roll back), True =
# publish_started set (roll the WHOLE epoch forward).
SHARD_EPOCH_CASES = [
    ("pre_claim", 1, None),        # before the locks: no record at all
    ("pre_clock_tick", 1, False),  # epoch tick: parked, nothing started
    ("pre_scatter", 1, True),      # mid shard-0 publish
    ("pre_scatter", 2, True),      # shard 0 done, mid shard-1 publish
    ("pre_release", 3, True),      # both published, epoch not released
]


def _run_shardstore_epoch_case(point, nth, expect_forward):
    from repro.core.shardstore import ShardStoreHandle
    from repro.reliability.recovery import (check_shardstore_invariants,
                                            recover_shardstore)
    st = ShardStoreHandle(2, n_shards=2, span=4, start_bg=False)
    st.alloc(32, 0)

    def w0(tx):
        tx.write_bulk(np.arange(32), list(range(32)))
    run(st, w0, tid=0)             # committed cross-shard prefix
    clocks0 = st.clocks
    sched = FP.install(FP.FaultSchedule([FP.Fault(point, nth, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        def w1(tx):
            tx.write_bulk(np.arange(32), [v + 100 for v in range(32)])
        run(st, w1, tid=1)
    FP.uninstall()
    assert sched.fired and sched.fired[-1][0] == point
    rep = recover_shardstore(st)
    violations = check_shardstore_invariants(st, clocks_at_least=clocks0)
    assert violations == [], violations
    # ATOMIC epoch: the heap is ALL-old or ALL-new, never a torn cut —
    # a crash between the two shard-local publishes must not leave
    # shard 0 new and shard 1 old
    vals, ok = st.snapshot_bulk(np.arange(32))
    assert ok
    got = list(np.asarray(vals))
    if expect_forward:
        assert got == [v + 100 for v in range(32)]
        assert rep.rolled_forward == [1]
    else:
        assert got == list(range(32))
        if expect_forward is False:
            assert rep.rolled_back == [1]
        else:
            assert rep.rolled_forward == [] and rep.rolled_back == []
    # begin() must not spin on a stale odd seqlock after recovery, and
    # the store stays usable across BOTH shards
    def w2(tx):
        tx.write_bulk(np.arange(16), [7] * 16)
    run(st, w2, tid=0)
    vals, ok = st.snapshot_bulk(np.arange(16))
    assert ok and list(np.asarray(vals)) == [7] * 16
    st.stop()


@pytest.mark.parametrize("point,nth,expect_forward", SHARD_EPOCH_CASES)
def test_crash_shardstore_epoch(point, nth, expect_forward):
    _run_shardstore_epoch_case(point, nth, expect_forward)


def test_crash_shardstore_single_shard_commit_unaffected():
    """A crash in a SINGLE-shard commit on a sharded store is the solo
    handle's case: per-shard recover_handle (inside recover_shardstore)
    heals it without any epoch record existing."""
    from repro.core.shardstore import ShardStoreHandle
    from repro.reliability.recovery import (check_shardstore_invariants,
                                            recover_shardstore)
    st = ShardStoreHandle(2, n_shards=2, span=4, start_bg=False)
    st.alloc(32, 0)

    def w0(tx):
        tx.write_bulk(np.arange(0, 4), [5] * 4)    # shard 0 only
    run(st, w0, tid=0)
    FP.install(FP.FaultSchedule([FP.Fault("pre_scatter", 1, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        def w1(tx):
            tx.write_bulk(np.arange(0, 4), [9] * 4)
        run(st, w1, tid=1)
    FP.uninstall()
    assert st._epoch_inflight is None              # never an epoch case
    recover_shardstore(st)
    assert check_shardstore_invariants(st) == []
    vals, ok = st.snapshot_bulk(np.arange(4))
    assert ok and set(np.asarray(vals).tolist()) <= {5, 9}
    st.stop()


# ---------------------------------------------------------------------------
# checkpoint manifest publish
# ---------------------------------------------------------------------------


def test_crash_manifest_publish(tmp_path):
    """A crash before the manifest rename leaves only the .tmp directory;
    restore skips it and replays the previous complete checkpoint."""
    import jax.numpy as jnp

    from repro.checkpoint.snapshotter import (restore_checkpoint,
                                              save_checkpoint)
    state1 = {"params": {"w": jnp.arange(4)}, "opt": {"m": jnp.zeros(4)}}
    save_checkpoint(str(tmp_path), 1, state1)
    sched = FP.install(FP.FaultSchedule(
        [FP.Fault("pre_manifest_publish", 1, "crash")]))
    state2 = {"params": {"w": jnp.arange(4) + 9}, "opt": {"m": jnp.ones(4)}}
    with pytest.raises(FP.ProcessCrashed):
        save_checkpoint(str(tmp_path), 2, state2)
    FP.uninstall()
    FP.reset_thread()
    assert sched.process_dead
    step, restored, _ = restore_checkpoint(str(tmp_path), state1)
    assert step == 1
    assert list(np.asarray(restored["params"]["w"])) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# fault actions beyond kill
# ---------------------------------------------------------------------------


def test_crash_raise_action_is_retryable():
    """action='raise' injects an ordinary error: before the commit
    record, the txn scope rolls it back like any user exception and the
    engine stays consistent."""
    tm = WORD_BACKENDS["multiverse"](2)
    tm.alloc(N, 0)
    _committed_write(tm, 0)
    FP.install(FP.FaultSchedule([FP.Fault("pre_claim", 1, "raise")]))
    with pytest.raises(FP.FaultError):
        _crashing_write(tm, tid=1)
    FP.uninstall()
    # run() aborted the txn on the FaultError (not a simulated crash):
    # no recovery needed, the engine is already consistent
    assert check_engine_invariants(tm) == []
    assert _heap_prefix(tm, N) == list(range(N))


def test_crash_raise_after_commit_record_rolls_forward():
    """action='raise' PAST the commit record cannot abort any more: the
    policy completes publication (versions are already visible and the
    scatter has no undo), then lets the error propagate."""
    for backend in ("multiverse", "tl2", "dctl"):
        tm = WORD_BACKENDS[backend](2)
        tm.alloc(N, 0)
        _committed_write(tm, 0)
        FP.install(FP.FaultSchedule([FP.Fault("pre_release", 1, "raise")]))
        with pytest.raises(FP.FaultError):
            _crashing_write(tm, tid=1)
        FP.uninstall()
        assert check_engine_invariants(tm) == [], backend
        assert _heap_prefix(tm, N) == [v + 1000 for v in range(N)], backend


def test_crash_process_drop_marks_schedule():
    tm = WORD_BACKENDS["tl2"](2)
    tm.alloc(N, 0)
    _committed_write(tm, 0)
    sched = FP.install(FP.FaultSchedule(
        [FP.Fault("post_claim", 1, "crash")]))
    with pytest.raises(FP.ProcessCrashed):
        _crashing_write(tm, tid=1)
    FP.uninstall()
    assert sched.process_dead
    recover_engine(tm, [0, 1])
    assert check_engine_invariants(tm) == []


def test_crash_schedule_seeded_periodic_is_deterministic():
    s1 = FP.FaultSchedule(seed=7, kill_every=5, points=("pre_release",),
                          max_fires=3)
    s2 = FP.FaultSchedule(seed=7, kill_every=5, points=("pre_release",),
                          max_fires=3)
    log1, log2 = [], []
    for i in range(60):
        log1.append(s1.arrive("pre_release", i % 4))
        log2.append(s2.arrive("pre_release", i % 4))
    assert log1 == log2
    assert sum(a is not None for a in log1) == 3


def test_crash_dying_thread_suppresses_nested_fires():
    FP.install(FP.FaultSchedule([FP.Fault("pre_claim", 1, "kill"),
                                 FP.Fault("pre_release", 1, "kill")]))
    with pytest.raises(FP.ThreadKilled):
        FP.fire("pre_claim", 0)
    # unwinding code that passes another fault point must NOT re-fire
    FP.fire("pre_release", 0)        # no raise: thread is dying
    FP.uninstall()
    FP.reset_thread()


# ---------------------------------------------------------------------------
# multi-worker simultaneous crashes: >= 2 dead tids, ONE recovery sweep
# ---------------------------------------------------------------------------


def _two_worker_crash(backend, point0, point1):
    """Worker tids 1 and 2 crash on DISJOINT ranges; both descriptors
    stay dead until one recover_engine sweep handles the pair."""
    tm = WORD_BACKENDS[backend](3)
    tm.alloc(2 * N, 0)
    _committed_write(tm, 0)            # tid 0 seeds [0, N)
    clock0 = tm.clock.load()
    # per-point arrival counters: tid 1 runs first and bumps point1's
    # counter iff it reaches point1 before dying at point0 — i.e. iff
    # point1 is at or before point0 in the pipeline order
    order = {p: i for i, p in enumerate(POINTS)}
    nth1 = 2 if order[point1] <= order[point0] else 1
    sched = FP.install(FP.FaultSchedule([
        FP.Fault(point0, 1, "kill", tid=1),
        FP.Fault(point1, nth1, "kill", tid=2)]))
    dead = []
    for tid, lo in ((1, 0), (2, N)):
        def w(tx, lo=lo):
            tx.write_bulk(np.arange(lo, lo + N),
                          [lo + v + 1000 for v in range(N)])
        try:
            run(tm, w, tid=tid)
        except FP.SimulatedCrash:
            dead.append(tid)
            FP.reset_thread()          # the next WORKER is its own thread
    FP.uninstall()
    assert dead == [1, 2], sched.fired
    decided = {t: tm.ctx(t).publish_started for t in dead}
    rep = recover_engine(tm, dead)     # ONE sweep over both corpses
    assert rep.dead_tids == [1, 2]
    violations = check_engine_invariants(tm, clock_at_least=clock0)
    assert violations == [], violations
    for tid, lo in ((1, 0), (2, N)):
        exp = ([lo + v + 1000 for v in range(N)] if decided[tid]
               else ([v for v in range(N)] if lo == 0 else [0] * N))
        assert [tm.peek(lo + i) for i in range(N)] == exp, (tid, decided)
    return rep, decided


@pytest.mark.parametrize("backend", ["multiverse", "tl2"])
def test_crash_multi_worker_both_roll_forward(backend):
    rep, decided = _two_worker_crash(backend, "pre_release", "pre_release")
    assert decided == {1: True, 2: True}
    assert sorted(rep.rolled_forward) == [1, 2]


def test_crash_multi_worker_mixed_directions():
    """tid 1 dies BEFORE its commit record (roll back), tid 2 dies
    holding its locks AFTER (roll forward) — one sweep, two verdicts."""
    rep, decided = _two_worker_crash("tl2", "pre_claim", "pre_release")
    assert decided == {1: False, 2: True}
    assert rep.rolled_forward == [2]


def test_crash_group_two_dead_same_batch_mid_scatter():
    """mid_scatter inside the GROUP publish: the concatenated scatter
    stops with some members' lanes written and others not — every
    member already flipped publish_started off the shared decide, so
    the sweep must roll the WHOLE batch forward."""
    tm = WORD_BACKENDS["tl2"](4)
    n_members = 3
    tm.alloc(n_members * N, 0)
    txs = []
    for t in range(n_members):
        tx = tm.begin(t)
        tx.write_bulk(np.arange(t * N, (t + 1) * N),
                      [t * 10000 + i for i in range(N)])
        txs.append(tx)
    clock0 = tm.clock.load()
    batcher = CommitBatcher(tm)
    for tx in txs:
        batcher.add(tx)
    FP.install(FP.FaultSchedule([FP.Fault("mid_scatter", 1, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        batcher.commit_all()
    FP.uninstall()
    assert all(tm.ctx(t).publish_started for t in range(n_members))
    rep = recover_engine(tm, list(range(n_members)))
    assert sorted(rep.rolled_forward) == [0, 1, 2]
    assert check_engine_invariants(tm, clock_at_least=clock0) == []
    got = [tm.peek(i) for i in range(n_members * N)]
    assert got == [t * 10000 + i for t in range(n_members)
                   for i in range(N)]


# ---------------------------------------------------------------------------
# partial-lane completion (mid_scatter) across the pipelines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, N], ids=["scalar", "bulk"])
def test_crash_partial_lane_write_back_rolls_forward(n):
    """Both write_back publication paths (scalar loop below BULK_MIN,
    bulk scatter above) crash with HALF the lanes written; redo is
    whole-record, so recovery lands the full write set."""
    tm = WORD_BACKENDS["tl2"](2)
    tm.alloc(n, 0)

    def w0(tx):
        tx.write_bulk(np.arange(n), list(range(n)))
    run(tm, w0, tid=0)
    clock0 = tm.clock.load()
    FP.install(FP.FaultSchedule([FP.Fault("mid_scatter", 1, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        def w1(tx):
            tx.write_bulk(np.arange(n), [v + 1000 for v in range(n)])
        run(tm, w1, tid=1)
    FP.uninstall()
    torn = [tm.peek(i) for i in range(n)]
    assert any(v >= 1000 for v in torn) and any(v < 1000 for v in torn)
    rep = recover_engine(tm, [1])
    assert rep.rolled_forward == [1]
    assert check_engine_invariants(tm, clock_at_least=clock0) == []
    assert [tm.peek(i) for i in range(n)] == [v + 1000 for v in range(n)]


def test_crash_partial_lane_encounter_rolls_back():
    """Encounter-time (DCTL) scatter happens at WRITE time, before any
    commit record: a partial-lane crash there must roll back via the
    undo log — the heap returns to the committed prefix."""
    tm = WORD_BACKENDS["dctl"](2)
    tm.alloc(N, 0)
    _committed_write(tm, 0)
    clock0 = tm.clock.load()
    FP.install(FP.FaultSchedule([FP.Fault("mid_scatter", 1, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        _crashing_write(tm, tid=1)
    FP.uninstall()
    assert not tm.ctx(1).publish_started
    rep = recover_engine(tm, [1])
    assert rep.rolled_back == [1]
    assert check_engine_invariants(tm, clock_at_least=clock0) == []
    assert _heap_prefix(tm, N) == list(range(N))


def test_crash_partial_lane_mvstore_fused_wal_recovers(tmp_path):
    """mid_scatter past the fused commit's buffer DONATION is the one
    window in-process recovery cannot heal (the old buffers are gone,
    the new state never parked) — the durable WAL is the only cover:
    a FRESH handle replays the decided record and serves the commit."""
    from repro.api.mvhandle import MVStoreHandle
    from repro.reliability.wal import (WriteAheadLog, attach_wal,
                                       recover_from_wal)
    h = MVStoreHandle(n_threads=2, versioned="all", start_bg=False)
    h.alloc(32, 0)
    attach_wal(h, WriteAheadLog(str(tmp_path)))

    def w0(tx):
        tx.write_bulk(np.arange(32), list(range(32)))
    run(h, w0, tid=0)
    FP.install(FP.FaultSchedule([FP.Fault("mid_scatter", 1, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        def w1(tx):
            tx.write_bulk(np.arange(32), [v + 100 for v in range(32)])
        run(h, w1, tid=1)
    FP.uninstall()
    FP.reset_thread()
    h.wal.close()
    h.stop()
    h2 = MVStoreHandle(n_threads=2, versioned="all", start_bg=False)
    h2.alloc(32, 0)
    rep = recover_from_wal(str(tmp_path), h2)
    assert rep.wal_records_replayed == 2
    vals, ok = h2.snapshot_bulk(np.arange(32))
    assert ok and list(np.asarray(vals)) == [v + 100 for v in range(32)]
    assert check_store_invariants(h2) == []
    h2.stop()


# ---------------------------------------------------------------------------
# durable WAL x crash matrix: restart-grade recovery (fresh target)
# ---------------------------------------------------------------------------


def test_crash_wal_group_batch_two_dead_survive_restart(tmp_path):
    """Two members dead in the SAME group-commit batch, process image
    lost: the group shares ONE fsync'd DECIDE frame, so the whole batch
    replays all-or-nothing into the fresh engine."""
    from repro.reliability.wal import (WriteAheadLog, attach_wal,
                                       recover_from_wal)
    tm = WORD_BACKENDS["tl2"](4)
    n_members = 3
    tm.alloc(n_members * N, 0)
    attach_wal(tm, WriteAheadLog(str(tmp_path)))
    batcher = CommitBatcher(tm)
    for t in range(n_members):
        tx = tm.begin(t)
        tx.write_bulk(np.arange(t * N, (t + 1) * N),
                      [t * 10000 + i for i in range(N)])
        batcher.add(tx)
    FP.install(FP.FaultSchedule([FP.Fault("mid_scatter", 1, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        batcher.commit_all()
    FP.uninstall()
    FP.reset_thread()
    tm.wal.close()
    tm2 = WORD_BACKENDS["tl2"](4)
    tm2.alloc(n_members * N, 0)
    rep = recover_from_wal(str(tmp_path), tm2)
    assert rep.wal_records_replayed == n_members
    assert sorted(set(rep.rolled_forward)) == [0, 1, 2]
    assert check_engine_invariants(tm2) == []
    got = [tm2.peek(i) for i in range(n_members * N)]
    assert got == [t * 10000 + i for t in range(n_members)
                   for i in range(N)]


def test_crash_wal_shardstore_epoch_mid_publish_survives_restart(tmp_path):
    """Crash BETWEEN the two shard-local publishes of a cross-shard
    epoch, process image lost: the epoch's members share one group
    DECIDE, so the fresh store replays ALL of it — never a torn cut."""
    from repro.core.shardstore import ShardStoreHandle
    from repro.reliability.recovery import check_shardstore_invariants
    from repro.reliability.wal import (WriteAheadLog, attach_wal,
                                       recover_from_wal)
    st = ShardStoreHandle(2, n_shards=2, span=4, start_bg=False)
    st.alloc(32, 0)
    attach_wal(st, WriteAheadLog(str(tmp_path)))

    def w0(tx):
        tx.write_bulk(np.arange(32), list(range(32)))
    run(st, w0, tid=0)
    FP.install(FP.FaultSchedule([FP.Fault("pre_scatter", 2, "kill")]))
    with pytest.raises(FP.SimulatedCrash):
        def w1(tx):
            tx.write_bulk(np.arange(32), [v + 100 for v in range(32)])
        run(st, w1, tid=1)
    FP.uninstall()
    FP.reset_thread()
    st.wal.close()
    st.stop()
    st2 = ShardStoreHandle(2, n_shards=2, span=4, start_bg=False)
    st2.alloc(32, 0)
    recover_from_wal(str(tmp_path), st2)
    vals, ok = st2.snapshot_bulk(np.arange(32))
    assert ok
    got = list(np.asarray(vals))
    # ATOMIC across the restart: all-old or all-new, never shard 0 new
    # with shard 1 old — and the fsync'd decide means all-new here
    assert got == [v + 100 for v in range(32)]
    assert check_shardstore_invariants(st2) == []
    st2.stop()


@pytest.mark.parametrize("cut", [1, 24, 200])
def test_crash_wal_torn_tail_truncation_recovers_prefix(cut, tmp_path):
    """SIGKILL can tear the last write() at any byte: whatever the cut,
    the scan stops at the tear and replay yields a consistent committed
    prefix — never a misparse, never a half-applied record."""
    from repro.reliability.wal import (WriteAheadLog, attach_wal,
                                       recover_from_wal, scan_dir)
    tm = WORD_BACKENDS["tl2"](2)
    tm.alloc(N, 0)
    attach_wal(tm, WriteAheadLog(str(tmp_path)))
    _committed_write(tm, 0)

    def w1(tx):
        tx.write_bulk(np.arange(N), [v + 1000 for v in range(N)])
    run(tm, w1, tid=1)
    seg = tm.wal._f.name
    tm.wal.close()
    size = os.path.getsize(seg)
    with open(seg, "r+b") as f:
        f.truncate(size - cut)
    recs, torn, _ = scan_dir(str(tmp_path))
    tm2 = WORD_BACKENDS["tl2"](2)
    tm2.alloc(N, 0)
    rep = recover_from_wal(str(tmp_path), tm2)
    assert check_engine_invariants(tm2) == []
    # the recovered heap IS the replay of the surviving decided records
    ref = np.zeros(N, np.int64)
    for r in recs:
        if r.decided:
            ref[np.asarray(r.addrs)] = np.asarray(r.values)
    assert [tm2.peek(i) for i in range(N)] == ref.tolist()
    # prefix-consistency: the heap is one of the three commit states,
    # never an interleave of txn 0 and txn 1 values
    got = [tm2.peek(i) for i in range(N)]
    assert got in ([0] * N, list(range(N)),
                   [v + 1000 for v in range(N)])
    assert rep.wal_records_replayed == sum(r.decided for r in recs)


# ---------------------------------------------------------------------------
# quick subset: 6 representative cases CI smoke runs via
#   -k "crash and quick"
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,point", [
    ("multiverse", "pre_release"),
    ("multiverse", "pre_claim"),
    ("tl2", "post_claim"),
    ("tl2", "pre_release"),
    ("dctl", "pre_scatter"),
])
def test_crash_quick_solo(backend, point):
    _run_solo_case(backend, point)


def test_crash_quick_mvstore():
    _run_mvstore_case("post_scatter")


def test_crash_quick_shardstore_epoch():
    # the sharpest epoch case: crash BETWEEN the two shard-local
    # publishes; recovery must roll the whole epoch forward atomically
    _run_shardstore_epoch_case("pre_scatter", 2, True)
