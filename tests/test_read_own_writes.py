"""Read-own-writes conformance: `tx.read` after `tx.write` in the SAME
transaction must return the pending value, on every backend and through
every write shape (fresh write, overwrite, write-after-read, txn-alloc'd
cells) — the opacity clause the engine migration must not disturb.
"""
import pytest

from _backends import ALL_BACKENDS, WORD_BACKENDS, make_test_tm as _make
from repro.api import atomic, run


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_read_sees_own_pending_write(backend):
    tm = _make(backend)
    a = tm.alloc(2, 10)

    def txn(tx):
        tx.write(a, 77)
        first = tx.read(a)               # pending value, not the heap's
        tx.write(a, first + 1)
        second = tx.read(a)
        untouched = tx.read(a + 1)       # reads of unwritten cells intact
        return first, second, untouched

    out = run(tm, txn, tid=0)
    assert out == (77, 78, 10)
    assert run(tm, lambda tx: tx.read(a), tid=0) == 78
    tm.stop()


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_read_after_write_after_read(backend):
    """The read-modify-write shape: read, write, re-read must round-trip
    through the pending write (TL2/NOrec redo logs, DCTL/Multiverse
    in-place undo logs — one contract)."""
    tm = _make(backend)
    a = tm.alloc(1, 5)

    @atomic(tm)
    def bump(tx):
        before = tx.read(a)
        tx.write(a, before + 100)
        after = tx.read(a)
        assert after == before + 100, (before, after)
        return after

    assert bump() == 105
    assert bump() == 205
    tm.stop()


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_read_own_write_to_txn_allocated_cell(backend):
    tm = _make(backend)
    tm.alloc(1, 0)                       # burn address 0

    def txn(tx):
        node = tx.alloc(3, 0)
        tx.write(node + 1, 42)
        return tx.read(node), tx.read(node + 1)

    assert run(tm, txn, tid=0) == (0, 42)
    tm.stop()


@pytest.mark.parametrize("backend", WORD_BACKENDS)
def test_own_writes_not_visible_to_other_threads_before_commit(backend):
    """The dual: pending writes are NOT read-own-writes for anyone else.
    Buffered backends keep them private; encounter-time backends hold the
    lock, so a reader validates-and-aborts rather than seeing them mixed
    with pre-write state (it never returns a committed-looking 99)."""
    from repro.api import AbortTx
    tm = _make(backend)
    a = tm.alloc(1, 1)
    run(tm, lambda tx: tx.write(a, 1), tid=0)    # warm the clock
    for _ in range(30):                          # deferred clocks can abort
        tx = tm.begin(0)                         # the first write attempt
        try:
            tx.write(a, 99)
            break
        except AbortTx:
            continue
    else:
        raise RuntimeError("could not acquire the write lock")
    try:
        for _ in range(5):
            try:
                got = run(tm, lambda t: t.read(a), tid=1, max_retries=1)
                assert got == 1, got             # buffered: old value only
            except Exception:                    # noqa: BLE001
                pass                             # locked: abort is correct
    finally:
        tm.abort(tx)
    assert run(tm, lambda t: t.read(a), tid=1) == 1
    tm.stop()
