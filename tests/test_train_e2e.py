"""End-to-end integration: real training runs with the full stack
(MVStore + controller + checkpointing + data pipeline) on CPU."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import MVStoreConfig, ShapeConfig, smoke_config
from repro.launch.train import Trainer


def _run(trainer, steps):
    losses = []
    state = trainer.state
    for s in range(steps):
        state, metrics = trainer.train_step(state, trainer.batch_at(s))
        losses.append(float(metrics["loss"]))
    trainer.state = state
    return losses


def test_loss_decreases_dense():
    cfg = smoke_config("qwen2.5-3b")
    shape = ShapeConfig("t", 32, 4, "train")
    from repro.optim import adamw
    tr = Trainer(cfg, shape,
                 opt_cfg=adamw.AdamWConfig(lr=3e-3, warmup_steps=5,
                                           total_steps=1000))
    losses = _run(tr, 40)
    tr.controller.stop()
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.1, (first, last)


def test_mode_u_training_matches_mode_q_numerically():
    """The versioned commit must not change training math: Mode-Q and
    Mode-U runs from the same seed produce identical losses."""
    cfg = smoke_config("minitron-4b")
    shape = ShapeConfig("t", 32, 2, "train")
    lq = _run(Trainer(cfg, shape, mvcfg=MVStoreConfig(mode="Q"),
                      seed=3), 6)
    lu = _run(Trainer(cfg, shape, mvcfg=MVStoreConfig(mode="U"),
                      seed=3), 6)
    np.testing.assert_allclose(lq, lu, rtol=1e-5, atol=1e-5)


def test_snapshot_serving_during_training():
    """The paper's headline scenario at MVStore level: a reader obtains a
    consistent parameter snapshot while training commits keep landing."""
    from repro.core import mvstore
    cfg = smoke_config("qwen2.5-3b")
    shape = ShapeConfig("t", 32, 2, "train")
    tr = Trainer(cfg, shape, mvcfg=MVStoreConfig(mode="U"))
    state = tr.state
    views = []
    for s in range(6):
        state, _ = tr.train_step(state, tr.batch_at(s))
        rc = int(state.mv.clock) - 1      # snapshot one step behind
        view, ok = mvstore.mv_snapshot(state.mv, rc)
        if s >= 2:
            assert bool(ok)               # ring keeps the previous version
            views.append(jax.tree.leaves(view)[0])
    tr.controller.stop()
    # versions differ step to step (training is actually moving)
    assert any(not np.array_equal(np.asarray(views[i]),
                                  np.asarray(views[i + 1]))
               for i in range(len(views) - 1))


def test_fused_commit_matches_unfused():
    """Beyond-paper fused_adamw kernel path == adamw.apply + mv_commit."""
    cfg = smoke_config("qwen2.5-3b")
    shape = ShapeConfig("t", 32, 2, "train")
    base = _run(Trainer(cfg, shape, mvcfg=MVStoreConfig(mode="U"),
                        seed=5), 4)
    fused = _run(Trainer(cfg, shape,
                         mvcfg=MVStoreConfig(mode="U", fused_commit=True),
                         seed=5), 4)
    np.testing.assert_allclose(base, fused, rtol=2e-3, atol=2e-3)


def test_serve_generates_tokens():
    from repro.launch.serve import Server
    cfg = smoke_config("deepseek-7b")
    srv = Server(cfg, batch=2, prompt_len=16, max_len=24)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(2, 16), dtype=np.int32)
    out = srv.serve_batch(prompts, max_new=8)
    assert out.shape == (2, 8)
    assert out.dtype == np.int32
    assert (out >= 0).all() and (out < cfg.padded_vocab()).all()
