"""MVStore semantics: commits, snapshot reads, modes, controller cycle."""
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import MVStoreConfig
from repro.configs.paper_stm import MultiverseParams
from repro.core import modes as M
from repro.core import mvcontroller, mvstore


def params_tree(scale=1.0):
    return {"a": jnp.full((4, 4), scale, jnp.float32),
            "b": {"w": jnp.full((8,), 2 * scale, jnp.float32)}}


def test_mode_q_commit_is_in_place_no_rings():
    cfg = MVStoreConfig(ring_slots=2, mode="Q")
    st = mvstore.mv_init(params_tree(), cfg, versioned="none")
    st2 = mvstore.mv_commit(st, params_tree(2.0), local_mode="Q", cfg=cfg)
    assert int(st2.clock) == 1 and not st2.ring
    view, ok = mvstore.mv_snapshot(st2, read_clock=1)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(view["a"]), 2.0)


def test_mode_q_reader_aborts_when_clock_advances():
    cfg = MVStoreConfig(ring_slots=2, mode="Q")
    st = mvstore.mv_init(params_tree(), cfg, versioned="none")
    st = mvstore.mv_commit(st, params_tree(2.0), local_mode="Q", cfg=cfg)
    # reader began before the commit (read clock 0) -> must abort
    _, ok = mvstore.mv_snapshot(st, read_clock=0)
    assert not bool(ok)


def test_mode_u_commit_keeps_old_version_readable():
    cfg = MVStoreConfig(ring_slots=2, mode="U")
    st = mvstore.mv_init(params_tree(1.0), cfg, versioned="all")
    st = mvstore.mv_commit(st, params_tree(2.0), local_mode="U", cfg=cfg)
    st = mvstore.mv_commit(st, params_tree(3.0), local_mode="U", cfg=cfg)
    # read at clock 1 -> the 2.0 version (ring holds last 2 versions)
    view, ok = mvstore.mv_snapshot(st, read_clock=1)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(view["a"]), 2.0)
    view, ok = mvstore.mv_snapshot(st, read_clock=2)
    np.testing.assert_array_equal(np.asarray(view["a"]), 3.0)


def test_ring_overflow_aborts_reader():
    cfg = MVStoreConfig(ring_slots=2, mode="U")
    st = mvstore.mv_init(params_tree(), cfg, versioned="all")
    for i in range(4):
        st = mvstore.mv_commit(st, params_tree(float(i)), local_mode="U",
                               cfg=cfg)
    # clock=4; ring holds versions at clocks 3 and 4; reading at 1 fails
    _, ok = mvstore.mv_snapshot(st, read_clock=1)
    assert not bool(ok)
    _, ok = mvstore.mv_snapshot(st, read_clock=3)
    assert bool(ok)


def test_mode_u_commit_requires_versioned_blocks():
    cfg = MVStoreConfig(ring_slots=2, mode="U")
    st = mvstore.mv_init(params_tree(), cfg, versioned="none")
    with pytest.raises(ValueError):
        mvstore.mv_commit(st, params_tree(2.0), local_mode="U", cfg=cfg)


def test_partial_versioning_mode_q():
    """Word-granularity insight at block level: only requested blocks get
    rings; snapshot mixes ring reads and validated live reads."""
    cfg = MVStoreConfig(ring_slots=2, mode="Q")
    st = mvstore.mv_init(params_tree(), cfg, versioned="none")
    paths = [p for p in mvstore.block_paths(st.live) if "a" in p]
    st = mvstore.version_blocks(st, set(paths), cfg)
    assert mvstore.versioned_paths(st) == frozenset(paths)
    st = mvstore.mv_commit(st, params_tree(5.0), local_mode="Q", cfg=cfg)
    # reading at clock 0: 'a' resolves via ring (old version), but the
    # unversioned 'b' fails validation -> reader aborts (paper Mode Q)
    _, ok = mvstore.mv_snapshot(st, read_clock=0)
    assert not bool(ok)
    view, ok = mvstore.mv_snapshot(st, read_clock=1)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(view["a"]), 5.0)


def test_unversion_blocks_drops_rings():
    cfg = MVStoreConfig(ring_slots=2, mode="U")
    st = mvstore.mv_init(params_tree(), cfg, versioned="all")
    assert mvstore.ring_bytes(st) > 0
    st = mvstore.unversion_blocks(st, set(mvstore.block_paths(st.live)))
    assert mvstore.ring_bytes(st) == 0


def test_snapshot_pallas_path_matches_xla():
    cfg = MVStoreConfig(ring_slots=4, mode="U")
    st = mvstore.mv_init(params_tree(), cfg, versioned="all")
    for i in range(3):
        st = mvstore.mv_commit(st, params_tree(float(i)), local_mode="U",
                               cfg=cfg)
    v1, ok1 = mvstore.mv_snapshot(st, read_clock=2, impl="xla")
    v2, ok2 = mvstore.mv_snapshot(st, read_clock=2, impl="pallas")
    assert bool(ok1) == bool(ok2)
    for a, b in zip(jax.tree.leaves(v1), jax.tree.leaves(v2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_controller_full_mode_cycle():
    """Reader aborts CAS the mode to QtoU; the controller walks
    QtoU->U->UtoQ->Q as participants catch up and stickies clear.

    Driven SYNCHRONOUSLY (``start_bg=False`` + ``step_once``): each
    transition depends only on announcement state, so the test asserts
    the walk deterministically instead of sleeping until a background
    poller observes it."""
    params = MultiverseParams(k1=1, k2=1, k3=1, s=1)
    ctl = mvcontroller.MVController(params=params,
                                    mvcfg=MVStoreConfig(ring_slots=2),
                                    start_bg=False)
    cfg = ctl.mvcfg
    st = mvstore.mv_init(params_tree(), cfg, versioned="none")
    reader = ctl.reader()
    st = ctl.trainer_tick(st)

    # reader aborts repeatedly -> versioned -> CAS to QtoU
    for _ in range(4):
        reader.begin(int(st.clock))
        st = mvstore.mv_commit(st, params_tree(2.0),
                               local_mode=ctl.current_local_mode(),
                               cfg=cfg)
        st = ctl.trainer_tick(st)
        _, ok = mvstore.mv_snapshot(st, read_clock=int(st.clock) - 1)
        reader.on_abort(2)
    assert ctl.mode != M.MODE_Q

    # trainer keeps ticking; controller must reach Mode U
    for _ in range(20):
        if ctl.mode == M.MODE_U:
            break
        st = ctl.trainer_tick(st)
        st = mvstore.mv_commit(st, params_tree(3.0),
                               local_mode=ctl.current_local_mode(),
                               cfg=cfg)
        reader.begin(int(st.clock))
        ctl.step_once()
    assert ctl.mode == M.MODE_U
    assert len(st.ring) == len(mvstore.block_paths(st.live))

    # reader commits small txns -> sticky clears -> back to Q eventually
    for _ in range(20):
        if ctl.mode == M.MODE_Q:
            break
        reader.begin(int(st.clock))
        view, ok = mvstore.mv_snapshot(st, read_clock=int(st.clock),
                                       assume_versioned=True)
        reader.on_commit(1, int(st.clock))
        st = ctl.trainer_tick(st)
        ctl.step_once()
    assert ctl.mode == M.MODE_Q
    ctl.stop()


def test_controller_stale_unversioning():
    cfg = MVStoreConfig(ring_slots=2)
    st = mvstore.mv_init(params_tree(), cfg, versioned="all")
    for i in range(3):
        st = mvstore.mv_commit(st, params_tree(float(i)), local_mode="U",
                               cfg=cfg)
    drop = mvcontroller.apply_stale_unversioning(
        st, {"__stale_older_than:0.5"})
    # newest ring ts == clock -> nothing stale
    assert drop == frozenset()
    # pretend the clock raced ahead
    st = st._replace(clock=jnp.asarray(100, jnp.int32))
    drop = mvcontroller.apply_stale_unversioning(
        st, {"__stale_older_than:50"})
    assert drop == frozenset(st.ring)
