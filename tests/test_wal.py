"""Durable WAL: frame format, torn tails, segments, and real-SIGKILL
whole-process recovery drills.

Two layers:

* ``test_wal_quick_*`` — fast, in-process: frame round-trips, torn-tail
  truncation, segment roll + lsn continuation, checkpoint reclaim,
  group-append fsync batching, and replay into a FRESH engine (the
  in-process stand-in for losing the process image).  CI smoke selects
  these with ``-k "wal and quick"``.
* ``test_wal_sigkill_*`` — the real thing: a subprocess commits a
  durable prefix, arms a ``die`` fault (actual ``SIGKILL`` to its own
  pid) inside the commit pipeline, and is reaped mid-instruction.  The
  parent asserts returncode ``-9``, restarts a fresh store, and
  ``recover_from_wal`` must rebuild the heap bit-identical to the
  committed-prefix reference derived from the scanned log.
"""
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api.substrate import run
from repro.core.baselines import TL2
from repro.core.stats_schema import normalize_stats
from repro.core.stm import Multiverse
from repro.reliability import faultpoints as FP
from repro.reliability.recovery import check_engine_invariants
from repro.reliability.wal import (WriteAheadLog, attach_wal,
                                   recover_from_wal, scan_dir)


@pytest.fixture(autouse=True)
def _no_leftover_schedule():
    yield
    FP.uninstall()
    FP.reset_thread()


# ---------------------------------------------------------------------------
# quick: frame format and file lifecycle
# ---------------------------------------------------------------------------


def test_wal_quick_prepare_decide_complete_roundtrip(tmp_path):
    with WriteAheadLog(str(tmp_path)) as wal:
        l0 = wal.append_prepare(3, [0, 1, 2], [10, 11, 12],
                                clocks=(7,), epoch=-1, shard=-1)
        l1 = wal.append_prepare(4, [5], [50], clocks=(8,))
        wal.append_decide(l0)
        wal.append_complete(l0)
    recs, torn, base = scan_dir(str(tmp_path))
    assert torn == 0 and base is None
    assert [r.lsn for r in recs] == [l0, l1]
    r0, r1 = recs
    assert (r0.tid, r0.decided, r0.completed) == (3, True, True)
    assert r0.clocks == (7,)
    assert r0.addrs.tolist() == [0, 1, 2]
    assert r0.values.tolist() == [10, 11, 12]
    # prepared-but-undecided: the frame survives but replay drops it
    assert (r1.tid, r1.decided, r1.completed) == (4, False, False)


def test_wal_quick_torn_tail_is_detected_and_dropped(tmp_path):
    with WriteAheadLog(str(tmp_path)) as wal:
        l0 = wal.append_prepare(0, [0], [1], clocks=(1,))
        wal.append_decide(l0)
        l1 = wal.append_prepare(1, list(range(8)), list(range(8)),
                                clocks=(2,))
        wal.append_decide(l1)
        seg = wal._f.name
    # tear the tail: the dying write() cut the last frame in half
    size = os.path.getsize(seg)
    with open(seg, "r+b") as f:
        f.truncate(size - 11)
    recs, torn, _ = scan_dir(str(tmp_path))
    assert torn > 0
    # the prefix before the tear is intact; the torn frame was l1's
    # DECIDE, so l1 reads back UNDECIDED — a torn commit record means
    # the commit never decided, exactly the fail-closed direction
    assert [r.lsn for r in recs] == [l0, l1]
    assert recs[0].decided and not recs[1].decided


def test_wal_quick_corrupt_frame_stops_scan_at_crc(tmp_path):
    with WriteAheadLog(str(tmp_path)) as wal:
        l0 = wal.append_prepare(0, [0], [1], clocks=(1,))
        wal.append_decide(l0)
        l1 = wal.append_prepare(1, [2], [3], clocks=(2,))
        wal.append_decide(l1)
        seg = wal._f.name
    data = bytearray(open(seg, "rb").read())
    # flip one payload byte in the MIDDLE record: CRC must catch it and
    # the scan must stop there (everything after is suspect)
    data[len(data) // 2] ^= 0xFF
    open(seg, "wb").write(bytes(data))
    recs, torn, _ = scan_dir(str(tmp_path))
    assert torn > 0
    assert len(recs) < 2 or not all(r.decided for r in recs)


def test_wal_quick_segment_roll_and_reopen_continues_lsn(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_bytes=256)
    lsns = []
    for i in range(10):
        lsn = wal.append_prepare(i, [i], [i * 10], clocks=(i,))
        wal.append_decide(lsn)
        lsns.append(lsn)
    n_segs = len(wal._segments())
    assert n_segs > 1                  # 256B forces rolls between frames
    wal.close()
    # reopen: lsn sequence continues, appends land in a FRESH segment
    wal2 = WriteAheadLog(str(tmp_path), segment_bytes=256)
    lsn = wal2.append_prepare(99, [0], [0], clocks=(99,))
    wal2.append_decide(lsn)
    assert lsn == lsns[-1] + 1
    assert len(wal2._segments()) == n_segs + 1
    wal2.close()
    recs, torn, _ = scan_dir(str(tmp_path))
    assert torn == 0
    assert [r.lsn for r in recs] == lsns + [lsn]
    assert all(r.decided for r in recs)


def test_wal_quick_checkpoint_reclaims_segments(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_bytes=256)
    for i in range(8):
        wal.append_decide(wal.append_prepare(i, [i], [i], clocks=(i,)))
    heap = np.arange(8, dtype=np.int64)
    floor = wal.checkpoint(heap, clock=8)
    assert floor == wal._next_lsn
    # everything below the floor is in the base image: old segments gone
    assert len(wal._segments()) == 1
    lsn = wal.append_prepare(9, [3], [333], clocks=(9,))
    wal.append_decide(lsn)
    wal.close()
    recs, torn, base = scan_dir(str(tmp_path))
    assert torn == 0
    assert base is not None
    b_floor, b_heap, b_clock = base
    assert b_floor == floor and b_clock == 8
    assert b_heap.tolist() == heap.tolist()
    # only the post-checkpoint record still needs replaying
    assert [r.lsn for r in recs] == [lsn]


def test_wal_quick_group_append_is_one_fsync(tmp_path):
    wal = WriteAheadLog(str(tmp_path))
    recs = [(t, [t * 4 + i for i in range(4)],
             [t * 100 + i for i in range(4)], (5,), -1, -1)
            for t in range(3)]
    f0 = wal.counters["fsyncs"]
    lsns = wal.append_prepare_group(recs)
    assert wal.counters["fsyncs"] == f0          # prepares are buffered
    wal.append_decide_group(lsns)
    assert wal.counters["fsyncs"] == f0 + 1      # ONE fsync per group
    assert wal.counters["decides"] == 3
    wal.close()
    scanned, _, _ = scan_dir(str(tmp_path))
    assert [r.tid for r in scanned] == [0, 1, 2]
    assert all(r.decided for r in scanned)


def test_wal_rejects_non_numeric_heap_values(tmp_path):
    with WriteAheadLog(str(tmp_path)) as wal:
        with pytest.raises(TypeError, match="numeric heap"):
            wal.append_prepare(0, [0], [object()], clocks=(1,))


# ---------------------------------------------------------------------------
# quick: replay into a fresh engine (in-process process-loss stand-in)
# ---------------------------------------------------------------------------

N = 300          # >= BULK_MIN so the bulk scatter (and mid_scatter) runs


def test_wal_quick_replay_rebuilds_fresh_engine(tmp_path):
    tm = TL2(2)
    tm.alloc(N, 0)
    attach_wal(tm, WriteAheadLog(str(tmp_path)))

    def w0(tx):
        tx.write_bulk(np.arange(N), list(range(N)))
    run(tm, w0, tid=0)

    def w1(tx):
        tx.write_bulk(np.arange(8), [v + 1000 for v in range(8)])
    run(tm, w1, tid=1)
    tm.wal.close()
    # the process image is gone: all that survives is the directory
    tm2 = TL2(2)
    tm2.alloc(N, 0)
    rep = recover_from_wal(str(tmp_path), tm2)
    assert rep.wal_records_replayed == 2
    exp = [v + 1000 for v in range(8)] + list(range(8, N))
    assert [tm2.peek(i) for i in range(N)] == exp
    assert check_engine_invariants(tm2) == []
    # the typed counters surface through the shared stats schema
    stats = normalize_stats(tm2.stats())
    assert stats["wal_records_replayed"] == 2
    assert stats["rolled_back"] == 0


def test_wal_quick_partial_lane_crash_heals_by_whole_record_redo(tmp_path):
    """mid_scatter crash: the dying process's heap is TORN (half the
    lanes new, half old), the WAL already holds the fsync'd DECIDE, and
    replay into a fresh engine redoes the WHOLE record idempotently."""
    tm = TL2(2)
    tm.alloc(N, 0)
    attach_wal(tm, WriteAheadLog(str(tmp_path)))

    def w0(tx):
        tx.write_bulk(np.arange(N), list(range(N)))
    run(tm, w0, tid=0)
    FP.install(FP.FaultSchedule([FP.Fault("mid_scatter", 1, "crash")]))
    with pytest.raises(FP.ProcessCrashed):
        def w1(tx):
            tx.write_bulk(np.arange(N), [v + 1000 for v in range(N)])
        run(tm, w1, tid=1)
    FP.uninstall()
    # the crash image really is partial-lane: some lanes new, some old
    torn = [tm.peek(i) for i in range(N)]
    assert any(v >= 1000 for v in torn) and any(v < 1000 for v in torn)
    tm.wal.close()
    tm2 = TL2(2)
    tm2.alloc(N, 0)
    rep = recover_from_wal(str(tmp_path), tm2)
    assert 1 in rep.rolled_forward         # decided, never COMPLETEd
    assert [tm2.peek(i) for i in range(N)] == [v + 1000 for v in range(N)]
    assert check_engine_invariants(tm2) == []


def test_wal_quick_undecided_prepare_rolls_back(tmp_path):
    """A crash BEFORE the decide: the prepare never replays — rollback
    is simply not replaying, and the report says so."""
    tm = TL2(2)
    tm.alloc(N, 0)
    attach_wal(tm, WriteAheadLog(str(tmp_path)))

    def w0(tx):
        tx.write_bulk(np.arange(N), list(range(N)))
    run(tm, w0, tid=0)
    FP.install(FP.FaultSchedule([FP.Fault("post_claim", 1, "crash")]))
    with pytest.raises(FP.ProcessCrashed):
        def w1(tx):
            tx.write_bulk(np.arange(N), [v + 1000 for v in range(N)])
        run(tm, w1, tid=1)
    FP.uninstall()
    tm.wal.flush()
    tm.wal.close()
    tm2 = TL2(2)
    tm2.alloc(N, 0)
    rep = recover_from_wal(str(tmp_path), tm2)
    assert 1 in rep.rolled_back and 1 not in rep.rolled_forward
    assert [tm2.peek(i) for i in range(N)] == list(range(N))
    assert check_engine_invariants(tm2) == []


def test_wal_mvhandle_replay_redrives_publish(tmp_path):
    from repro.api.mvhandle import MVStoreHandle
    h = MVStoreHandle(n_threads=2, versioned="all", start_bg=False)
    h.alloc(32, 0)
    attach_wal(h, WriteAheadLog(str(tmp_path)))

    def w0(tx):
        tx.write_bulk(np.arange(32), [v + 5 for v in range(32)])
    run(h, w0, tid=0)
    h.wal.close()
    h.stop()
    h2 = MVStoreHandle(n_threads=2, versioned="all", start_bg=False)
    h2.alloc(32, 0)
    rep = recover_from_wal(str(tmp_path), h2)
    assert rep.wal_records_replayed == 1
    vals, ok = h2.snapshot_bulk(np.arange(32))
    assert ok and list(np.asarray(vals)) == [v + 5 for v in range(32)]
    assert h2.clock >= 1
    assert normalize_stats(h2.stats())["wal_records_replayed"] == 1
    h2.stop()


def test_wal_shardstore_epoch_survives_restart_atomically(tmp_path):
    """Cross-shard epoch: one prepare per write shard + one shared group
    DECIDE — after a restart the epoch replays all-or-nothing."""
    from repro.core.shardstore import ShardStoreHandle
    from repro.reliability.recovery import check_shardstore_invariants
    st = ShardStoreHandle(2, n_shards=2, span=4, start_bg=False)
    st.alloc(32, 0)
    attach_wal(st, WriteAheadLog(str(tmp_path)))

    def w0(tx):
        tx.write_bulk(np.arange(32), [v + 100 for v in range(32)])
    run(st, w0, tid=0)                 # spans both shards: epoch commit
    st.wal.close()
    st.stop()
    recs, _, _ = scan_dir(str(tmp_path))
    epochs = {r.epoch for r in recs if r.epoch >= 0}
    shards = {r.shard for r in recs if r.epoch >= 0}
    assert len(epochs) == 1 and shards == {0, 1}
    st2 = ShardStoreHandle(2, n_shards=2, span=4, start_bg=False)
    st2.alloc(32, 0)
    rep = recover_from_wal(str(tmp_path), st2)
    assert rep.wal_records_replayed == len(recs)
    vals, ok = st2.snapshot_bulk(np.arange(32))
    assert ok and list(np.asarray(vals)) == [v + 100 for v in range(32)]
    assert check_shardstore_invariants(st2) == []
    st2.stop()


def test_wal_group_commit_batch_journals_one_decide(tmp_path):
    from repro.core.engine.groupcommit import CommitBatcher
    tm = TL2(4)
    tm.alloc(3 * N, 0)
    attach_wal(tm, WriteAheadLog(str(tmp_path)))
    batcher = CommitBatcher(tm)
    for t in range(3):
        tx = tm.begin(t)
        tx.write_bulk(np.arange(t * N, (t + 1) * N),
                      [t * 10000 + i for i in range(N)])
        batcher.add(tx)
    f0 = tm.wal.counters["fsyncs"]
    batcher.commit_all()
    assert tm.wal.counters["fsyncs"] == f0 + 1     # group decide batches
    tm.wal.close()
    tm2 = TL2(4)
    tm2.alloc(3 * N, 0)
    rep = recover_from_wal(str(tmp_path), tm2)
    assert rep.wal_records_replayed == 3
    got = [tm2.peek(i) for i in range(3 * N)]
    exp = [t * 10000 + i for t in range(3) for i in range(N)]
    assert got == exp


# ---------------------------------------------------------------------------
# subprocess SIGKILL drills: the process image is REALLY gone
# ---------------------------------------------------------------------------

_WORKER = textwrap.dedent("""
    import sys
    import numpy as np
    from repro.api.substrate import run
    from repro.core.baselines import TL2
    from repro.core.stm import Multiverse
    from repro.reliability import faultpoints as FP
    from repro.reliability.wal import WriteAheadLog, attach_wal

    backend, point, wal_dir, n = (sys.argv[1], sys.argv[2], sys.argv[3],
                                  int(sys.argv[4]))
    tm = (Multiverse(2, start_bg=False) if backend == "multiverse"
          else TL2(2))
    tm.alloc(n, 0)
    attach_wal(tm, WriteAheadLog(wal_dir))

    def w0(tx):
        tx.write_bulk(np.arange(n), list(range(n)))
    run(tm, w0, tid=0)                 # the committed prefix

    FP.install(FP.FaultSchedule([FP.Fault(point, 1, "die")]))

    def w1(tx):
        tx.write_bulk(np.arange(n), [v + 1000 for v in range(n)])
    run(tm, w1, tid=1)                 # SIGKILLs itself mid-commit
    sys.exit(3)                        # reached only if the fault missed
""")


def _sigkill_drill(tmp_path, backend, point, n):
    """Run the worker, assert it was reaped by SIGKILL, and return the
    recovered fresh store plus the recovery report and scanned records."""
    script = tmp_path / "worker.py"
    script.write_text(_WORKER)
    wal_dir = str(tmp_path / "wal")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.getcwd(), "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(script), backend, point, wal_dir, str(n)],
        env=env, capture_output=True, timeout=120)
    assert proc.returncode == -signal.SIGKILL, (
        proc.returncode, proc.stdout, proc.stderr)
    recs, torn, base = scan_dir(wal_dir)
    # restart: a FRESH process image recovers from the directory alone
    tm2 = (Multiverse(2, start_bg=False) if backend == "multiverse"
           else TL2(2))
    tm2.alloc(n, 0)
    rep = recover_from_wal(wal_dir, tm2)
    # bit-identical to the committed-prefix reference: replay exactly
    # the decided records, in lsn order, onto a zeroed heap
    ref = np.zeros(n, np.int64)
    for r in recs:
        if r.decided:
            ref[r.addrs] = r.values
    got = np.array([tm2.peek(i) for i in range(n)])
    assert np.array_equal(got, ref), (got[:8], ref[:8])
    assert check_engine_invariants(tm2) == []
    return tm2, rep, recs


def test_wal_sigkill_pre_record_rolls_back(tmp_path):
    """SIGKILL before the commit record exists: the crashed txn's
    writes never became durable — the restart sees the prefix only."""
    tm2, rep, recs = _sigkill_drill(tmp_path, "tl2", "pre_claim", N)
    assert not any(r.decided for r in recs if r.tid == 1)
    assert 1 not in rep.rolled_forward
    assert [tm2.peek(i) for i in range(N)] == list(range(N))


def test_wal_sigkill_mid_scatter_partial_lane_rolls_forward(tmp_path):
    """SIGKILL INSIDE the bulk publish sweep (partial-lane completion):
    the fsync'd DECIDE landed before the first heap write, so the fresh
    process must roll the whole record forward idempotently."""
    tm2, rep, recs = _sigkill_drill(tmp_path, "tl2", "mid_scatter", N)
    assert any(r.decided and r.tid == 1 for r in recs)
    assert 1 in rep.rolled_forward       # decided, COMPLETE never landed
    assert [tm2.peek(i) for i in range(N)] == [v + 1000 for v in range(N)]


def test_wal_sigkill_pre_release_rolls_forward_encounter(tmp_path):
    """Encounter-time backend (Multiverse): prepare+decide collapse at
    the decide point; SIGKILL holding every write lock still leaves a
    durable record the restart honors."""
    tm2, rep, recs = _sigkill_drill(tmp_path, "multiverse", "pre_release",
                                    32)
    assert any(r.decided and r.tid == 1 for r in recs)
    assert 1 in rep.rolled_forward
    assert [tm2.peek(i) for i in range(32)] == [v + 1000 for v in range(32)]
