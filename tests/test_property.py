"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.configs.base import MVStoreConfig
from repro.core import mvstore
from repro.core.stm import Multiverse, run
from repro.kernels import ref
from repro.structs import ABTree

_settings = settings(max_examples=25, deadline=None,
                     suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# STM: sequential equivalence — any op sequence == dict semantics
# ---------------------------------------------------------------------------


@given(ops=st.lists(st.tuples(st.sampled_from(["ins", "del", "get"]),
                              st.integers(0, 63)), max_size=120))
@_settings
def test_stm_abtree_sequentially_consistent(ops):
    tm = Multiverse(1, start_bg=False)
    t = ABTree(tm, a=2, b=4)
    ref_map = {}
    for op, k in ops:
        if op == "ins":
            run(tm, lambda tx, k=k: t.insert(tx, k, k + 1), tid=0)
            ref_map[k] = k + 1
        elif op == "del":
            run(tm, lambda tx, k=k: t.delete(tx, k), tid=0)
            ref_map.pop(k, None)
        else:
            got = run(tm, lambda tx, k=k: t.search(tx, k), tid=0)
            assert got == ref_map.get(k)
    out = run(tm, lambda tx: t.range_query(tx, 0, 10 ** 6), tid=0)
    assert out == sorted(ref_map.items())


# ---------------------------------------------------------------------------
# STM: transactions are all-or-nothing under voluntary aborts
# ---------------------------------------------------------------------------


@given(writes=st.lists(st.tuples(st.integers(0, 15),
                                 st.integers(-100, 100)),
                       min_size=1, max_size=20),
       abort_after=st.integers(0, 19))
@_settings
def test_stm_atomicity_of_aborted_writes(writes, abort_after):
    from repro.core.stm import AbortTx
    tm = Multiverse(1, start_bg=False)
    base = tm.alloc(16, 0)

    def txn(tx):
        for i, (a, v) in enumerate(writes):
            if i == abort_after:
                raise AbortTx()
            tx.write(base + a, v)
        return True

    try:
        tx = tm.begin(0)
        txn(tx)
        tm._try_commit(tx._ctx)
        committed = True
    except AbortTx:
        tm._abort(tm.ctx(0)) if tm.ctx(0).active else None
        committed = False
    vals = [tm.peek(base + i) for i in range(16)]
    if not committed:
        assert vals == [0] * 16          # rollback left no trace
    else:
        expect = [0] * 16
        for a, v in writes:
            expect[a] = v
        assert vals == expect


# ---------------------------------------------------------------------------
# MVStore: snapshot reads are always some prefix-consistent committed state
# ---------------------------------------------------------------------------


@given(n_commits=st.integers(1, 8), ring=st.integers(2, 4),
       read_at=st.integers(0, 8))
@_settings
def test_mvstore_snapshot_reads_committed_prefix(n_commits, ring, read_at):
    cfg = MVStoreConfig(ring_slots=ring, mode="U")
    vals = {"w": jnp.zeros((4,), jnp.float32)}
    stt = mvstore.mv_init(vals, cfg, versioned="all")
    for i in range(1, n_commits + 1):
        stt = mvstore.mv_commit(
            stt, {"w": jnp.full((4,), float(i), jnp.float32)},
            local_mode="U", cfg=cfg)
    view, ok = mvstore.mv_snapshot(stt, read_clock=read_at)
    if bool(ok):
        got = float(np.asarray(view["w"])[0])
        # the newest commit <= read_at, within ring reach
        expect = min(read_at, n_commits)
        assert got == float(expect)
        assert n_commits - expect < ring    # within the ring window
    else:
        # aborts happen iff the wanted version fell out of the ring
        assert read_at < n_commits - (ring - 1) or read_at < 0


# ---------------------------------------------------------------------------
# Kernels: oracles on random shapes (tie the kernel sweep together)
# ---------------------------------------------------------------------------


@given(r=st.integers(2, 6), n=st.integers(1, 64),
       clock=st.integers(-1, 12), seed=st.integers(0, 99))
@_settings
def test_snapshot_select_always_newest_leq_clock(r, n, clock, seed):
    rng = np.random.RandomState(seed)
    ring = jnp.asarray(rng.randn(r, n).astype(np.float32))
    ts = jnp.asarray(rng.choice(range(-1, 10), size=r).astype(np.int32))
    val, ok = ref.snapshot_select_ref(ring, ts, clock)
    tsn = np.asarray(ts)
    valid = [t for t in tsn if t != -1 and t <= clock]
    assert bool(ok) == (len(valid) > 0)
    if valid:
        best = max(valid)
        idx = int(np.argmax(np.where((tsn != -1) & (tsn <= clock), tsn,
                                     -1)))
        assert tsn[idx] == best
        np.testing.assert_array_equal(np.asarray(val),
                                      np.asarray(ring)[idx])


@given(s=st.sampled_from([32, 64, 128]), h=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_equals_sequential(s, h, seed):
    from repro.models.mamba import ssd_chunk_scan
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 5)
    B, P, N = 1, 8, 4
    xh = jax.random.normal(ks[0], (B, s, h, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, s, N)) * 0.5
    C_ = jax.random.normal(ks[4], (B, s, N)) * 0.5
    y, stt = ssd_chunk_scan(xh, dt, A, B_, C_, chunk=16)
    yr, str_ = ref.ssd_scan_ref(xh, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3,
                               atol=2e-3)


# ---------------------------------------------------------------------------
# Data pipeline: determinism + shard partition invariants
# ---------------------------------------------------------------------------


@given(step=st.integers(0, 1000), n_shards=st.sampled_from([1, 2, 4, 8]))
@_settings
def test_pipeline_shards_partition_global_batch(step, n_shards):
    from repro.data.pipeline import SyntheticLM
    src = SyntheticLM(vocab_size=101, seq_len=16, global_batch=8, seed=3)
    whole = src.global_batch_at(step)["tokens"]
    parts = [src.shard_batch(step, s, n_shards)["tokens"]
             for s in range(n_shards)]
    # deterministic: same call twice is identical
    np.testing.assert_array_equal(
        parts[0], src.shard_batch(step, 0, n_shards)["tokens"])
    # every shard has the right rows; shards are mutually independent
    assert all(p.shape == (8 // n_shards, 16) for p in parts)
    # labels are the next-token shift of tokens under the affine process
    b = src.shard_batch(step, 0, n_shards)
    assert ((b["labels"][:, :-1] == b["tokens"][:, 1:]).all())


# ---------------------------------------------------------------------------
# Reliability: any seeded FaultSchedule recovers to the committed prefix
# ---------------------------------------------------------------------------


_FAULT_POINTS = ("pre_claim", "post_claim", "pre_clock_tick",
                 "pre_scatter", "post_scatter", "pre_release")

_fault_st = st.builds(
    lambda p, n, a: (p, n, a),
    st.sampled_from(_FAULT_POINTS),
    st.integers(1, 3),
    st.sampled_from(["raise", "kill"]))


@given(backend=st.sampled_from(["multiverse", "tl2", "dctl"]),
       faults=st.lists(_fault_st, max_size=4, unique=True))
@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_random_fault_schedule_recovers_committed_prefix(backend, faults):
    """Random seeded FaultSchedule vs the fault-free reference: the
    recovered heap must equal the reference truncated at the last
    durable commit (every finished txn, plus a crashed one iff its
    commit record was written).  On failure hypothesis shrinks to a
    minimal failing schedule."""
    from repro.api.substrate import MaxRetriesExceeded, run as api_run
    from repro.core.baselines import DCTL, TL2
    from repro.core.stm import Multiverse
    from repro.reliability import faultpoints as FP
    from repro.reliability.recovery import (check_engine_invariants,
                                            recover_engine)
    mk = {"multiverse": lambda: Multiverse(1, start_bg=False),
          "tl2": lambda: TL2(1), "dctl": lambda: DCTL(1)}
    tm = mk[backend]()
    n = 300
    tm.alloc(n, 0)
    expected = [0] * n
    sched = FP.FaultSchedule(
        [FP.Fault(p, nth, a) for (p, nth, a) in faults])
    FP.install(sched)
    try:
        for g in range(1, 5):
            vals = [g * 1000 + i for i in range(n)]

            def w(tx, vals=vals):
                tx.write_bulk(np.arange(n), vals)
            try:
                api_run(tm, w, tid=0, max_retries=10)
                expected = vals
            except FP.FaultError:
                # injected recoverable error: rolled back — unless it hit
                # past the commit record, where the policy rolls forward
                # (the buffered scatter has no undo to take it back)
                if tm.ctx(0).publish_started:
                    expected = vals
            except MaxRetriesExceeded:
                pass                      # repeated injected aborts
            except FP.SimulatedCrash:
                d = tm.ctx(0)
                decided = d.active and d.publish_started
                recover_engine(tm, [0])
                if decided:
                    expected = vals       # rolled forward: commit landed
    finally:
        FP.uninstall()
        FP.reset_thread()
    violations = check_engine_invariants(tm, clock_at_least=0)
    assert violations == [], (violations, sched.fired)
    got = [tm.peek(i) for i in range(n)]
    assert got == expected, (sched.fired,)


@given(backend=st.sampled_from(["multiverse", "tl2"]),
       p0=st.sampled_from(_FAULT_POINTS),
       p1=st.sampled_from(_FAULT_POINTS),
       nth0=st.integers(1, 3), nth1=st.integers(1, 3),
       rounds=st.integers(2, 4))
@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_multi_fault_schedule_recovers_all_dead_in_one_sweep(
        backend, p0, p1, nth0, nth1, rounds):
    """Multi-worker crash schedules: tids 0 and 1 alternate commits on
    DISJOINT ranges while tid-filtered faults pick them off — possibly
    BOTH, possibly at a pre-record point for one and a post-record point
    for the other.  A single recover_engine sweep over every dead tid
    must land each region on its own committed-prefix value: finished
    commits, plus the crashed one iff its commit record was written."""
    from repro.api.substrate import MaxRetriesExceeded, run as api_run
    from repro.core.baselines import TL2
    from repro.core.stm import Multiverse
    from repro.reliability import faultpoints as FP
    from repro.reliability.recovery import (check_engine_invariants,
                                            recover_engine)
    tm = (Multiverse(2, start_bg=False) if backend == "multiverse"
          else TL2(2))
    n = 300
    tm.alloc(2 * n, 0)
    expected = {0: [0] * n, 1: [0] * n}
    pending = {}                       # tid -> values of the crashed txn
    dead = set()
    FP.install(FP.FaultSchedule([FP.Fault(p0, nth0, "kill", tid=0),
                                 FP.Fault(p1, nth1, "kill", tid=1)]))
    try:
        for g in range(1, rounds + 1):
            for tid in (0, 1):
                if tid in dead:
                    continue           # a dead worker stays dead
                lo = tid * n
                vals = [g * 1000 + tid * 100000 + i for i in range(n)]

                def w(tx, lo=lo, vals=vals):
                    tx.write_bulk(np.arange(lo, lo + n), vals)
                try:
                    api_run(tm, w, tid=tid, max_retries=10)
                    expected[tid] = vals
                except FP.FaultError:
                    if tm.ctx(tid).publish_started:
                        expected[tid] = vals
                except MaxRetriesExceeded:
                    pass
                except FP.SimulatedCrash:
                    dead.add(tid)
                    pending[tid] = vals
                    FP.reset_thread()  # next worker = its own thread
    finally:
        FP.uninstall()
        FP.reset_thread()
    if dead:
        decided = {t: tm.ctx(t).active and tm.ctx(t).publish_started
                   for t in dead}
        rep = recover_engine(tm, sorted(dead))   # ONE sweep, all corpses
        assert rep.dead_tids == sorted(dead)
        for t in sorted(dead):
            if decided[t]:
                expected[t] = pending[t]
    violations = check_engine_invariants(tm, clock_at_least=0)
    assert violations == [], violations
    for tid in (0, 1):
        got = [tm.peek(tid * n + i) for i in range(n)]
        assert got == expected[tid], (tid, sorted(dead))
