"""Substrate tests: checkpointing, fault tolerance, straggler, elastic,
optimizer, data pipeline determinism."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.snapshotter import (CheckpointManager,
                                          restore_checkpoint,
                                          save_checkpoint)
from repro.configs.base import MVStoreConfig
from repro.core import mvstore
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.runtime.elastic import rescale_plan
from repro.runtime.straggler import StragglerMonitor


def test_checkpoint_roundtrip(tmp_path):
    state = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
             "b": {"x": jnp.ones((2,), jnp.bfloat16)},
             "step": jnp.asarray(7, jnp.int32)}
    save_checkpoint(str(tmp_path), 7, state, extra={"note": "hi"})
    step, restored, extra = restore_checkpoint(str(tmp_path), state)
    assert step == 7 and extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    cfg = MVStoreConfig(ring_slots=2)
    st = mvstore.mv_init({"w": jnp.zeros((4,))}, cfg, versioned="none")
    for i in range(1, 5):
        st = mvstore.mv_commit(st, {"w": jnp.full((4,), float(i))},
                               local_mode="Q", cfg=cfg)
        assert mgr.submit(i, st, {"count": jnp.asarray(i)})
        mgr.wait_idle()
    mgr.close()
    kept = sorted(p for p in os.listdir(tmp_path)
                  if p.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]
    assert not mgr.errors


def test_checkpoint_submit_reports_queue_full(tmp_path, monkeypatch):
    """A full serializer queue is a TYPED outcome, not a silent drop: the
    caller sees QUEUE_FULL (falsy), the drop lands in stats(), and the
    reader heuristics record an abort — never a commit for a checkpoint
    that was thrown away."""
    from repro.checkpoint.snapshotter import SubmitOutcome

    # park the serializer so the maxsize-2 queue never drains
    monkeypatch.setattr(CheckpointManager, "_loop", lambda self: None)

    class _Reader:
        def __init__(self):
            self.commits, self.aborts = 0, 0

        def begin(self, clock):
            pass

        def on_commit(self, n, clock):
            self.commits += 1

        def on_abort(self, n):
            self.aborts += 1

    reader = _Reader()
    mgr = CheckpointManager(str(tmp_path), reader=reader)
    cfg = MVStoreConfig(ring_slots=2)
    st = mvstore.mv_init({"w": jnp.zeros((4,))}, cfg, versioned="none")
    outcomes = [mgr.submit(i, st, {"count": jnp.asarray(i)})
                for i in range(1, 4)]
    assert outcomes[:2] == [SubmitOutcome.SAVED, SubmitOutcome.SAVED]
    assert outcomes[2] is SubmitOutcome.QUEUE_FULL
    assert all(outcomes[:2]) and not outcomes[2]   # bool contract
    assert mgr.stats()["dropped"] == 1
    assert reader.commits == 2 and reader.aborts == 1


def test_checkpoint_snapshot_abort_on_stale_clock(tmp_path):
    """Checkpointer is a Mode-Q reader: a commit between clock capture and
    snapshot makes it retry, never write a torn view."""
    cfg = MVStoreConfig(ring_slots=2)
    st = mvstore.mv_init({"w": jnp.zeros((4,))}, cfg, versioned="none")
    st2 = mvstore.mv_commit(st, {"w": jnp.ones((4,))}, local_mode="Q",
                            cfg=cfg)
    # snapshot with a read clock older than the store's clock -> not ok
    _, ok = mvstore.mv_snapshot(st2, read_clock=0)
    assert not bool(ok)


def test_supervisor_restart_resumes_training(tmp_path):
    from repro.configs import ShapeConfig, smoke_config
    from repro.launch.train import Trainer
    from repro.runtime.fault_tolerance import FaultPlan, TrainSupervisor

    cfg = smoke_config("qwen2.5-3b")
    shape = ShapeConfig("t", 32, 2, "train")
    tr = Trainer(cfg, shape)
    sup = TrainSupervisor(ckpt_dir=str(tmp_path), ckpt_every=5,
                          reader=tr.snapshot_reader())
    seen = []
    step, state = sup.run(
        state=tr.state, train_step=tr.train_step, batch_at=tr.batch_at,
        n_steps=12, fault_plan=FaultPlan(fail_at_steps=(8,)),
        on_step=lambda s, st, m: seen.append(s))
    tr.controller.stop()
    sup.manager.close()
    assert step == 12
    assert sup.restarts == 1
    assert ("restored", 5, "") in sup.events   # resumed from step 5
    # steps 6..8 were replayed after the failure at 8
    assert seen.count(7) >= 2


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=16, threshold=2.5, persist=2)
    esc = []
    mon.escalate = lambda step, s: esc.append(step)
    for i in range(10):
        mon.observe(i, 0.10)
    assert not mon.flagged
    mon.observe(10, 0.30)
    assert mon.flagged and mon.flagged[-1][0] == 10
    mon.observe(11, 0.35)
    assert esc == [11]                 # escalated after 2 consecutive
    mon.observe(12, 0.1)
    assert len(esc) == 1


def test_rescale_plan_keeps_tp_and_divisibility():
    p = rescale_plan(n_devices=512, model_parallel=16, global_batch=256,
                     old_microbatches=8)
    assert p.mesh_shape == (32, 16)
    p = rescale_plan(n_devices=480, model_parallel=16, global_batch=256,
                     old_microbatches=8)   # lost a slice of 32 chips
    assert p.mesh_shape[1] == 16
    assert 256 % p.mesh_shape[0] == 0
    with pytest.raises(ValueError):
        rescale_plan(n_devices=100, model_parallel=16, global_batch=256,
                     old_microbatches=8)


def test_adamw_decreases_quadratic_loss():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = adamw.apply(g, state, params, cfg)
    assert float(loss(params)) < 0.5


def test_adamw_clipping_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1e-3, warmup_steps=1)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params, cfg)
    g = {"w": jnp.full((4,), 1e6)}
    p2, _ = adamw.apply(g, state, params, cfg)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 10.0


def test_pipeline_restart_reproducibility():
    src = SyntheticLM(vocab_size=97, seq_len=8, global_batch=4, seed=11)
    a = src.global_batch_at(123)
    b = src.global_batch_at(123)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.global_batch_at(124)
    assert not np.array_equal(a["tokens"], c["tokens"])
