"""The eval subsystem: driver plumbing, results schema, CLI wiring.

Quick-mode trials on a two-backend subset keep this a smoke of the REAL
path (threads, warmup, counters, JSON) rather than a perf assertion —
relative throughput claims live in the full `python -m repro.eval`
run and BENCHMARKS.md, not in CI-sized windows.
"""
import json

import pytest

from repro.eval import WORKLOADS, longread_headline, run_eval


def test_workload_registry_names():
    assert {"longread", "rwmix", "structrq", "reliability"} <= set(WORKLOADS)
    for w in WORKLOADS.values():
        variants = w.variants(quick=True)
        assert variants and all(v.workload == w.name for v in variants)
        assert len(w.variants(quick=False)) >= len(variants)


def test_run_eval_unknown_workload():
    with pytest.raises(ValueError, match="unknown workload"):
        run_eval("nope", save=False)


def test_longread_quick_rows_and_results_file(tmp_path):
    rows, path = run_eval("longread", backends=["multiverse", "tl2"],
                          quick=True, seed=7, out_dir=str(tmp_path))
    assert len(rows) == 2
    for r in rows:
        assert r["workload"] == "longread"
        assert r["seed"] == 7
        assert r["violations"] == 0          # consistency, not speed
        assert "scans_per_sec" in r and "stm_stats" in r
        assert set(r["stm_stats"]) >= {"commits", "aborts", "mode",
                                       "backend"}
    payload = json.loads((tmp_path / "eval_longread.json").read_text())
    assert payload["meta"]["schema_version"] == 1
    assert payload["meta"]["seed"] == 7
    assert payload["meta"]["workload"] == "longread"
    assert sorted(payload["meta"]["backends"]) == ["multiverse", "tl2"]
    assert "mode_transitions" in payload["meta"]
    assert len(payload["rows"]) == 2
    assert path == str(tmp_path / "eval_longread.json")


def test_longread_headline_extraction():
    rows = [
        {"backend": "multiverse", "scan_size": 4096, "scans_per_sec": 9.0},
        {"backend": "multiverse", "scan_size": 256, "scans_per_sec": 1.0},
        {"backend": "tl2", "scan_size": 4096, "scans_per_sec": 2.0},
        {"backend": "tinystm", "scan_size": 4096, "scans_per_sec": 0.5},
    ]
    h = longread_headline(rows)
    assert h["scan_size"] == 4096
    assert h["multiverse_wins"] is True
    assert h["baseline_scans_per_sec"] == {"tl2": 2.0, "tinystm": 0.5}
    assert longread_headline([]) == {}


def test_structrq_quick_single_backend(tmp_path):
    rows, _ = run_eval("structrq", backends=["tl2"], quick=True,
                       out_dir=str(tmp_path))
    assert rows and rows[0]["structure"] == "hashmap"
    assert rows[0]["rqs_per_sec"] >= 0
    assert rows[0]["violations"] == 0
    # the quiescent reference pair: struct query vs equal-word flat scan
    assert rows[0]["rq_words"] > 0
    assert rows[0]["rq_vs_scan"] > 0
