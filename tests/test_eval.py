"""The eval subsystem: driver plumbing, results schema, CLI wiring.

Quick-mode trials on a two-backend subset keep this a smoke of the REAL
path (threads, warmup, counters, JSON) rather than a perf assertion —
relative throughput claims live in the full `python -m repro.eval`
run and BENCHMARKS.md, not in CI-sized windows.
"""
import json

import pytest

from repro.eval import WORKLOADS, longread_headline, run_eval


def test_workload_registry_names():
    assert {"longread", "rwmix", "structrq", "reliability"} <= set(WORKLOADS)
    for w in WORKLOADS.values():
        variants = w.variants(quick=True)
        assert variants and all(v.workload == w.name for v in variants)
        assert len(w.variants(quick=False)) >= len(variants)


def test_run_eval_unknown_workload():
    with pytest.raises(ValueError, match="unknown workload"):
        run_eval("nope", save=False)


def test_longread_quick_rows_and_results_file(tmp_path):
    rows, path = run_eval("longread", backends=["multiverse", "tl2"],
                          quick=True, seed=7, out_dir=str(tmp_path))
    assert len(rows) == 2
    for r in rows:
        assert r["workload"] == "longread"
        assert r["seed"] == 7
        assert r["violations"] == 0          # consistency, not speed
        assert "scans_per_sec" in r and "stm_stats" in r
        assert set(r["stm_stats"]) >= {"commits", "aborts", "mode",
                                       "backend"}
    payload = json.loads((tmp_path / "eval_longread.json").read_text())
    assert payload["meta"]["schema_version"] == 1
    assert payload["meta"]["seed"] == 7
    assert payload["meta"]["workload"] == "longread"
    assert sorted(payload["meta"]["backends"]) == ["multiverse", "tl2"]
    assert "mode_transitions" in payload["meta"]
    assert len(payload["rows"]) == 2
    assert path == str(tmp_path / "eval_longread.json")


def test_longread_headline_extraction():
    rows = [
        {"backend": "multiverse", "scan_size": 4096, "scans_per_sec": 9.0},
        {"backend": "multiverse", "scan_size": 256, "scans_per_sec": 1.0},
        {"backend": "tl2", "scan_size": 4096, "scans_per_sec": 2.0},
        {"backend": "tinystm", "scan_size": 4096, "scans_per_sec": 0.5},
    ]
    h = longread_headline(rows)
    assert h["scan_size"] == 4096
    assert h["multiverse_wins"] is True
    assert h["baseline_scans_per_sec"] == {"tl2": 2.0, "tinystm": 0.5}
    assert longread_headline([]) == {}


def test_structrq_quick_single_backend(tmp_path):
    rows, _ = run_eval("structrq", backends=["tl2"], quick=True,
                       out_dir=str(tmp_path))
    assert rows and rows[0]["structure"] == "hashmap"
    assert rows[0]["rqs_per_sec"] >= 0
    assert rows[0]["violations"] == 0
    # the quiescent reference pair: struct query vs equal-word flat scan
    assert rows[0]["rq_words"] > 0
    assert rows[0]["rq_vs_scan"] > 0


def _durability_row(backend, variant, ups, *, violations=0, replayed=5,
                    grouped_members=0):
    return {"workload": "durability", "backend": backend,
            "variant": variant, "durable": "durable" in variant,
            "updates_per_sec": ups, "violations": violations,
            "wal_records_replayed": replayed if "durable" in variant
            else 0, "grouped_members": grouped_members,
            "commit_groups": 3 if grouped_members else 0,
            "wal_stats": {"fsyncs": 4} if "durable" in variant else {}}


def test_durability_headline_gates_on_group_pair():
    from repro.eval import durability_headline
    rows = [
        _durability_row("tl2", "inmem", 2000.0),
        _durability_row("tl2", "durable", 700.0),          # 0.35x solo
        _durability_row("tl2", "inmem-group", 2400.0),
        _durability_row("tl2", "durable-group", 1600.0,    # 0.67x group
                        grouped_members=12),
    ]
    h = durability_headline(rows)["tl2"]
    assert h["gated_on"] == "group"
    assert h["holds"] is True
    assert abs(h["ratio_vs_inmem"] - 1600.0 / 2400.0) < 1e-9
    assert abs(h["solo_ratio_vs_inmem"] - 0.35) < 1e-9


def test_durability_headline_falls_back_to_solo_and_fails_closed():
    from repro.eval import durability_headline
    # no grouped members -> solo pair gates; 0.35x -> does not hold
    rows = [
        _durability_row("multiverse", "inmem", 2000.0),
        _durability_row("multiverse", "durable", 700.0),
        _durability_row("multiverse", "inmem-group", 2000.0),
        _durability_row("multiverse", "durable-group", 700.0),
    ]
    h = durability_headline(rows)["multiverse"]
    assert h["gated_on"] == "solo"
    assert h["holds"] is False
    # a violation anywhere in the quartet kills the claim
    rows2 = [
        _durability_row("tl2", "inmem", 2000.0, violations=1),
        _durability_row("tl2", "inmem-group", 2400.0),
        _durability_row("tl2", "durable-group", 1600.0,
                        grouped_members=12),
    ]
    assert durability_headline(rows2)["tl2"]["holds"] is False


def test_durability_group_trial_smoke():
    """One live durable-group trial: fused batches journal through the
    WAL, the restart drill replays them, the checker stays clean."""
    from repro.eval.workloads import WORKLOADS, TrialSpec
    w = WORKLOADS["durability"]
    spec = TrialSpec(
        workload="durability", variant="durable-group", n_readers=1,
        n_updaters=2, duration_s=0.25, warmup_s=0.1,
        params=dict(write_words=64, n_blocks=8, max_retries=2000,
                    durable=True, grouped=True))
    row = w.run_trial("tl2", spec, seed=3)
    assert row["violations"] == 0
    assert row["updates_per_sec"] > 0
    assert row["grouped_members"] > 0          # batches really fused
    assert row["wal_records_replayed"] > 0     # restart drill replayed
    assert row["restart_drill_failures"] == []
    assert row["wal_stats"]["fsyncs"] <= row["wal_stats"]["decides"]
