"""The `repro.core.stm.run` shim must blame the CALLER, not repro.

`stacklevel=2` on the DeprecationWarning makes the warning point at the
legacy call site (the thing that needs migrating), not at repro
internals — asserted here via the warning's reported filename.
"""
import warnings

from repro.api import make_tm
from repro.core import stm


def test_stm_run_deprecation_warning_points_at_caller():
    tm = make_tm("tl2", n_threads=1)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        assert stm.run(tm, lambda tx: 41 + 1, tid=0) == 42
    dep = [w for w in caught
           if issubclass(w.category, DeprecationWarning)]
    assert dep, "shim did not warn"
    assert dep[0].filename == __file__      # stacklevel=2: the caller
    tm.stop()
