import os
import sys

# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benches must see the real (single) device; only
# launch/dryrun.py forces 512 (see the assignment's dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)
