"""Dedicated coverage for `core/heuristics.py`: K1/K2/K3 trigger
conditions, `MinModeUReadCount` reset semantics, sticky-bit clearing, and
the L/P commit-delta unversioning threshold (paper SS4.2-SS4.4)."""
import pytest

from repro.configs.paper_stm import MultiverseParams
from repro.core import heuristics as heur


# ---------------------------------------------------------------------------
# K1: unversioned read-only txns go versioned after K1 failed attempts
# ---------------------------------------------------------------------------


def test_k1_exact_boundary():
    p = MultiverseParams(k1=3)
    assert not heur.should_go_versioned(p, 0)
    assert not heur.should_go_versioned(p, 2)
    assert heur.should_go_versioned(p, 3)        # >= k1, not >
    assert heur.should_go_versioned(p, 4)


# ---------------------------------------------------------------------------
# K2/K3: when a read-only txn CASes the TM from Q to QtoU
# ---------------------------------------------------------------------------


def test_k3_versioned_txns_cas_regardless_of_read_count():
    p = MultiverseParams(k2=100, k3=4)
    for read_cnt in (0, 1, 10 ** 6):
        assert heur.should_attempt_mode_cas(
            p, versioned=True, attempts=4, read_cnt=read_cnt,
            min_mode_u_reads=None)
    assert not heur.should_attempt_mode_cas(
        p, versioned=True, attempts=3, read_cnt=10 ** 6,
        min_mode_u_reads=None)


def test_k2_requires_mode_u_read_evidence_for_unversioned():
    p = MultiverseParams(k2=2, k3=100)
    # no Mode-U history: unversioned txns may NOT CAS (only versioned do)
    assert not heur.should_attempt_mode_cas(
        p, versioned=False, attempts=5, read_cnt=10 ** 6,
        min_mode_u_reads=None)
    assert heur.should_attempt_mode_cas(
        p, versioned=True, attempts=5, read_cnt=0, min_mode_u_reads=None)
    # with history: read count must reach the observed Mode-U minimum
    assert heur.should_attempt_mode_cas(
        p, versioned=False, attempts=2, read_cnt=8, min_mode_u_reads=8)
    assert not heur.should_attempt_mode_cas(
        p, versioned=False, attempts=2, read_cnt=7, min_mode_u_reads=8)
    # attempts below k2 never CAS for unversioned txns
    assert not heur.should_attempt_mode_cas(
        p, versioned=False, attempts=1, read_cnt=100, min_mode_u_reads=1)


# ---------------------------------------------------------------------------
# MinModeUReadCount: monotone minimum with explicit reset
# ---------------------------------------------------------------------------


def test_min_mode_u_read_count_tracks_minimum_and_resets():
    m = heur.MinModeUReadCount()
    assert m.get() is None                       # no Mode-U commits yet
    m.update(50)
    assert m.get() == 50
    m.update(80)                                 # larger: ignored
    assert m.get() == 50
    m.update(12)                                 # smaller: new minimum
    assert m.get() == 12
    m.reset()
    assert m.get() is None                       # Mode-U epoch ended
    m.update(7)                                  # fresh epoch re-learns
    assert m.get() == 7


# ---------------------------------------------------------------------------
# S: sticky Mode-U bit clears after S consecutive small transactions
# ---------------------------------------------------------------------------


def test_sticky_threshold_set_by_first_commit_then_clears():
    p = MultiverseParams(s=2)
    ann = heur.ThreadAnnouncement()
    ann.sticky_mode_u = True
    # first post-CAS commit of 100 reads sets small-threshold = 100/2 = 50
    assert not heur.sticky_cleared(p, ann, 100)
    assert ann.small_txn_read_cnt == 50
    assert not heur.sticky_cleared(p, ann, 50)   # 1 consecutive small
    assert heur.sticky_cleared(p, ann, 49)       # 2 consecutive: cleared
    # clearing resets the tracking state for the next Mode-U episode
    assert ann.small_txn_read_cnt is None and ann.consec_small_txns == 0


def test_sticky_large_txn_resets_consecutive_count():
    p = MultiverseParams(s=2)
    ann = heur.ThreadAnnouncement()
    heur.sticky_cleared(p, ann, 100)             # threshold = 50
    assert not heur.sticky_cleared(p, ann, 10)   # small (1)
    assert not heur.sticky_cleared(p, ann, 99)   # LARGE: streak broken
    assert ann.consec_small_txns == 0
    assert not heur.sticky_cleared(p, ann, 10)   # small (1)
    assert heur.sticky_cleared(p, ann, 10)       # small (2): cleared


def test_sticky_threshold_floor_is_one():
    p = MultiverseParams(s=10)
    ann = heur.ThreadAnnouncement()
    heur.sticky_cleared(p, ann, 3)               # 3 // 10 == 0 -> floor 1
    assert ann.small_txn_read_cnt == 1


# ---------------------------------------------------------------------------
# L/P: the commit-delta unversioning threshold
# ---------------------------------------------------------------------------


def test_lp_threshold_needs_l_full_rounds():
    p = MultiverseParams(l=3, p=0.5)
    u = heur.UnversionThreshold(p)
    u.observe_round([10])
    u.observe_round([20])
    assert u.threshold() is None                 # only 2 of L=3 rounds
    u.observe_round([30])
    # sorted desc [30,20,10]; top P=0.5 of 3 -> 1 entry -> 30
    assert u.threshold() == pytest.approx(30.0)


def test_lp_empty_rounds_are_ignored():
    p = MultiverseParams(l=2, p=1.0)
    u = heur.UnversionThreshold(p)
    u.observe_round([])                          # no announcements: skipped
    u.observe_round([8])
    assert u.threshold() is None
    u.observe_round([])
    assert u.threshold() is None                 # still one real round
    u.observe_round([4])
    assert u.threshold() == pytest.approx(6.0)   # mean of [8, 4], P=1.0


def test_lp_window_slides_and_averages_within_rounds():
    p = MultiverseParams(l=2, p=1.0)
    u = heur.UnversionThreshold(p)
    u.observe_round([10, 30])                    # round mean 20
    u.observe_round([40])
    assert u.threshold() == pytest.approx(30.0)  # (20 + 40) / 2
    u.observe_round([100])                       # evicts the 20
    assert u.threshold() == pytest.approx(70.0)  # (40 + 100) / 2
