"""Cross-shard serializability harness for ``ShardStoreHandle``.

The property under test: for ANY interleaved schedule of begin/execute/
commit events over block-rotation transactions (single-shard and
cross-shard footprints mixed), the set of COMMITTED transactions must be
serializable in COMMIT ORDER — replaying just the committed rotations,
in the order their commits succeeded, against a plain single-clock
reference array reproduces the store's heap exactly (the
committed-prefix equality the single global clock used to give for
free).  Alongside it:

  * per-shard clock monotonicity: every component of ``store.clocks``
    and the coarse ``store.epoch`` are non-decreasing across the whole
    schedule;
  * snapshot-at-every-cut consistency: after EVERY committed
    transaction, a whole-heap ``snapshot_bulk`` at the current per-shard
    cut equals the reference prefix — no torn cut is ever observable,
    including cuts taken right after a cross-shard epoch publish.

The generator half runs under ``hypothesis`` when available (CI installs
it via requirements-dev.txt); the seeded-random twin below exercises the
same property unconditionally so local runs keep real coverage.
"""
import random

import numpy as np
import pytest

from repro.configs.paper_stm import MultiverseParams
from repro.core.engine import AbortTx
from repro.core.shardstore import ShardStoreHandle

SPAN = 4
N_BLOCKS = 8                     # block b = span b -> shard b % n_shards
N_WORDS = SPAN * N_BLOCKS


def make_store(n_shards, n_threads=8):
    params = MultiverseParams(k1=50, k2=500, k3=500, lock_table_bits=8)
    return ShardStoreHandle(n_threads, n_shards=n_shards, span=SPAN,
                            params=params, start_bg=False)


def apply_ref(ref, blocks, shift):
    for b in blocks:
        lo = SPAN * b
        ref[lo:lo + SPAN] = np.roll(ref[lo:lo + SPAN], shift)


def run_schedule(n_shards, txn_specs, schedule):
    """Drive an interleaved schedule; check the three properties inline.

    ``txn_specs[i] = (blocks, shift)``; ``schedule`` is a sequence of
    ``("begin", i) | ("exec", i) | ("commit", i)`` events (invalid or
    duplicate events are skipped — generators stay unconstrained).
    Returns the number of committed transactions.
    """
    st = make_store(n_shards)
    base = st.alloc(N_WORDS, 0)
    init = np.arange(N_WORDS, dtype=np.int64) * 5 + 3
    with st.txn(tid=0) as tx:
        tx.write_bulk(range(base, base + N_WORDS), init)
    ref = init.copy()

    open_tx = {}
    done = set()
    committed = 0
    prev_clocks = st.clocks
    prev_epoch = st.epoch
    for ev, i in schedule:
        if i in done:
            continue
        blocks, shift = txn_specs[i]
        if ev == "begin":
            if i not in open_tx:
                tid = i % st.n_threads
                st.begin_operation(tid)
                open_tx[i] = [st.begin(tid), False]
        elif ev == "exec" and i in open_tx and not open_tx[i][1]:
            tx = open_tx[i][0]
            try:
                for b in blocks:
                    lo = base + SPAN * b
                    vals = np.asarray(
                        tx.read_bulk(range(lo, lo + SPAN)), np.int64)
                    tx.write_bulk(range(lo, lo + SPAN),
                                  np.roll(vals, shift))
                open_tx[i][1] = True
            except AbortTx:
                del open_tx[i]
                done.add(i)
        elif ev == "commit" and i in open_tx and open_tx[i][1]:
            tx = open_tx[i][0]
            del open_tx[i]
            done.add(i)
            try:
                st.commit(tx)
            except AbortTx:
                continue
            committed += 1
            # serializability: committed prefix == reference replay
            apply_ref(ref, blocks, shift)
            snap, ok = st.snapshot_bulk(np.arange(base, base + N_WORDS))
            assert ok, "whole-heap snapshot at the current cut failed"
            np.testing.assert_array_equal(
                snap, ref,
                err_msg=f"committed prefix diverged after txn {i}")
        # clock monotonicity holds at EVERY event boundary
        clocks, epoch = st.clocks, st.epoch
        assert all(c >= p for c, p in zip(clocks, prev_clocks))
        assert epoch >= prev_epoch
        prev_clocks, prev_epoch = clocks, epoch
    for slot in open_tx.values():          # abandon whatever never committed
        st.abort(slot[0])
    snap, ok = st.snapshot_bulk(np.arange(base, base + N_WORDS))
    assert ok
    np.testing.assert_array_equal(snap, ref)
    st.stop()
    return committed


def random_case(r):
    n_shards = r.choice((1, 2, 4))
    n_txns = r.randrange(2, 8)
    specs = []
    for _ in range(n_txns):
        k = r.randrange(1, 4)              # 1 block = single-shard;
        blocks = r.sample(range(N_BLOCKS), k)   # >1 may span shards
        specs.append((tuple(blocks), 1 + r.randrange(SPAN - 1)))
    events = []
    for i in range(n_txns):
        events += [("begin", i), ("exec", i), ("commit", i)]
    r.shuffle(events)
    return n_shards, specs, events


@pytest.mark.parametrize("seed", range(12))
def test_shard_serializable_committed_prefix_seeded(seed):
    r = random.Random(1000 + seed)
    n_shards, specs, events = random_case(r)
    run_schedule(n_shards, specs, events)


def test_shard_serializable_interleaved_cross_shard_pair():
    """The sharpest hand-built case: two cross-shard rotations pinned
    before either commits — the second MUST abort (their footprints
    overlap on a shard), never merge into a non-serializable cut."""
    specs = [((0, 1), 1), ((1, 2), 2)]
    schedule = [("begin", 0), ("begin", 1), ("exec", 0), ("exec", 1),
                ("commit", 0), ("commit", 1)]
    committed = run_schedule(2, specs, schedule)
    assert committed == 1


def test_shard_serializable_disjoint_cross_pairs_both_commit():
    """Two cross-shard rotations on DISJOINT shard sets interleaved:
    both commit — at 4 shards blocks (0,1) live on shards {0,1} and
    blocks (2,3) on shards {2,3}, so neither epoch publish stales the
    other's pins (a store-wide clock would abort the second)."""
    specs = [((0, 1), 1), ((2, 3), 2)]
    schedule = [("begin", 0), ("begin", 1), ("exec", 0), ("exec", 1),
                ("commit", 0), ("commit", 1)]
    committed = run_schedule(4, specs, schedule)
    assert committed == 2


def test_shard_serializable_many_seeds_high_contention():
    """A denser sweep: more txns over fewer blocks, all shard counts."""
    for seed in range(8):
        r = random.Random(7000 + seed)
        n_txns = r.randrange(4, 10)
        specs = [(tuple(r.sample(range(4), r.randrange(1, 3))),
                  1 + r.randrange(SPAN - 1)) for _ in range(n_txns)]
        events = []
        for i in range(n_txns):
            events += [("begin", i), ("exec", i), ("commit", i)]
        r.shuffle(events)
        run_schedule(r.choice((1, 2, 4)), specs, events)


# ---------------------------------------------------------------------------
# hypothesis half (CI: requirements-dev.txt installs it; local runs skip)
# ---------------------------------------------------------------------------

def test_shard_serializable_committed_prefix_property():
    """Generator-driven twin of the seeded sweep (importorskip keeps
    local runs green without the package; the seeded tests above carry
    the coverage there)."""
    hypothesis = pytest.importorskip("hypothesis")
    st_mod = pytest.importorskip("hypothesis.strategies")

    @st_mod.composite
    def schedules(draw):
        n_shards = draw(st_mod.sampled_from((1, 2, 4)))
        n_txns = draw(st_mod.integers(2, 6))
        specs = []
        for _ in range(n_txns):
            blocks = draw(st_mod.lists(
                st_mod.integers(0, N_BLOCKS - 1), min_size=1,
                max_size=3, unique=True))
            specs.append((tuple(blocks),
                          draw(st_mod.integers(1, SPAN - 1))))
        events = [ev for i in range(n_txns)
                  for ev in (("begin", i), ("exec", i), ("commit", i))]
        events = draw(st_mod.permutations(events))
        return n_shards, specs, events

    @hypothesis.given(schedules())
    @hypothesis.settings(max_examples=40, deadline=None)
    def prop(case):
        n_shards, specs, events = case
        run_schedule(n_shards, specs, events)

    prop()
