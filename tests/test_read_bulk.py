"""`Txn.read_bulk` / `snapshot_bulk`: batched reads on every backend.

Three layers of assurance:

  * unit: batch == scalar loop on quiescent heaps (values, read-own-
    writes, read-count accounting, empty/duplicate batches), fallback on
    foreign-locked words, and the deterministic versioned-snapshot case
    (a bulk read returns the PAST value of a word committed after the
    reader's snapshot);
  * kernel: the Pallas gather twin agrees with the numpy fancy-index
    element-for-element, ragged sizes included;
  * concurrency (the snapshot-consistency satellite): scanner threads
    `read_bulk` the whole region while updaters commit balance-preserving
    transfers — every completed scan must observe an exact region sum,
    on the word backends and on mvstore.
"""
import random
import sys
import threading
import time

import numpy as np
import pytest

from repro.api import AbortTx, MaxRetriesExceeded, run

from tests._backends import ALL_BACKENDS, WORD_BACKENDS, make_test_tm

INITIAL = 10


# ---------------------------------------------------------------------------
# unit: batch == scalar loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_read_bulk_matches_scalar(backend):
    tm = make_test_tm(backend, n_threads=1)
    base = tm.alloc(300, 7)
    def body(tx):
        bulk = [int(v) for v in tx.read_bulk(range(base, base + 300))]
        scalar = [int(tx.read(base + i)) for i in range(300)]
        return bulk, scalar
    bulk, scalar = run(tm, body, tid=0)
    assert bulk == scalar == [7] * 300
    tm.stop()


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("array_heap", [False, True])
def test_read_bulk_sees_own_writes(backend, array_heap):
    if backend == "mvstore" and array_heap:
        pytest.skip("store layer is always array-backed")
    kw = {} if backend == "mvstore" else {"array_heap": array_heap}
    tm = make_test_tm(backend, n_threads=1, **kw)
    base = tm.alloc(64, 1)
    def body(tx):
        tx.write(base + 3, 42)
        tx.write(base + 60, 43)
        return [int(v) for v in tx.read_bulk(
            [base + 2, base + 3, base + 60, base + 3])]
    assert run(tm, body, tid=0) == [1, 42, 43, 42]
    tm.stop()


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_read_bulk_counts_reads_and_handles_empty(backend):
    tm = make_test_tm(backend, n_threads=1)
    base = tm.alloc(128, 0)
    def body(tx):
        assert list(tx.read_bulk([])) == []
        tx.read_bulk(range(base, base + 128))
        return tx.read_count
    assert run(tm, body, tid=0) >= 128
    tm.stop()


def test_read_bulk_scalar_fallback_aborts_on_foreign_lock():
    """A word encounter-locked by another thread fails the vectorized
    predicate; the per-element scalar fallback must then abort with the
    policy's exact semantics (not return a torn value)."""
    tm = make_test_tm("dctl", n_threads=2)
    base = tm.alloc(400, 5)
    tx0 = None
    for _ in range(3):                # deferred clock: first access may
        tx0 = tm.begin(0)             # abort once on a fresh TM
        try:
            tx0.write(base + 17, 99)  # encounter-time: lock held, in-place
            break
        except AbortTx:
            tx0 = None
    assert tx0 is not None
    with pytest.raises(MaxRetriesExceeded):
        run(tm, lambda tx: tx.read_bulk(range(base, base + 400)),
            tid=1, max_retries=3)
    tm.abort(tx0)                     # rolls the 99 back
    vals = run(tm, lambda tx: tx.read_bulk(range(base, base + 400)), tid=1)
    assert [int(v) for v in vals] == [5] * 400
    tm.stop()


def test_versioned_bulk_read_returns_snapshot_past():
    """Deterministic snapshot isolation through the hybrid bulk path: a
    versioned reader whose snapshot predates a committed write must get
    the OLD value from the version list while the heap already holds the
    new one — the paper's long-running read, in one batch."""
    tm = make_test_tm("multiverse", n_threads=2, start_bg=False)
    base = tm.alloc(300, 7)
    target = base + 5
    # warm the deferred clock past 0 (a fresh TM's first access aborts
    # once; versioning needs lock versions strictly below the snapshot)
    run(tm, lambda t: t.write(base + 299, 7), tid=0)
    # seed a version list for the target (a versioned read versions it)
    tx = tm.begin(1)
    tx._ctx.versioned = True
    assert tx.read(target) == 7
    tm.commit(tx)
    # bump the deferred clock (what any abort does) so the reader's
    # snapshot sits strictly ABOVE every version committed so far
    tm.clock.increment()
    # reader pins its snapshot, THEN a writer commits a new value
    tx = tm.begin(1)
    tx._ctx.versioned = True
    run(tm, lambda t: t.write(target, 99), tid=0)
    assert tm.peek(target) == 99
    # scan everything except UNVERSIONED words sharing the target's lock
    # bucket: their bucket version now equals the snapshot, so a Mode-Q
    # versioned reader would (correctly) abort on versioning them — the
    # scalar path included; excluding them keeps the test deterministic
    idx_t = tm.locks.index(target)
    addrs = [a for a in range(base, base + 300)
             if a == target or tm.locks.index(a) != idx_t]
    vals = tx.read_bulk(addrs)
    tm.commit(tx)
    assert int(vals[addrs.index(target)]) == 7   # the snapshot's past
    assert sum(int(v) for v in vals) == len(addrs) * 7
    assert tm.stats()["versioned_commits"] >= 1
    tm.stop()


def test_mvstore_snapshot_bulk_serves_past_clock():
    tm = make_test_tm("mvstore", n_threads=2)
    base = tm.alloc(40, 3)
    # version the block (a K1-promoted reader would do this), then commit
    tx = tm.begin(1)
    tx._ctx.versioned = True
    old = [int(v) for v in tx.read_bulk(range(base, base + 40))]
    tm.commit(tx)
    clock0 = tm.clock
    run(tm, lambda t: t.write(base + 1, 77), tid=0)
    vals, ok = tm.snapshot_bulk(range(base, base + 40))
    assert ok and int(vals[1]) == 77            # current clock: live block
    stale, ok = tm.snapshot_bulk(range(base, base + 40),
                                 read_clock=clock0)
    assert ok and [int(v) for v in stale] == old == [3] * 40
    tm.stop()


# ---------------------------------------------------------------------------
# kernel twin agreement
# ---------------------------------------------------------------------------


def test_gather_kernel_matches_numpy_twin():
    import jax.numpy as jnp
    from repro.kernels import gather_read
    rng = np.random.default_rng(0)
    heap = jnp.asarray(rng.integers(0, 1 << 20, size=2048), jnp.int32)
    for n in (512, 1024):
        addrs = jnp.asarray(rng.integers(0, 2048, size=n), jnp.int32)
        out = gather_read.gather_read_flat(heap, addrs, tile=256,
                                           interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(heap)[np.asarray(addrs)])


def test_ops_snapshot_read_pads_ragged_batches():
    import jax.numpy as jnp
    from repro.kernels import ops
    heap = jnp.arange(1000, dtype=jnp.int32)
    for n in (1, 7, 130, 777):
        addrs = np.arange(n) * 3 % 1000
        out = np.asarray(ops.snapshot_read(heap, addrs))
        assert out.shape == (n,)
        np.testing.assert_array_equal(out, np.arange(1000)[addrs])


# ---------------------------------------------------------------------------
# concurrency: balance-preserving snapshots (the satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_scanner_snapshots_are_balance_preserving(backend):
    """Scanner `read_bulk`s the whole region while updaters commit
    transfers; EVERY completed scan must see the exact region sum.  The
    updaters then stop and the scanner must still complete cleanly (so
    the test is deterministic about completing, while any torn batch
    during the concurrent phase would have tripped the assertion)."""
    n = 128
    n_threads = 3
    kw = {"array_heap": True} if backend in WORD_BACKENDS else {}
    tm = make_test_tm(backend, n_threads=n_threads, **kw)
    base = tm.alloc(n, INITIAL)
    expected = n * INITIAL
    stop = threading.Event()
    scans = {"done": 0, "bad": 0}

    def updater(tid):
        r = random.Random(1000 + tid)
        def transfer(tx):
            i = r.randrange(n)
            j = (i + 1 + r.randrange(n - 1)) % n
            tx.write(base + i, int(tx.read(base + i)) - 1)
            tx.write(base + j, int(tx.read(base + j)) + 1)
        while not stop.is_set():
            try:
                run(tm, transfer, tid=tid, max_retries=2000)
            except MaxRetriesExceeded:
                pass

    def scan_once(max_retries):
        def scan(tx):
            total = 0
            for off in range(0, n, 64):
                total += int(np.sum(np.asarray(
                    tx.read_bulk(range(base + off, base + off + 64)),
                    dtype=np.int64)))
            return total
        total = run(tm, scan, tid=n_threads - 1,
                    max_retries=max_retries)
        scans["done"] += 1
        if total != expected:
            scans["bad"] += 1

    old_si = sys.getswitchinterval()
    sys.setswitchinterval(2e-5)
    threads = [threading.Thread(target=updater, args=(t,), daemon=True)
               for t in range(2)]
    try:
        [t.start() for t in threads]
        deadline = time.time() + 2.0
        while time.time() < deadline and scans["done"] < 5:
            try:
                scan_once(max_retries=10)
            except MaxRetriesExceeded:
                pass                   # unversioned TMs starve here
    finally:
        stop.set()
        [t.join() for t in threads]
        sys.setswitchinterval(old_si)
    scan_once(max_retries=100)         # quiescent: must complete exactly
    assert scans["bad"] == 0
    assert scans["done"] >= 1
    tm.stop()
