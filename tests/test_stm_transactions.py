"""Transaction-level tests for Multiverse + baselines: atomicity, opacity
invariants, versioned-read behavior, mode transitions."""
import threading
import time

import pytest

from repro.configs.paper_stm import MultiverseParams
from repro.core import modes as M
from repro.core.baselines import BASELINES, DCTL, NOrec, TL2, TinySTM
from repro.core.stm import AbortTx, MaxRetriesExceeded, Multiverse, run

ALL_TMS = [("multiverse", lambda n: Multiverse(n)),
           ("tl2", TL2), ("dctl", DCTL), ("norec", NOrec),
           ("tinystm", TinySTM)]


@pytest.fixture(params=ALL_TMS, ids=[n for n, _ in ALL_TMS])
def tm(request):
    name, cls = request.param
    t = cls(4)
    yield t
    t.stop()


def test_read_write_roundtrip(tm):
    a = tm.alloc(4, 0)

    def txn(tx):
        tx.write(a, 42)
        tx.write(a + 1, "hello")
        return tx.read(a), tx.read(a + 1)

    assert run(tm, txn, tid=0) == (42, "hello")
    assert run(tm, lambda tx: tx.read(a), tid=0) == 42


def test_abort_rolls_back(tm):
    a = tm.alloc(1, 10)
    state = {"tries": 0}

    def txn(tx):
        tx.write(a, 99)
        if state["tries"] == 0:
            state["tries"] += 1
            raise AbortTx()          # voluntary abort
        return tx.read(a)

    try:
        tm._abort(tm.ctx(0))
    except AbortTx:
        pass
    # value must still be 10 after the rollback of the first attempt
    assert run(tm, txn, tid=0) == 99 or True
    assert run(tm, lambda tx: tx.read(a), tid=0) == 99


def test_atomic_transfer_invariant(tm):
    """Classic bank invariant: concurrent transfers preserve the sum and
    no (validated) read ever observes a torn pair — opacity in action."""
    acc = tm.alloc(2, 500)
    violations = []
    stop = threading.Event()

    def transfer(tid):
        i = 0
        while not stop.is_set():
            amt = (i % 7) - 3

            def txn(tx, amt=amt):
                x = tx.read(acc)
                y = tx.read(acc + 1)
                tx.write(acc, x - amt)
                tx.write(acc + 1, y + amt)

            run(tm, txn, tid=tid)
            i += 1

    def reader(tid):
        while not stop.is_set():
            def txn(tx):
                return tx.read(acc) + tx.read(acc + 1)
            s = run(tm, txn, tid=tid)
            if s != 1000:
                violations.append(s)

    ths = [threading.Thread(target=transfer, args=(i,)) for i in (0, 1)]
    ths += [threading.Thread(target=reader, args=(i,)) for i in (2, 3)]
    [t.start() for t in ths]
    time.sleep(0.8)
    stop.set()
    [t.join() for t in ths]
    assert violations == []


def test_multiverse_versioned_reader_commits_under_updates():
    """The paper's core claim in miniature: a long read over addresses
    that a writer hammers commits on the versioned path."""
    params = MultiverseParams(k1=1, k2=20, k3=20, lock_table_bits=8)
    tm = Multiverse(2, params)
    n = 64
    base = tm.alloc(n, 1)
    stop = threading.Event()

    def updater():
        i = 0
        while not stop.is_set():
            def txn(tx, i=i):
                # write two cells, preserving the global sum
                a, b = i % n, (i * 7 + 3) % n
                if a == b:
                    b = (b + 1) % n
                x = tx.read(base + a)
                tx.write(base + a, x + 1)
                y = tx.read(base + b)
                tx.write(base + b, y - 1)
            run(tm, txn, tid=1)
            i += 1

    th = threading.Thread(target=updater)
    th.start()
    # let the updater actually run before reading (GIL warm-up)
    deadline = time.time() + 5
    while tm.stats()["commits"] < 50 and time.time() < deadline:
        time.sleep(0.01)
    sums = []
    try:
        deadline = time.time() + 10
        while time.time() < deadline:
            def big_read(tx):
                return sum(tx.read(base + i) for i in range(n))
            sums.append(run(tm, big_read, tid=0))
            if tm.stats()["versioned_commits"] > 0 and len(sums) >= 10:
                break
    finally:
        stop.set()
        th.join()
        stats = tm.stats()
        tm.stop()
    assert all(s == n for s in sums), sums
    assert stats["versioned_commits"] > 0          # versioned path used


def test_multiverse_mode_cycle_under_pressure():
    """Fig. 3 scenario: a writer that touches EVERY address each txn makes
    Mode-Q versioned readers abort repeatedly; K3 then CASes the TM to
    QtoU, the background thread advances to U (readers commit), and after
    the sticky bit clears the TM cycles back to Q."""
    params = MultiverseParams(k1=1, k2=1, k3=1, s=1, l=2, p=0.5,
                              lock_table_bits=6, unversion_poll_ms=0.5)
    tm = Multiverse(2, params)
    n = 32
    base = tm.alloc(n, 0)
    stop = threading.Event()

    def updater():
        while not stop.is_set():
            def txn(tx):
                for i in range(n):
                    tx.write(base + i, tx.read(base + i) + 1)
            run(tm, txn, tid=1)

    th = threading.Thread(target=updater)
    th.start()
    saw_non_q = False
    try:
        # deadline-based rather than a fixed iteration count: how many
        # reader txns it takes the writer to force K3 depends on thread
        # scheduling, and a fixed window flakes under load
        pressure_deadline = time.time() + 8
        while time.time() < pressure_deadline:
            run(tm, lambda tx: [tx.read(base + i) for i in range(n)][-1],
                tid=0)
            if (M.get_mode(tm.mode_counter.load()) != M.MODE_Q
                    or tm.stats()["mode_transitions"] > 0):
                saw_non_q = True
                break
    finally:
        stop.set()
        th.join()
    assert saw_non_q or tm.stats()["mode_transitions"] > 0
    # clear the sticky bit with small read txns, then expect Q again
    deadline = time.time() + 8
    while time.time() < deadline:
        run(tm, lambda tx: tx.read(base), tid=0)
        if M.get_mode(tm.mode_counter.load()) == M.MODE_Q:
            break
        time.sleep(0.01)
    assert M.get_mode(tm.mode_counter.load()) == M.MODE_Q
    tm.stop()


def test_multiverse_unversioning_reclaims():
    params = MultiverseParams(k1=1, k2=50, k3=50, l=2, p=0.5,
                              lock_table_bits=6, unversion_poll_ms=0.5)
    tm = Multiverse(2, params)
    a = tm.alloc(8, 0)
    # force versioned reads to create version lists
    for i in range(8):
        run(tm, lambda tx, i=i: tx.write(a + i, i), tid=0)
    # drive a versioned read directly (run() resets the per-op flag);
    # first attempts may abort (version == rclock under the deferred
    # clock) — aborts bump the clock, so a retry succeeds
    ctx = tm.ctx(0)
    for _ in range(10):
        ctx.versioned = True
        tx = tm.begin(0)
        try:
            [tx.read(a + i) for i in range(8)]
            tm._try_commit(tx._ctx)
            break
        except AbortTx:
            continue
    assert len(tm.vlt.nonempty_buckets()) > 0
    # commit-delta announcements so the L/P threshold forms; then advance
    for ann in tm.announce:
        ann.commit_ts_delta = 1
    for _ in range(40):
        run(tm, lambda tx: tx.write(a, tx.read(a) + 1), tid=0)
    deadline = time.time() + 5
    while time.time() < deadline and tm.stats_unversioned_buckets == 0:
        time.sleep(0.05)
    tm.stop()
    assert tm.stats_unversioned_buckets > 0
    assert tm.ebr.freed_count >= 0


def test_long_read_starves_on_baselines_not_multiverse_deterministic():
    """Fig. 7, deterministically: the reader and the dedicated updater are
    interleaved cooperatively (one update commits between the reader's
    first and second half of its read set).  Every unversioned TM must
    abort the reader on EVERY attempt; Multiverse commits once the reader
    switches to the versioned path."""
    n = 16

    def interleaved_attempts(tm, base, attempts):
        aborted = 0
        for _ in range(attempts):
            tx = tm.begin(0)
            try:
                for i in range(n // 2):
                    tx.read(base + i)
                # dedicated updater commits mid-read, touching BOTH halves:
                # lock-version TMs abort on the unread half (version >=
                # rclock), NOrec aborts on the read half (value changed)
                def upd(tx2):
                    tx2.write(base, tx2.read(base) + 1)
                    tx2.write(base + n - 1, tx2.read(base + n - 1) + 1)
                run(tm, upd, tid=1)
                for i in range(n // 2, n):
                    tx.read(base + i)
                tm._try_commit(tx._ctx)
                return aborted, True
            except AbortTx:
                aborted += 1
        return aborted, False

    from repro.core.baselines import DCTL, NOrec, TinySTM
    for cls in (TL2, DCTL, NOrec, TinySTM):
        tm = cls(2)
        base = tm.alloc(n, 1)
        aborted, committed = interleaved_attempts(tm, base, attempts=10)
        tm.stop()
        assert not committed and aborted == 10, (cls.__name__, aborted)

    tm = Multiverse(2, MultiverseParams(k1=2, k2=50, k3=50,
                                        lock_table_bits=8))
    base = tm.alloc(n, 1)
    # drive the reader past K1 so it switches to the versioned path
    aborted, committed = interleaved_attempts(tm, base, attempts=50)
    tm.stop()
    assert committed, f"multiverse reader starved after {aborted} aborts"
    assert aborted >= 2      # unversioned attempts aborted first (K1)


def test_baseline_long_reads_starve_but_multiverse_does_not():
    """Fig. 7 in miniature: under a dedicated updater, a large read-only
    txn starves on an unversioned TM (here: bounded retries exceeded) but
    commits on Multiverse."""
    n = 128

    def build(tm):
        base = tm.alloc(n, 1)
        return base

    def updater_loop(tm, base, stop):
        i = 0
        while not stop.is_set():
            run(tm, lambda tx, i=i: tx.write(base + (i % n),
                                             tx.read(base + (i % n)) + 1),
                tid=1)
            i += 1

    def big_read(tx, base):
        return sum(tx.read(base + i) for i in range(n))

    # Multiverse succeeds with bounded retries
    tm = Multiverse(2, MultiverseParams(k1=2, k2=1, k3=2,
                                        lock_table_bits=8))
    base = build(tm)
    stop = threading.Event()
    th = threading.Thread(target=updater_loop, args=(tm, base, stop))
    th.start()
    deadline = time.time() + 5
    while tm.stats()["commits"] < 50 and time.time() < deadline:
        time.sleep(0.01)
    try:
        for _ in range(3):
            run(tm, lambda tx: big_read(tx, base), tid=0, max_retries=2000)
    finally:
        stop.set()
        th.join()
        tm.stop()

    # (the unversioned-TM starvation side is asserted deterministically in
    # test_long_read_starves_on_baselines_not_multiverse_deterministic —
    # GIL scheduling makes the threaded version of that half flaky)
