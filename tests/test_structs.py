"""Data-structure correctness on every TM + concurrent mixed workloads."""
import random
import threading

import pytest

from repro.core.baselines import DCTL, NOrec, TL2, TinySTM
from repro.core.stm import Multiverse, run
from repro.structs import ABTree, ExternalBST, HashMap

TMS = [("multiverse", lambda n: Multiverse(n)), ("tl2", TL2),
       ("dctl", DCTL), ("norec", NOrec), ("tinystm", TinySTM)]
STRUCTS = [("abtree", ABTree), ("hashmap", lambda tm: HashMap(tm, 64)),
           ("extbst", ExternalBST)]


@pytest.mark.parametrize("tm_name,tm_cls", TMS, ids=[n for n, _ in TMS])
@pytest.mark.parametrize("s_name,s_cls", STRUCTS,
                         ids=[n for n, _ in STRUCTS])
def test_struct_matches_dict(tm_name, tm_cls, s_name, s_cls):
    tm = tm_cls(2)
    s = s_cls(tm)
    ref = {}
    rnd = random.Random(7)
    for _ in range(600):
        op = rnd.random()
        k = rnd.randrange(200)
        if op < 0.5:
            run(tm, lambda tx, k=k: s.insert(tx, k, k * 3), tid=0)
            ref[k] = k * 3
        elif op < 0.75:
            run(tm, lambda tx, k=k: s.delete(tx, k), tid=0)
            ref.pop(k, None)
        else:
            got = run(tm, lambda tx, k=k: s.search(tx, k), tid=0)
            assert got == ref.get(k), (k, got, ref.get(k))
    # final sweep
    for k in range(200):
        got = run(tm, lambda tx, k=k: s.search(tx, k), tid=0)
        assert got == ref.get(k)
    tm.stop()


@pytest.mark.parametrize("s_name,s_cls",
                         [("abtree", ABTree), ("extbst", ExternalBST)],
                         ids=["abtree", "extbst"])
def test_range_query_ordered_and_complete(s_name, s_cls):
    tm = Multiverse(1)
    s = s_cls(tm)
    keys = random.Random(3).sample(range(10000), 500)
    for k in keys:
        run(tm, lambda tx, k=k: s.insert(tx, k, k), tid=0)
    lo = 2500
    out = run(tm, lambda tx: s.range_query(tx, lo, 100), tid=0)
    expect = sorted(k for k in keys if k >= lo)[:100]
    assert [k for k, _ in out] == expect
    tm.stop()


def test_hashmap_size_query_atomicity():
    tm = Multiverse(2)
    h = HashMap(tm, 64)
    for k in range(100):
        run(tm, lambda tx, k=k: h.insert(tx, k, k), tid=0)
    stop = threading.Event()

    def churn():
        i = 0
        while not stop.is_set():
            # insert+delete one key in ONE txn: size must stay 100
            def txn(tx):
                h.insert(tx, 1000 + (i % 7), 1)
                h.delete(tx, 1000 + (i % 7))
            run(tm, txn, tid=1)
            i += 1

    th = threading.Thread(target=churn)
    th.start()
    try:
        sizes = [run(tm, h.size_query, tid=0) for _ in range(5)]
    finally:
        stop.set()
        th.join()
        tm.stop()
    assert all(sz == 100 for sz in sizes), sizes


def test_abtree_splits_deep_tree():
    tm = Multiverse(1)
    t = ABTree(tm, a=2, b=4)          # tiny fanout -> deep tree
    n = 500
    for k in range(n):
        run(tm, lambda tx, k=k: t.insert(tx, k, -k), tid=0)
    for k in range(0, n, 17):
        assert run(tm, lambda tx, k=k: t.search(tx, k), tid=0) == -k
    out = run(tm, lambda tx: t.range_query(tx, 0, n), tid=0)
    assert [k for k, _ in out] == list(range(n))
    tm.stop()
