"""Shared backend lists + factory for the engine-era test modules.

`tests/test_api_conformance.py` keeps its own private copy by design —
the engine-refactor acceptance criteria pin that file as UNCHANGED, so
it must not grow an import on this helper.  Everything newer
(test_engine.py, test_read_own_writes.py, future conformance suites)
imports from here instead of copy-pasting.
"""
from repro.api import make_tm
from repro.configs.paper_stm import MultiverseParams

WORD_BACKENDS = ["multiverse", "tl2", "dctl", "norec", "tinystm"]
ALL_BACKENDS = WORD_BACKENDS + ["mvstore"]


def make_test_tm(backend, n_threads=2, **kw):
    """A small-table TM tuned for fast deterministic tests."""
    params = MultiverseParams(k1=2, k2=50, k3=50, lock_table_bits=8)
    if backend == "mvstore":
        kw.setdefault("ring_slots", 16)
        kw.setdefault("start_bg", False)
    return make_tm(backend, n_threads, params=params, **kw)
