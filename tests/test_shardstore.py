"""ShardStoreHandle conformance: routing, parity vs the solo store,
per-shard clock independence, cross-shard epoch commits, the sharded
group-commit batcher.

The parity ladder (`-k "shard and parity"` — CI's smoke subset):

  * shard==1 is BIT-IDENTICAL to a solo ``MVStoreHandle`` on the same
    seeded history (routing is the identity, the shard clock IS the
    store clock);
  * shards in {2, 4} produce the SAME final heap as the solo store for
    any sequential history (sharding changes placement, never values);
  * scalar and bulk paths agree with each other across shard counts.

Clock independence is the tentpole's observable: a transaction pinned
BEFORE a commit to a different shard still commits (at one shard the
same schedule aborts), and cross-shard commits tick the coarse epoch
exactly once while ticking each touched shard-local clock exactly once.
"""
import numpy as np
import pytest

from repro.api import make_tm
from repro.api.substrate import Txn
from repro.configs.paper_stm import MultiverseParams
from repro.core.engine import AbortTx
from repro.core.engine.bulkread import shard_partition
from repro.core.engine.groupcommit import ShardedCommitBatcher
from repro.core.shardstore import ShardStoreHandle, shard_devices

SHARD_COUNTS = (1, 2, 4)


def make_store(n_shards, span=8, n_threads=4, **kw):
    params = MultiverseParams(k1=2, k2=50, k3=50, lock_table_bits=8)
    return ShardStoreHandle(n_threads, n_shards=n_shards, span=span,
                            params=params, start_bg=False, **kw)


def make_solo(n_threads=4):
    params = MultiverseParams(k1=2, k2=50, k3=50, lock_table_bits=8)
    return make_tm("mvstore", n_threads, params=params, start_bg=False)


def seeded_history(seed, n_words, n_ops=40):
    """A deterministic mixed scalar/bulk history over [0, n_words)."""
    r = np.random.RandomState(seed)
    ops = []
    for i in range(n_ops):
        kind = r.randint(3)
        if kind == 0:                                  # scalar write
            ops.append(("w", int(r.randint(n_words)), int(r.randint(100))))
        elif kind == 1:                                # bulk rotate
            lo = int(r.randint(n_words - 4))
            ln = int(r.randint(2, min(16, n_words - lo) + 1))
            ops.append(("rot", lo, ln))
        else:                                          # bulk stamp
            lo = int(r.randint(n_words - 4))
            ln = int(r.randint(2, min(16, n_words - lo) + 1))
            ops.append(("stamp", lo, ln, int(r.randint(1000))))
    return ops


def drive(tm, ops, base, n_words, tid=0):
    """Run one op per transaction; return the final full-heap values."""
    def one(tx, op):
        if op[0] == "w":
            tx.write(base + op[1], op[2])
        elif op[0] == "rot":
            lo, ln = op[1], op[2]
            vals = np.asarray(tx.read_bulk(range(base + lo, base + lo + ln)),
                              np.int64)
            tx.write_bulk(range(base + lo, base + lo + ln),
                          np.roll(vals, 1))
        else:
            lo, ln, v = op[1], op[2], op[3]
            tx.write_bulk(range(base + lo, base + lo + ln),
                          np.arange(v, v + ln, dtype=np.int64))
    for op in ops:
        with tm.txn(tid=tid) as tx:
            one(tx, op)
    with tm.txn(tid=tid) as tx:
        return np.asarray(tx.read_bulk(range(base, base + n_words)),
                          np.int64)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def test_route_identity_at_one_shard():
    st = make_store(1, span=8)
    a = np.arange(100, dtype=np.int64)
    sid, local = st._route(a)
    assert (sid == 0).all()
    np.testing.assert_array_equal(local, a)
    st.stop()


@pytest.mark.parametrize("n_shards", (2, 3, 4))
@pytest.mark.parametrize("span", (1, 4, 8))
def test_route_is_a_bijection(n_shards, span):
    st = make_store(n_shards, span=span)
    top = span * n_shards * 5 + (span // 2)
    a = np.arange(top, dtype=np.int64)
    sid, local = st._route(a)
    # (shard, local) pairs are unique and land below the shard's top
    pairs = set(zip(sid.tolist(), local.tolist()))
    assert len(pairs) == top
    for s in range(n_shards):
        lt = st._local_top(s, top)
        assert all(l < lt for sh, l in pairs if sh == s)
    # local tops partition the global heap exactly
    assert sum(st._local_top(s, top) for s in range(n_shards)) == top
    # scalar and vector routing agree
    for addr in (0, span - 1, span, top - 1):
        assert st._route1(addr) == (int(sid[addr]), int(local[addr]))
    st.stop()


def test_shard_partition_covers_in_order():
    parts = shard_partition(np.array([2, 0, 2, 1, 0]), 4)
    assert [s for s, _ in parts] == [0, 1, 2]
    got = sorted(int(i) for _, pos in parts for i in pos)
    assert got == [0, 1, 2, 3, 4]


def test_shard_devices_single_host_is_noop():
    assert shard_devices(3) in ([None, None, None],
                               shard_devices(3))  # deterministic
    assert len(shard_devices(5)) == 5


def test_shard_devices_mesh_round_robin():
    """With an explicit mesh, shards stripe over its device slices
    (launch/sharding.shard_device_slices) and placement is real."""
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    devs = shard_devices(3, mesh=mesh)
    assert len(devs) == 3 and all(d is not None for d in devs)
    st = make_store(2, span=4, mesh=mesh)   # device_put path exercised
    base = st.alloc(16, 1)
    with st.txn(tid=0) as tx:
        tx.write_bulk(range(base, base + 16), np.arange(16))
    assert [st.peek(base + i) for i in range(16)] == list(range(16))
    st.stop()


# ---------------------------------------------------------------------------
# parity: sharded store vs the solo MVStoreHandle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("seed", (0, 1))
def test_shard_parity_seeded_history(n_shards, seed):
    """Same sequential history -> same final heap at every shard count."""
    n_words = 64
    ops = seeded_history(seed, n_words)
    solo = make_solo()
    base_s = solo.alloc(n_words, 7)
    want = drive(solo, ops, base_s, n_words)
    solo.stop()
    st = make_store(n_shards)
    base = st.alloc(n_words, 7)
    got = drive(st, ops, base, n_words)
    st.stop()
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", (0, 3))
def test_shard1_parity_is_bit_identical(seed):
    """shard==1: not just the final heap — the clock and every
    intermediate peek match the solo store step for step."""
    n_words = 48
    ops = seeded_history(seed, n_words, n_ops=25)
    solo, st = make_solo(), make_store(1, span=8)
    bs, bt = solo.alloc(n_words, 7), st.alloc(n_words, 7)
    assert bs == bt == 0

    def step(tm, base, op):
        with tm.txn(tid=0) as tx:
            if op[0] == "w":
                tx.write(base + op[1], op[2])
            elif op[0] == "rot":
                lo, ln = op[1], op[2]
                vals = np.asarray(
                    tx.read_bulk(range(base + lo, base + lo + ln)),
                    np.int64)
                tx.write_bulk(range(base + lo, base + lo + ln),
                              np.roll(vals, 1))
            else:
                lo, ln, v = op[1], op[2], op[3]
                tx.write_bulk(range(base + lo, base + lo + ln),
                              np.arange(v, v + ln, dtype=np.int64))
    for op in ops:
        step(solo, bs, op)
        step(st, bt, op)
        assert st.clocks == (solo.clock,)
        got = [st.peek(bt + i) for i in range(n_words)]
        want = [solo.peek(bs + i) for i in range(n_words)]
        assert got == want
    assert st.epoch == 0          # no cross-shard traffic at one shard
    solo.stop()
    st.stop()


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_shard_parity_bulk_vs_scalar_paths(n_shards):
    """write_bulk over a shard-spanning range == scalar writes."""
    st = make_store(n_shards, span=4)
    base = st.alloc(32, 0)
    vals = np.arange(100, 132, dtype=np.int64)
    with st.txn(tid=0) as tx:
        tx.write_bulk(range(base, base + 32), vals)
    with st.txn(tid=0) as tx:
        got_bulk = np.asarray(tx.read_bulk(range(base, base + 32)),
                              np.int64)
        got_scalar = [tx.read(base + i) for i in range(32)]
    np.testing.assert_array_equal(got_bulk, vals)
    assert got_scalar == vals.tolist()
    assert [st.peek(base + i) for i in range(32)] == vals.tolist()
    st.stop()


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_shard_parity_registry_backend(n_shards):
    """`make_tm("shardstore")` builds the same store the ctor does."""
    tm = make_tm("shardstore", 2,
                 params=MultiverseParams(k1=2, k2=50, k3=50,
                                         lock_table_bits=8),
                 n_shards=n_shards, span=8, start_bg=False)
    assert isinstance(tm, ShardStoreHandle)
    base = tm.alloc(16, 5)
    with tm.txn(tid=0) as tx:
        tx.write_bulk(range(base, base + 16), np.arange(16))
    st = tm.stats()
    assert st["backend"] == "shardstore"
    assert st["n_shards"] == n_shards and st["commits"] == 1
    tm.stop()


# ---------------------------------------------------------------------------
# per-shard clock independence (the tentpole's observable)
# ---------------------------------------------------------------------------


def test_shard_disjoint_commits_do_not_conflict():
    """A txn pinned BEFORE a commit to a DIFFERENT shard still commits;
    the same schedule on one shard aborts.  This is the per-shard clock
    doing its job."""
    st = make_store(2, span=8)
    base = st.alloc(16, 0)                 # words 0-7 -> shard 0, 8-15 -> 1
    tx = st.begin(tid=0)
    tx.write(base + 0, 11)                 # shard 0
    with st.txn(tid=1) as tx2:
        tx2.write(base + 8, 22)            # shard 1 commits in between
    st.commit(tx)                          # must NOT abort
    assert st.peek(base + 0) == 11 and st.peek(base + 8) == 22
    assert st.clocks == (1, 1) and st.epoch == 0
    st.stop()

    solo = make_store(1, span=8)
    base = solo.alloc(16, 0)
    tx = solo.begin(tid=0)
    tx.write(base + 0, 11)
    with solo.txn(tid=1) as tx2:
        tx2.write(base + 8, 22)
    with pytest.raises(AbortTx):
        solo.commit(tx)                    # one shard = one clock: stale
    solo.stop()


def test_shard_same_shard_conflict_still_aborts():
    st = make_store(2, span=8)
    base = st.alloc(16, 0)
    tx = st.begin(tid=0)
    tx.write(base + 1, 1)
    with st.txn(tid=1) as tx2:
        tx2.write(base + 2, 2)             # same shard 0
    with pytest.raises(AbortTx):
        st.commit(tx)
    assert st.stats()["aborts"] == 1
    st.stop()


def test_shard_cross_commit_epoch_and_clocks():
    st = make_store(2, span=4)
    base = st.alloc(16, 0)
    vals = np.arange(50, 66, dtype=np.int64)
    with st.txn(tid=0) as tx:           # spans both shards
        tx.write_bulk(range(base, base + 16), vals)
    assert [st.peek(base + i) for i in range(16)] == vals.tolist()
    assert st.epoch == 1                   # one cross-shard publish
    assert st.clocks == (1, 1)             # each write shard ticked once
    s = st.stats()
    assert s["cross_shard_commits"] == 1 and s["commits"] == 1
    st.stop()


def test_shard_cross_commit_conflict_aborts_all_shards():
    st = make_store(2, span=4)
    base = st.alloc(16, 3)
    tx = st.begin(tid=0)
    tx.write_bulk(range(base, base + 16), np.arange(16))   # both shards
    with st.txn(tid=1) as tx2:
        tx2.write(base + 0, 99)            # stales shard 0's pin
    with pytest.raises(AbortTx):
        st.commit(tx)
    # neither shard published the doomed cross-shard write
    assert st.peek(base + 0) == 99 and st.peek(base + 8) == 3
    assert st.epoch == 0 and st._epoch_seq.load() % 2 == 0
    st.stop()


def test_shard_cross_read_validates_every_touched_shard():
    """Read one shard, write another: the read shard's pin is validated
    under the locks, so a stale read aborts the commit."""
    st = make_store(2, span=4)
    base = st.alloc(16, 3)
    tx = st.begin(tid=0)
    v = tx.read(base + 0)                  # read shard 0
    tx.write(base + 4, v + 1)              # write shard 1
    with st.txn(tid=1) as tx2:
        tx2.write(base + 0, 99)            # invalidate the read
    with pytest.raises(AbortTx):
        st.commit(tx)
    assert st.peek(base + 4) == 3          # write never published
    st.stop()


def test_shard_readonly_commit_needs_no_epoch():
    st = make_store(4, span=4)
    base = st.alloc(32, 9)
    with st.txn(tid=0) as tx:
        got = tx.read_bulk(range(base, base + 32))    # touches all shards
    assert list(got) == [9] * 32
    assert st.epoch == 0 and st.clocks == (0, 0, 0, 0)
    assert st.stats()["ro_commits"] == 1
    st.stop()


def test_shard_snapshot_bulk_pinned_vector():
    st = make_store(2, span=4)
    base = st.alloc(16, 0)
    with st.txn(tid=0) as tx:
        tx.write_bulk(range(base, base + 16), np.arange(16))
    pins = st.clocks                       # the cut right after epoch 1
    vals, ok = st.snapshot_bulk(np.arange(base, base + 16), list(pins))
    assert ok
    np.testing.assert_array_equal(vals, np.arange(16))
    vals, ok = st.snapshot_bulk(np.arange(base, base + 16))   # now
    assert ok
    np.testing.assert_array_equal(vals, np.arange(16))
    st.stop()


def test_shard_alloc_grows_each_local_heap_to_its_top():
    st = make_store(3, span=4)
    st.alloc(10, 1)                        # partial span tail
    st.alloc(30, 2)
    top = 40
    for s in range(3):
        sh = st._shards[s]
        have = int(sh._state.live[sh._key].shape[0])
        assert have == st._local_top(s, top)
    # every global address readable with its init value
    got = [st.peek(a) for a in range(top)]
    assert got[:10] == [1] * 10 and got[10:] == [2] * 30
    st.stop()


# ---------------------------------------------------------------------------
# sharded group commit
# ---------------------------------------------------------------------------


def test_shard_batcher_groups_blind_writers_one_tick():
    st = make_store(2, span=4, n_threads=8)
    base = st.alloc(64, 0)
    b = ShardedCommitBatcher(st)
    # four span-aligned blind writes, all landing on shard 0
    spans = [0, 2, 4, 6]                   # span index k: shard = k % 2
    for t, k in enumerate(spans):
        tx = st.begin(tid=t)
        tx.write_bulk(range(base + 4 * k, base + 4 * k + 4),
                      np.full(4, 100 + t, np.int64))
        b.add(tx)
    ok = b.commit_all()
    assert ok == [True] * 4
    assert b.stats["grouped"] == 4 and b.stats["groups"] == 1
    assert b.stats["failed"] == 0
    assert st.clocks[0] == 1               # ONE tick for the whole group
    for t, k in enumerate(spans):
        assert st.peek(base + 4 * k) == 100 + t
    assert st.stats()["commits"] == 4      # four logical commits
    st.stop()


def test_shard_batcher_readers_and_cross_shard_fall_back_solo():
    st = make_store(2, span=4, n_threads=8)
    base = st.alloc(64, 5)
    b = ShardedCommitBatcher(st)
    tx1 = st.begin(tid=0)                  # has a read: not blind
    v = tx1.read(base + 0)
    tx1.write(base + 0, v + 1)
    tx2 = st.begin(tid=1)                  # spans two shards: not blind
    tx2.write_bulk(range(base, base + 16), np.arange(16))
    b.add(tx1)
    b.add(tx2)
    ok = b.commit_all()
    # neither is blind, so neither groups; tx1 commits solo first, which
    # stales tx2's shard-0 pin — exactly the solo path's semantics
    assert b.stats["grouped"] == 0 and b.stats["solo"] == 2
    assert ok[0] is True
    assert st.peek(base + 0) == 6          # tx1's increment landed
    st.stop()


def test_shard_batcher_overlapping_blind_writers_split():
    st = make_store(2, span=4, n_threads=8)
    base = st.alloc(32, 0)
    b = ShardedCommitBatcher(st)
    for t in range(2):                     # the SAME word: true overlap
        tx = st.begin(tid=t)
        tx.write(base + 0, t + 1)
        b.add(tx)
    ok = b.commit_all()
    assert b.stats["grouped"] == 0         # overlap -> solo, 2nd aborts
    assert ok == [True, False] and b.stats["failed"] == 1
    assert st.peek(base + 0) == 1          # first writer won, no merge
    st.stop()
