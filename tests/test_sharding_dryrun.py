"""Sharding + dry-run machinery on a small in-process mesh.

The production 512-device dry-run runs via launch/dryrun.py in its own
process (XLA device count is locked at first init); here we verify the
same code paths on an 8-device mesh spawned in a subprocess, plus the
mesh/rules/roofline utilities in-process.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch import roofline
from repro.launch.sharding import Rules, default_rules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_rules_mapping():
    import jax
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    r = default_rules(mesh)
    assert r.get("batch") == ("data",)
    assert r.get("tp") is None
    r2 = r.with_(batch=None)
    assert r2.get("batch") is None
    assert r.spec(("batch", None, "tp")) == jax.sharding.PartitionSpec(
        ("data",), None, None)


def test_collective_bytes_parser():
    hlo = textwrap.dedent("""
      ENTRY %main {
        %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
        %ag = bf16[64,64]{1,0} all-gather(%y), replica_groups=[8,2]<=[16]
        %cp = f32[32]{0} collective-permute(%z)
        %dot = f32[8,8]{1,0} dot(%a, %b)
      }
    """)
    out = roofline.collective_bytes(hlo, default_group=4)
    assert out["ops"]["all-reduce"]["count"] == 1
    ar_bytes = 128 * 256 * 4
    assert out["ops"]["all-reduce"]["result_bytes"] == ar_bytes
    assert out["ops"]["all-reduce"]["wire_bytes"] == pytest.approx(
        2 * ar_bytes * 3 / 4)
    assert out["ops"]["all-gather"]["result_bytes"] == 64 * 64 * 2
    assert out["ops"]["collective-permute"]["wire_bytes"] == 32 * 4
    assert len(out["top"]) == 3


def test_roofline_terms_identifies_dominant():
    from repro.configs import get_config, get_shape
    cfg = get_config("qwen2.5-3b")
    shape = get_shape("train_4k")
    terms = roofline.roofline_terms(
        cfg, shape, cost={"flops": 1e14, "bytes accessed": 1e11},
        collectives={"total_wire_bytes": 1e9}, n_chips=256)
    assert terms["dominant"] == "compute"
    assert terms["t_compute_s"] == pytest.approx(1e14 / 197e12)
    assert 0 < terms["roofline_fraction"] <= 1.5


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    """Full lower+compile of train/decode steps on an 8-device host mesh —
    the same compile_once path the 512-device dry-run uses."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import smoke_config, MVStoreConfig, ParallelConfig
        from repro.configs.base import ShapeConfig
        from repro.launch.dryrun import compile_once, cell_rules
        from repro.launch.mesh import make_mesh
        from repro.optim import adamw

        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config("qwen2.5-3b")
        out = {}
        for kind, mv in (("train", "Q"), ("train", "U"), ("decode", "U")):
            shape = ShapeConfig("t", 64, 8, kind)
            pcfg = ParallelConfig(microbatches=2 if kind == "train" else 1,
                                  remat="block" if kind == "train" else "none",
                                  attn_block_q=32, attn_block_k=32)
            rules = cell_rules(mesh, shape, pcfg)
            c, t = compile_once(cfg, shape, mesh, pcfg,
                                MVStoreConfig(enabled=True, mode=mv),
                                adamw.AdamWConfig(), rules)
            ca = c.cost_analysis()
            if isinstance(ca, (list, tuple)):   # jax<=0.4.x: one per device
                ca = ca[0]
            out[f"{kind}_{mv}"] = {"flops": ca.get("flops"),
                                   "mem": c.memory_analysis().temp_size_in_bytes}
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["train_Q"]["flops"] > 0
    # Mode-U versioned commit adds ring writes (more bytes, ~same flops)
    assert out["train_U"]["flops"] >= out["train_Q"]["flops"]
    assert out["decode_U"]["flops"] > 0


def test_tpu_bytes_model_edge_materialization():
    """Edges collapse iff BOTH endpoints are fusable; non-fusable ops
    write their results; params read by anyone count."""
    hlo = textwrap.dedent("""
      %fused_computation.1 {
        %p0 = f32[1024]{0} parameter(0)
        %e = f32[1024]{0} exponential(%p0)
        %m = f32[1024]{0} multiply(%e, %e)
      }
      ENTRY %main {
        %a = f32[128,128]{1,0} parameter(0)
        %b = f32[128,128]{1,0} parameter(1)
        %c = f32[1024]{0} parameter(2)
        %d = f32[128,128]{1,0} dot(%a, %b)
        %big = f32[1024]{0} fusion(%c), kind=kLoop, calls=%fused_computation.1
        %e2 = f32[1024]{0} exponential(%big)
        %add = f32[128,128]{1,0} add(%d, %d)
      }
    """)
    out = roofline.tpu_bytes_model(hlo)
    t = 128 * 128 * 4
    v = 1024 * 4
    # dot: reads a+b (2t) + writes d (t); add reads d twice (2t, add is
    # fusable but producer dot is not); fusion reads param c (v);
    # fusion->exponential edge collapses (both fusable, never read by a
    # non-fusable op). e2's own output is dead (no consumer, not ROOT).
    assert out["tpu_bytes"] == 5 * t + v, out
