"""The snapshot-serving subsystem (repro.serve).

Layers under test:

  * queue: admission control admits under the depth bound, sheds typed
    outcomes (depth / wait budget / closed) and keeps exact counters;
  * reservoir: streaming percentiles are EXACT vs numpy while the
    sample fits, and sane (bounded, deterministic) once it spills;
  * scheduler: continuous batching proper — a freed slot is refilled
    from the queue while the other slot's request keeps decoding (no
    whole-batch drain), and a Mode-Q abort re-pins / eventually fails
    the request (abort-driven shedding);
  * service: the closed-loop occupancy floor the CI smoke job asserts,
    and the e2e open-loop smoke — a Mode-U service under a live
    committing trainer completes requests with ZERO torn reads and
    zero snapshot aborts.
"""
import threading
import time

import numpy as np
import pytest

from repro.serve import (Admission, ContinuousBatchingScheduler, Outcome,
                         PercentileReservoir, Request, RequestQueue,
                         ServeMetrics, ServiceConfig, SnapshotService,
                         StepResult, StoreExecutor, SyntheticTrainer)


# ---------------------------------------------------------------------------
# queue admission control
# ---------------------------------------------------------------------------


def test_queue_admits_then_sheds_on_depth():
    q = RequestQueue(max_depth=2)
    assert q.offer(Request(1)) is Admission.ADMITTED
    assert q.offer(Request(2)) is Admission.ADMITTED
    a = q.offer(Request(3))
    assert a is Admission.SHED_DEPTH and a.shed
    assert q.depth == 2
    assert q.counters == {"offered": 3, "admitted": 2, "shed_depth": 1,
                          "shed_wait": 0, "closed": 0}


def test_queue_sheds_on_wait_budget():
    # 4 queued * 1s est / 1 server = 4s estimated wait >> 0.1s budget
    q = RequestQueue(max_depth=64, wait_budget_s=0.1, est_service_s=1.0)
    assert q.offer(Request(1)) is Admission.ADMITTED  # empty: zero wait
    for rid in (2, 3, 4, 5):
        q.offer(Request(rid))
    assert q.offer(Request(6)) is Admission.SHED_WAIT
    # scheduler feedback drives the estimate down; admission recovers
    for _ in range(60):
        q.note_service_time(0.001)
    assert q.offer(Request(7)) is Admission.ADMITTED


def test_queue_autotune_tightens_budget_under_slow_tail():
    """Injected slow-tail service times must TIGHTEN the effective wait
    budget: a bimodal distribution keeps the EMA low (mean-seeking),
    but the p99 reservoir sees the tail, so the autotuned queue sheds
    an offer a fixed-budget twin would admit."""
    mk = lambda auto: RequestQueue(  # noqa: E731
        max_depth=64, wait_budget_s=0.5, est_service_s=0.01,
        autotune=auto)
    tuned, fixed = mk(True), mk(False)
    for q in (tuned, fixed):
        # fast decodes with mid-stream slow-tail stalls (Mode-Q abort
        # storms); more fast traffic follows, so the mean-seeking EMA
        # forgets the tail while the reservoir keeps it
        for _ in range(60):
            q.note_service_time(0.01)
        for _ in range(5):
            q.note_service_time(2.0)
        for _ in range(60):
            q.note_service_time(0.01)
        for rid in range(3):
            q.offer(Request(rid))
    # EMA forgot the tail; the p99 reservoir did not
    assert tuned.service_ema_s < 0.5 < tuned.service_p99_s
    assert tuned.effective_wait_budget_s < fixed.effective_wait_budget_s
    assert fixed.effective_wait_budget_s == pytest.approx(0.5)
    # depth 3 * p99 2s >> 0.5s budget: autotune sheds, fixed admits
    assert fixed.offer(Request(10)) is Admission.ADMITTED
    assert tuned.offer(Request(10)) is Admission.SHED_WAIT
    # tail drains: fast observations refill the reservoir and the
    # budget relaxes back toward the configured value
    for _ in range(4000):
        tuned.note_service_time(0.01)
    assert tuned.offer(Request(11)) is Admission.ADMITTED


def test_queue_wait_estimate_scales_with_servers():
    one = RequestQueue(max_depth=64, est_service_s=1.0, n_servers=1)
    four = RequestQueue(max_depth=64, est_service_s=1.0, n_servers=4)
    for q in (one, four):
        for rid in range(4):
            q.offer(Request(rid))
    assert one.estimated_wait_s() == pytest.approx(4.0)
    assert four.estimated_wait_s() == pytest.approx(1.0)


def test_queue_close_stops_admission_but_drains():
    q = RequestQueue()
    q.offer(Request(1))
    q.close()
    assert q.offer(Request(2)) is Admission.CLOSED
    assert q.counters["closed"] == 1
    req = q.get()
    assert req is not None and req.rid == 1   # queued work still drains
    assert q.get() is None


def test_queue_stamps_arrival_and_dequeue_times():
    q = RequestQueue()
    req = Request(1)
    q.offer(req, now=10.0)
    assert req.t_arrival == 10.0 and req.t_admitted == 10.0
    out = q.get(now=10.5)
    assert out is req and req.t_dequeued == 10.5
    assert req.queue_wait_s == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# percentile reservoir
# ---------------------------------------------------------------------------


def test_reservoir_exact_below_capacity():
    rng = np.random.default_rng(3)
    xs = rng.lognormal(0.0, 1.0, size=1000)
    r = PercentileReservoir(capacity=4096, seed=0)
    for x in xs:
        r.add(float(x))
    for q in (50, 90, 95, 99):
        assert r.percentile(q) == pytest.approx(
            float(np.percentile(xs, q)), rel=1e-12)
    assert r.mean == pytest.approx(float(xs.mean()), rel=1e-12)


def test_reservoir_estimates_past_capacity():
    # uniform stream, tiny reservoir: estimates stay in-range and the
    # median lands near the true median (loose — it is a sample)
    rng = np.random.default_rng(5)
    xs = rng.uniform(0.0, 100.0, size=20000)
    r = PercentileReservoir(capacity=512, seed=1)
    for x in xs:
        r.add(float(x))
    assert r.count == 20000
    p50 = r.percentile(50)
    assert 0.0 <= p50 <= 100.0
    assert abs(p50 - 50.0) < 15.0
    # deterministic under the same seed
    r2 = PercentileReservoir(capacity=512, seed=1)
    for x in xs:
        r2.add(float(x))
    assert r2.percentile(50) == p50


def test_reservoir_empty_is_nan():
    r = PercentileReservoir()
    assert np.isnan(r.percentile(99)) and np.isnan(r.mean)


# ---------------------------------------------------------------------------
# continuous-batching scheduler (fake executor: no store, no model)
# ---------------------------------------------------------------------------


class FakeExecutor:
    """Deterministic SlotExecutor: token = request id, never aborts
    unless an rid is in ``abort_rids`` at decode time."""

    def __init__(self, n_slots=2, clock=0):
        self.n_slots = n_slots
        self.clock = clock
        self.abort_rids = set()
        self.prefills = []            # (rid, slot, clock) in call order
        self.decode_calls = []        # list of (slots, clocks) per step

    def current_clock(self):
        return self.clock

    def prefill(self, slot, req, clock):
        self.prefills.append((req.rid, slot, clock))
        return StepResult(True, clock, token=req.rid)

    def decode(self, slots, clocks):
        self.decode_calls.append((list(slots), list(clocks)))
        return [StepResult(self._slot_rid(s) not in self.abort_rids,
                           c, token=self._slot_rid(s))
                for s, c in zip(slots, clocks)]

    def _slot_rid(self, slot):
        return self._sched.slots[slot].req.rid


def _make_sched(n_slots=2, max_request_aborts=3):
    q = RequestQueue(max_depth=64)
    ex = FakeExecutor(n_slots=n_slots)
    sched = ContinuousBatchingScheduler(
        q, ex, ServeMetrics(), max_request_aborts=max_request_aborts)
    ex._sched = sched
    return q, ex, sched


def test_scheduler_refills_freed_slot_without_draining_batch():
    """The continuous-batching property: request 1 (short) finishes,
    its slot takes request 3 from the queue on the very next step,
    while request 2 (long) keeps decoding uninterrupted."""
    q, ex, sched = _make_sched(n_slots=2)
    r1 = Request(1, max_new=2)
    r2 = Request(2, max_new=6)
    r3 = Request(3, max_new=2)
    for r in (r1, r2, r3):
        q.offer(r)
    sched.step()                      # prefill r1+r2 (r3 queued), decode
    assert r1.outcome is Outcome.COMPLETED      # 2 tokens: prefill+decode
    assert r2.outcome is Outcome.PENDING
    sched.step()                      # r3 prefills INTO r1's freed slot
    assert (3, 0, 0) in ex.prefills   # rid 3, slot 0
    assert r2.outcome is Outcome.PENDING        # r2 never drained
    # r2's decode stream never paused: it is in every decode call
    assert all(1 in slots for slots, _ in ex.decode_calls)
    while r2.outcome is Outcome.PENDING or r3.outcome is Outcome.PENDING:
        sched.step()
    assert r2.tokens == [2] * 6 and r3.tokens == [3] * 2
    assert sched.metrics.completed == 3


def test_scheduler_pins_clock_at_prefill():
    q, ex, sched = _make_sched(n_slots=1)
    r1 = Request(1, max_new=3)
    q.offer(r1)
    ex.clock = 7
    sched.step()
    ex.clock = 9                      # store moves on; pin must not
    sched.step()
    assert r1.pinned_clock == 7
    assert ex.decode_calls[-1][1] == [7]
    assert r1.served_clocks == [7, 7, 7][: len(r1.served_clocks)]


def test_scheduler_abort_repins_then_fails_request():
    """A snapshot abort discards progress and re-pins at a fresh clock;
    max_request_aborts converts persistent aborts into a typed failure
    (abort-driven shedding)."""
    q, ex, sched = _make_sched(n_slots=1, max_request_aborts=2)
    r1 = Request(1, max_new=4)
    q.offer(r1)
    ex.clock = 5
    sched.step()                      # prefill at 5, decode ok
    assert r1.tokens == [1, 1]
    ex.abort_rids.add(1)
    ex.clock = 6
    sched.step()                      # decode aborts: progress discarded
    assert r1.aborts == 1 and r1.tokens == [] and r1.pinned_clock == -1
    sched.step()                      # re-prefill at 6, decode aborts again
    assert r1.pinned_clock == 6
    assert r1.outcome is Outcome.FAILED_ABORTS
    assert sched.metrics.failed_aborts == 1
    assert sched.metrics.snapshot_aborts == 2
    assert sched.slots == [None]


def test_scheduler_drain_finishes_inflight_and_closes_queue():
    q, ex, sched = _make_sched(n_slots=2)
    reqs = [Request(i, max_new=3) for i in range(1, 6)]
    for r in reqs:
        q.offer(r)
    assert sched.run_until_drained(timeout_s=5.0)
    assert all(r.outcome is Outcome.COMPLETED for r in reqs)
    assert q.offer(Request(99)) is Admission.CLOSED


class DyingExecutor(FakeExecutor):
    """Aborts everything until the crash step, then dies for real."""

    def __init__(self, n_slots=2, die_after_decodes=2):
        super().__init__(n_slots=n_slots)
        self.die_after_decodes = die_after_decodes

    def decode(self, slots, clocks):
        if len(self.decode_calls) + 1 >= self.die_after_decodes:
            self.decode_calls.append((list(slots), list(clocks)))
            raise RuntimeError("executor died mid-decode")
        return super().decode(slots, clocks)


def test_scheduler_crash_drain_sweeps_slots_then_reraises():
    """Pinned: an executor crash inside run_until_drained leaves NO slot
    half-served.  In-flight requests below the abort cap are re-admitted
    (progress discarded, decode state reset, one abort charged); those
    at the cap are FAILED; the crash still propagates; and a later drain
    over the same scheduler completes the survivors."""
    q = RequestQueue(max_depth=64)
    ex = DyingExecutor(n_slots=2, die_after_decodes=2)
    sched = ContinuousBatchingScheduler(
        q, ex, ServeMetrics(), max_request_aborts=3)
    ex._sched = sched
    r1 = Request(1, max_new=6)
    r2 = Request(2, max_new=6)
    r2.aborts = 2                      # one more abort hits the cap
    q.offer(r1)
    q.offer(r2)
    try:
        sched.run_until_drained(timeout_s=5.0)
        raise AssertionError("crash did not propagate")
    except RuntimeError as e:
        assert "died mid-decode" in str(e)
    # r2 was at the cap: swept to FAILED with complete accounting
    assert r2.outcome is Outcome.FAILED_ABORTS
    # r1 survives: re-admitted with stale state fully discarded
    assert r1.outcome is Outcome.PENDING
    assert r1.aborts == 1 and r1.tokens == [] and r1.pinned_clock == -1
    assert r1.served_clocks == []
    slot = sched.slots[0]
    assert slot is not None and not slot.decoding and slot.produced == 0
    assert sched.metrics.snapshot_aborts >= 2
    # post-recovery drain (executor healthy again) finishes r1
    sched.executor = healthy = FakeExecutor(n_slots=2)
    healthy.clock = 11
    healthy._sched = sched
    assert sched.run_until_drained(timeout_s=5.0)
    assert r1.outcome is Outcome.COMPLETED
    assert r1.pinned_clock == 11       # re-pinned at the fresh clock
    assert r1.tokens == [1] * 6


# ---------------------------------------------------------------------------
# service: occupancy floor + e2e under a committing trainer
# ---------------------------------------------------------------------------


def test_closed_loop_occupancy_floor():
    """With a 4x-slot backlog the scheduler must keep the slot pool
    busy: occupancy (active slot-steps / total slot-steps) stays above
    0.5.  The CI smoke job runs this as its scheduler-health assertion."""
    cfg = ServiceConfig(mode="U", n_slots=4, max_new=6, work_s=0.0,
                        commit_interval_s=3600.0)  # no commits mid-run
    svc = SnapshotService.synthetic(cfg)
    row = svc.serve_requests([None] * (4 * cfg.n_slots))
    assert row["completed"] == 16
    assert row["occupancy"] >= 0.5
    assert row["violations"] == 0


def test_e2e_mode_u_zero_torn_reads_under_live_commits():
    """The subsystem's reason to exist: a Mode-U service completes N
    requests while the trainer commits every few ms — no torn reads,
    no snapshot aborts, every request served from one pinned version."""
    cfg = ServiceConfig(mode="U", n_slots=4, max_new=6, work_s=0.0005,
                        commit_interval_s=0.002, ring_slots=8,
                        target_qps=200.0, duration_s=0.4)
    svc = SnapshotService.synthetic(cfg)
    row = svc.run_open_loop()
    assert row["drained"]
    assert row["completed"] >= 10
    assert row["violations"] == 0
    assert row["snapshot_aborts"] == 0 and row["failed_aborts"] == 0
    assert row["trainer_commits"] > 0
    assert row["stm_stats"]["commits"] == row["completed"]


def test_mode_u_commit_between_steps_never_aborts_deterministically():
    """Deterministic Mode-U twin of the threaded e2e smoke: drive the
    scheduler by hand and commit between EVERY decode step — the pinned
    ring version keeps serving (zero aborts, one version per request)
    no matter how many commits land mid-request.  Same property the
    threaded test asserts, with the trainer race replaced by explicit
    interleaving."""
    trainer = SyntheticTrainer(mode="U", commit_interval_s=3600.0,
                               ring_slots=8)
    metrics = ServeMetrics()
    ex = StoreExecutor(lambda: trainer.state, policy="U", n_slots=1,
                       work_s=0.0, metrics=metrics)
    q = RequestQueue()
    sched = ContinuousBatchingScheduler(q, ex, metrics,
                                        max_request_aborts=8)
    r = Request(1, max_new=6)
    q.offer(r)
    sched.step()                      # prefill pins a ring version
    pinned = r.pinned_clock
    while r.outcome is Outcome.PENDING:
        trainer.commit_once()         # a commit between every step
        sched.step()
        assert r.pinned_clock in (pinned, -1)   # never re-pins mid-flight
    assert r.outcome is Outcome.COMPLETED
    assert r.aborts == 0 and metrics.snapshot_aborts == 0
    assert metrics.violations == 0


def test_mode_q_commit_between_steps_aborts_deterministically():
    """Deterministic Mode-Q abort (no thread races): drive the scheduler
    by hand and commit between decode steps — the pinned snapshot fails
    validation and the request restarts at the new clock."""
    trainer = SyntheticTrainer(mode="Q", commit_interval_s=3600.0)
    metrics = ServeMetrics()
    ex = StoreExecutor(lambda: trainer.state, policy="Q", n_slots=1,
                       work_s=0.0, metrics=metrics)
    q = RequestQueue()
    sched = ContinuousBatchingScheduler(q, ex, metrics,
                                        max_request_aborts=8)
    r = Request(1, max_new=4)
    q.offer(r)
    sched.step()                      # prefill at clock 0, one decode ok
    pinned0 = r.pinned_clock
    trainer.commit_once()             # invalidates the pinned snapshot
    sched.step()                      # decode at stale pin: abort
    assert r.aborts == 1 and r.pinned_clock == -1
    sched.step()                      # re-pin at the new clock
    assert r.pinned_clock == int(trainer.state.clock) > pinned0
    while r.outcome is Outcome.PENDING:
        sched.step()
    assert r.outcome is Outcome.COMPLETED
    assert metrics.snapshot_aborts == 1


def test_unversioned_baseline_mixes_versions_across_steps():
    """The 'live' policy never aborts — it silently serves different
    parameter versions across one request's steps (the failure mode
    ``mixed_version_requests`` reports)."""
    trainer = SyntheticTrainer(mode="U", commit_interval_s=3600.0)
    metrics = ServeMetrics()
    ex = StoreExecutor(lambda: trainer.state, policy="live", n_slots=1,
                       work_s=0.0, metrics=metrics)
    q = RequestQueue()
    sched = ContinuousBatchingScheduler(q, ex, metrics)
    r = Request(1, max_new=3)
    q.offer(r)
    sched.step()
    trainer.commit_once()
    while r.outcome is Outcome.PENDING:
        sched.step()
    assert r.outcome is Outcome.COMPLETED
    assert r.mixed_versions
    assert metrics.mixed_version_requests == 1
    assert metrics.snapshot_aborts == 0
