"""Engine-layer unit tests: descriptor lifecycle, policy plumbing, the
array lock table, scalar-vs-bulk validation parity, `Txn.validate_bulk`,
and the retry-exhaustion safety net (lock release + retire-buffer flush).
"""
import numpy as np
import pytest

from _backends import ALL_BACKENDS, WORD_BACKENDS, make_test_tm as _make
from repro.api import AbortTx, MaxRetriesExceeded, run
from repro.configs.paper_stm import MultiverseParams
from repro.core.baselines import BASELINES
from repro.core.engine import (
    ArrayHeap,
    ArrayLockTable,
    PolicyBase,
    TransactionEngine,
    TxnDescriptor,
    V_EQ,
    V_LE,
    V_LT,
)
from repro.core.engine import validation as V
from repro.core.locks import LockState, LockTable
from repro.core.stm import Multiverse


# ---------------------------------------------------------------------------
# descriptor lifecycle
# ---------------------------------------------------------------------------


def test_descriptor_reset_scopes():
    d = TxnDescriptor(3)
    d.read_set.append((1, 2))
    d.undo[5] = "old"
    d.attempts = 4
    d.versioned = True
    d.no_versioning = True
    d.reset()                      # per-attempt: sets cleared, op state kept
    assert d.read_set == [] and d.undo == {} and d.write_map == {}
    assert d.attempts == 4 and d.versioned and d.no_versioning
    d.reset_operation()            # per-operation: retry state cleared
    assert d.attempts == 0 and not d.versioned and not d.no_versioning
    assert d.initial_versioned_ts is None


def test_every_word_backend_is_a_policy_over_the_engine():
    tms = [Multiverse(1, start_bg=False)] + [cls(1)
                                             for cls in BASELINES.values()]
    for tm in tms:
        assert isinstance(tm, TransactionEngine), type(tm)
        assert isinstance(tm.policy, PolicyBase), type(tm.policy)
        tm.stop()


# ---------------------------------------------------------------------------
# array lock table: packed-word semantics == list-of-namedtuple semantics
# ---------------------------------------------------------------------------


def test_array_lock_table_matches_lock_table_semantics():
    for lt in (LockTable(8), ArrayLockTable(8)):
        idx = lt.index(1234)
        st = lt.read(idx)
        assert lt.validate(st, r_clock=1, tid=0)
        assert lt.try_lock(idx, st, tid=3)
        held = lt.read(idx)
        assert held.locked and held.tid == 3 and not held.flag
        assert not lt.validate(held, r_clock=10, tid=0)
        assert lt.validate(held, r_clock=10, tid=3)
        lt.unlock(idx, version=9)
        st = lt.read(idx)
        assert not st.locked and st.version == 9
        assert not lt.validate(st, r_clock=9, tid=0)
        st = lt.lock_and_flag(idx, tid=-2)       # background-thread tid
        assert st.version == 9
        flagged = lt.read(idx)
        assert flagged.flag and flagged.locked and flagged.tid == -2
        lt.unlock(idx)
        assert lt.read(idx).version == 9


def test_array_lock_table_gather_and_held_by():
    lt = ArrayLockTable(6)
    st0 = lt.read(0)
    assert lt.try_lock(0, st0, tid=2)
    lt.store(5, LockState(False, 17, -1, False))
    lt.store(9, LockState(True, 4, 2, True))
    ver, own, meta = lt.gather(np.array([0, 5, 9]))
    assert list(ver) == [0, 17, 4]
    assert list(own) == [2, -1, 2]
    assert list(meta) == [1, 0, 3]               # bit0 locked, bit1 flag
    assert sorted(lt.held_by(2)) == [0, 9]
    assert list(lt.held_by(7)) == []


def test_array_heap_growth_and_indexing():
    h = ArrayHeap(capacity=2)
    base = h.alloc(5, 7)
    assert [h[base + i] for i in range(5)] == [7] * 5
    b2 = h.alloc(2000, 1)                        # forces buffer doubling
    h[b2 + 1999] = 42
    assert h[b2 + 1999] == 42 and len(h) == 2005
    with pytest.raises(IndexError):
        h[len(h)]
    assert h.jnp().shape == (2005,)


# ---------------------------------------------------------------------------
# scalar vs bulk validation parity (all three predicates)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", [V_LT, V_LE, V_EQ])
def test_bulk_validation_matches_scalar(mode):
    lt = ArrayLockTable(10)
    rng = np.random.default_rng(mode)
    for idx in rng.integers(0, 1 << 10, 300):
        lt.store(int(idx), LockState(
            bool(rng.integers(2)), int(rng.integers(0, 40)),
            int(rng.integers(-2, 4)), bool(rng.integers(2))))
    read_set = [(int(i), int(rng.integers(0, 40)))
                for i in rng.integers(0, 1 << 10, 2000)]
    for r_clock, tid in [(0, 0), (20, 1), (39, -1)]:
        scalar = V.revalidate_scalar(lt, read_set, r_clock, tid, mode)
        bulk = V.revalidate_bulk(lt, read_set, r_clock, tid, mode)
        assert scalar == bulk
        # dispatcher: large read sets take the bulk path, small the scalar
        assert V.revalidate(lt, read_set, r_clock, tid, mode) == scalar
        assert V.revalidate(lt, read_set[:3], r_clock, tid, mode) == \
            V.revalidate_scalar(lt, read_set[:3], r_clock, tid, mode)


def test_bulk_validation_none_without_gather():
    lt = LockTable(4)                            # no gather(): bulk opts out
    assert V.revalidate_bulk(lt, [(0, 0)], 1, 0, V_LT) is None
    assert V.revalidate(lt, [(0, 0)], 1, 0, V_LT) is True


# ---------------------------------------------------------------------------
# Txn.validate_bulk through the API (both layers)
# ---------------------------------------------------------------------------


def _begin_with_reads(tm, base, n, tid=0):
    """Begin a txn and read n addresses, retrying begin-time aborts (the
    deferred clock can make the very first read of a fresh table abort)."""
    for _ in range(30):
        tx = tm.begin(tid)
        try:
            for i in range(n):
                tx.read(base + i)
            return tx
        except AbortTx:
            continue
    raise RuntimeError("could not establish a clean read snapshot")


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_validate_bulk_goes_stale_after_concurrent_commit(backend):
    tm = _make(backend)
    base = tm.alloc(4, 0)
    run(tm, lambda tx: tx.write(base, 0), tid=0)     # warm the clock
    tx = _begin_with_reads(tm, base, 4, tid=0)
    assert tx.validate_bulk()                        # fresh: consistent
    run(tm, lambda tx2: tx2.write(base + 1, 99), tid=1)
    assert not tx.validate_bulk()                    # stale: writer won
    tm.abort(tx)
    tm.stop()


@pytest.mark.parametrize("backend", WORD_BACKENDS)
def test_validate_bulk_large_readset_routes_through_bulk(backend):
    n = max(V.BULK_MIN * 2, 600)
    tm = _make(backend)
    base = tm.alloc(n, 1)
    run(tm, lambda tx: tx.write(base, 1), tid=0)
    tx = _begin_with_reads(tm, base, n, tid=0)
    assert len(getattr(tx._ctx, "read_set", [])) >= 0  # norec uses read_vals
    assert tx.validate_bulk()
    run(tm, lambda tx2: tx2.write(base + n // 2, -5), tid=1)
    assert not tx.validate_bulk()
    tm.abort(tx)
    tm.stop()


# ---------------------------------------------------------------------------
# retry-exhaustion safety net (MaxRetriesExceeded must not wedge the TM)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", WORD_BACKENDS)
def test_retries_exhausted_releases_leaked_locks(backend):
    """A capped operation force-releases anything its thread still holds:
    later writers (other tids) must not spin/abort forever on its locks."""
    tm = _make(backend)
    a = tm.alloc(2, 0)
    raw = tm.raw
    idx = raw.locks.index(a)
    st = raw.locks.read(idx)
    assert raw.locks.try_lock(idx, st, 0)        # simulate a wedged tid-0 op

    def always_abort(tx):
        raise AbortTx()

    with pytest.raises(MaxRetriesExceeded):
        run(tm, always_abort, tid=0, max_retries=3)
    assert not raw.locks.read(idx).locked        # exhaustion cleanup ran
    run(tm, lambda tx: tx.write(a, 5), tid=1, max_retries=50)
    got = run(tm, lambda tx: tx.read(a), tid=1)
    tm.stop()
    assert got == 5


def test_retries_exhausted_flushes_multiverse_retire_buffer():
    tm = Multiverse(2, MultiverseParams(lock_table_bits=6), start_bg=False)
    from repro.core.vlt import VListNode
    buf = tm.policy._retire_bufs[0]
    pending = VListNode(None, 1, "p", False)
    on_abort = VListNode(None, 1, "a", False)
    buf.retire_on_commit(pending)                # would leak if unflushed
    buf.retire_on_abort(on_abort)
    tm.ebr.pin(0)                                # simulate a wedged pin
    tm.on_retries_exhausted(0)
    assert buf._pending == [] and buf._on_abort == []
    assert tm.ebr.limbo_size == 1                # abort-retire landed in EBR
    assert tm.ebr._thread_epochs[0] == -1        # unpinned: reclaim can run
    tm.stop()


def test_release_thread_locks_bumps_clock():
    tm = BASELINES["dctl"](2)
    a = tm.alloc(1, 0)
    idx = tm.locks.index(a)
    assert tm.locks.try_lock(idx, tm.locks.read(idx), 0)
    before = tm.clock.load()
    assert tm.release_thread_locks(0) == 1
    st = tm.locks.read(idx)
    assert not st.locked and st.version == before + 1
    assert tm.release_thread_locks(0) == 0       # idempotent, no extra bump
    assert tm.clock.load() == before + 1
    tm.stop()


def test_tl2_mid_commit_exception_releases_commit_time_locks():
    """A non-AbortTx failure inside commit-time validation (e.g. a kernel
    lowering error on the bulk path) must not leak the write locks TL2
    acquired at commit — they are invisible to rollback, so the commit
    pipeline itself owns their release."""
    tm = BASELINES["tl2"](2)
    a = tm.alloc(2, 0)

    boom = RuntimeError("bulk validator exploded")
    original = tm.revalidate

    def exploding_revalidate(d, *args, **kw):
        raise boom

    tx = tm.begin(0)
    tx.read(a)
    tx.write(a + 1, 5)
    tm.revalidate = exploding_revalidate
    try:
        with pytest.raises(RuntimeError):
            tm._try_commit(tx._ctx)
    finally:
        tm.revalidate = original
    idx = tm.locks.index(a + 1)
    assert not tm.locks.read(idx).locked     # commit-time lock released
    tm._abort(tx._ctx)
    run(tm, lambda t: t.write(a + 1, 9), tid=1, max_retries=50)
    assert tm.peek(a + 1) == 9               # later writers not wedged
    tm.stop()
