"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, output shapes + finiteness (assignment SSarch)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SMOKE_SHAPE, ParallelConfig,
                           smoke_config)
from repro.models import model_zoo as zoo

PCFG = ParallelConfig(attn_block_q=16, attn_block_k=16, remat="block")


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, key):
    cfg = smoke_config(arch)
    params = zoo.init_params(cfg, key)
    batch = zoo.concrete_batch(cfg, SMOKE_SHAPE, key)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: zoo.loss_fn(p, batch, cfg, PCFG)))(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gleaves = jax.tree.leaves(grads)
    pleaves = jax.tree.leaves(params)
    assert len(gleaves) == len(pleaves)
    for g, p in zip(gleaves, pleaves):
        assert g.shape == p.shape
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in gleaves)
    assert np.isfinite(gn) and gn > 0    # gradients flow to every layer


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch, key):
    cfg = smoke_config(arch)
    params = zoo.init_params(cfg, key)
    B, L = 2, 32
    cache = zoo.init_cache(cfg, B, L, jnp.bfloat16)
    clen = jnp.full((B,), L - 1, jnp.int32)
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache2, clen2 = zoo.decode_fn(params, cache, clen, tok, cfg,
                                          PCFG)
    vpad = cfg.padded_vocab()
    assert logits.shape == (B, vpad)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(clen2[0]) == L
    # cache tree structure is preserved
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(cache2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_consistent(arch, key):
    """Prefill + one decode == forward over the extended sequence (greedy
    token equality; bf16 tolerance via top-1 check on a tiny model)."""
    cfg = smoke_config(arch)
    params = zoo.init_params(cfg, key)
    batch = zoo.concrete_batch(cfg, SMOKE_SHAPE, key)
    logits, cache, clen = zoo.prefill_fn(params, batch, cfg, PCFG)
    assert logits.shape[0] == SMOKE_SHAPE.global_batch
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # pad cache so the decode append fits
    def pad(x):
        if x.ndim >= 3 and x.shape[-2] == SMOKE_SHAPE.seq_len:
            pads = [(0, 0)] * x.ndim
            pads[-2] = (0, 8)
            return jnp.pad(x, pads)
        return x
    # (only attention caches carry a seq dim == seq_len)
    cache = jax.tree.map(
        lambda x: _pad_seq_leaf(x, SMOKE_SHAPE.seq_len, 8), cache)
    logits2, cache2, clen2 = zoo.decode_fn(params, cache, clen, tok, cfg,
                                           PCFG)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def _pad_seq_leaf(x, seq_len, extra):
    import jax.numpy as jnp
    for ax in range(x.ndim):
        if x.shape[ax] == seq_len and ax >= 1:
            pads = [(0, 0)] * x.ndim
            pads[ax] = (0, extra)
            return jnp.pad(x, pads)
    return x


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_sane(arch):
    """The FULL configs are exercised via the dry-run only; here we check
    the meta tree's parameter count is in the right ballpark for the
    arch's nameplate size."""
    from repro.configs import get_config
    cfg = get_config(arch)
    counts = zoo.param_counts(cfg)
    expected = {
        "jamba-v0.1-52b": (45e9, 60e9),
        "paligemma-3b": (2e9, 4e9),
        "qwen2.5-3b": (2.5e9, 4.5e9),
        "deepseek-7b": (6e9, 8e9),
        "mistral-large-123b": (115e9, 130e9),
        "minitron-4b": (3.5e9, 6e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "llama4-scout-17b-a16e": (95e9, 120e9),   # 16e x 5120x1408... total
        # the assigned 48L x 64e x d_ff 1408 config totals ~28B; the
        # nameplate 'A3B' matches the ACTIVE count (~3.6B), checked below
        "moonshot-v1-16b-a3b": (25e9, 30e9),
        "seamless-m4t-medium": (0.8e9, 1.6e9),
    }[arch]
    assert expected[0] <= counts["total"] <= expected[1], counts
    assert counts["active"] <= counts["total"]
    if arch == "moonshot-v1-16b-a3b":
        assert 3e9 <= counts["active"] <= 4.5e9     # 'A3B'
    if arch == "llama4-scout-17b-a16e":
        assert 15e9 <= counts["active"] <= 19e9     # '17B active'
