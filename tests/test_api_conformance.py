"""Substrate-conformance suite: ONE workload, every backend, one contract.

Runs the quickstart transfer + long-running audit through `make_tm(...)`
for all five word-level backends plus the Layer-B `MVStoreHandle`,
asserting (a) no torn reads, (b) the normalized stats schema everywhere,
(c) the deprecation shim still works, and (d) the paper's separation —
versioned substrates commit the mid-read-interleaved audit, unversioned
ones starve — through the SAME API on BOTH layers.
"""
import threading
import time
import warnings

import pytest

from repro.api import (AbortTx, MaxRetriesExceeded, STATS_KEYS, Txn,
                       atomic, backend_names, make_tm, run)
from repro.configs.paper_stm import MultiverseParams

WORD_BACKENDS = ["multiverse", "tl2", "dctl", "norec", "tinystm"]
ALL_BACKENDS = WORD_BACKENDS + ["mvstore"]


def _make(backend, n_threads=3, **kw):
    params = MultiverseParams(k1=2, k2=50, k3=50, lock_table_bits=8)
    if backend == "mvstore":
        kw.setdefault("ring_slots", 16)
        kw.setdefault("start_bg", False)
    return make_tm(backend, n_threads, params=params, **kw)


# ---------------------------------------------------------------------------
# transfer + audit (the quickstart workload) on every backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_transfer_audit_no_torn_reads(backend):
    n, initial = 32, 100
    tm = _make(backend)
    base = tm.alloc(n, initial)

    @atomic(tm)
    def transfer(tx, src, dst, amt):
        a = tx.read(base + src)
        b = tx.read(base + dst)
        tx.write(base + src, a - amt)
        tx.write(base + dst, b + amt)

    for i in range(40):
        src, dst = i % n, (i * 13 + 7) % n
        if src != dst:
            transfer(src, dst, 5, tid=i % 2)

    total = run(tm, lambda tx: sum(tx.read(base + i) for i in range(n)),
                tid=2)
    st = tm.stats()
    tm.stop()
    assert total == n * initial
    assert st["commits"] >= 35
    assert st["ro_commits"] >= 1


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_concurrent_transfer_audit_invariant(backend):
    """Threaded transfers + audits: the bank invariant must hold on every
    substrate (baselines may retry, but a committed audit is consistent)."""
    n, initial = 24, 50
    tm = _make(backend)
    base = tm.alloc(n, initial)
    stop = threading.Event()
    errors = []

    @atomic(tm)
    def transfer(tx, src, dst):
        a = tx.read(base + src)
        b = tx.read(base + dst)
        tx.write(base + src, a - 1)
        tx.write(base + dst, b + 1)

    def worker(tid):
        i = 0
        try:
            while not stop.is_set():
                src, dst = i % n, (i * 7 + 3) % n
                if src != dst:
                    transfer(src, dst, tid=tid)
                i += 1
        except Exception as e:  # pragma: no cover - fails the test below
            errors.append(repr(e))

    ths = [threading.Thread(target=worker, args=(t,)) for t in (0, 1)]
    [t.start() for t in ths]
    sums = []
    deadline = time.time() + 2.0
    while time.time() < deadline and len(sums) < 10:
        sums.append(run(tm, lambda tx: sum(tx.read(base + i)
                                           for i in range(n)), tid=2))
    stop.set()
    [t.join() for t in ths]
    tm.stop()
    assert not errors, errors
    assert sums and all(s == n * initial for s in sums), sums


# ---------------------------------------------------------------------------
# the paper's separation, deterministically, via one API on both layers
# ---------------------------------------------------------------------------


def _audit_with_mid_read_commit(tm, base, n, max_retries):
    """Long read; a dedicated updater commits between its two halves,
    touching both, so every unversioned TM must abort every attempt."""

    @atomic(tm, tid=1)
    def upd(tx):
        tx.write(base, tx.read(base) + 1)
        tx.write(base + n - 1, tx.read(base + n - 1) + 1)

    def audit(tx):
        first = [tx.read(base + i) for i in range(n // 2)]
        upd()
        rest = [tx.read(base + i) for i in range(n // 2, n)]
        return sum(first) + sum(rest)

    return run(tm, audit, tid=0, max_retries=max_retries)


@pytest.mark.parametrize("backend", ["multiverse", "mvstore"])
def test_versioned_substrates_commit_long_audit(backend):
    n = 16
    tm = _make(backend, n_threads=2)
    base = tm.alloc(n, 1)
    total = _audit_with_mid_read_commit(tm, base, n, max_retries=30)
    st = tm.stats()
    tm.stop()
    # a consistent snapshot: n plus 2 per fully-included updater commit
    assert total >= n and (total - n) % 2 == 0
    assert st["versioned_commits"] > 0          # the versioned path did it


@pytest.mark.parametrize("backend", ["tl2", "dctl", "norec", "tinystm"])
def test_unversioned_substrates_starve_long_audit(backend):
    n = 16
    tm = _make(backend, n_threads=2)
    base = tm.alloc(n, 1)
    with pytest.raises(MaxRetriesExceeded):
        _audit_with_mid_read_commit(tm, base, n, max_retries=10)
    tm.stop()


# ---------------------------------------------------------------------------
# stats schema / registry / shim / handle plumbing
# ---------------------------------------------------------------------------


def test_stats_schema_identical_across_backends():
    key_sets = {}
    for backend in ALL_BACKENDS:
        tm = _make(backend, n_threads=1)
        a = tm.alloc(1, 0)
        run(tm, lambda tx: tx.write(a, 1), tid=0)
        key_sets[backend] = frozenset(tm.stats())
        tm.stop()
    assert set(key_sets.values()) == {frozenset(STATS_KEYS)}, key_sets


def test_registry_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown backend"):
        make_tm("no-such-tm", 1)
    assert set(ALL_BACKENDS) <= set(backend_names())


def test_stm_run_shim_still_works_and_warns():
    from repro.core import stm
    tm = stm.Multiverse(1, start_bg=False)
    a = tm.alloc(1, 0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = stm.run(tm, lambda tx: (tx.write(a, 7), tx.read(a))[1], tid=0)
    tm.stop()
    assert out == 7 and tm.peek(a) == 7
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_user_errors_roll_back_and_do_not_poison(backend):
    tm = _make(backend, n_threads=1)
    a = tm.alloc(1, 0)

    def bad(tx):
        tx.write(a, 99)
        raise RuntimeError("user bug")

    with pytest.raises(RuntimeError):
        run(tm, bad, tid=0)
    assert tm.peek(a) == 0               # the write was rolled back
    # TM not poisoned: the same thread can run transactions again, both
    # through run() and through the single-attempt context manager
    # (which may surface AbortTx once on deferred-clock backends)
    for _ in range(10):
        try:
            with tm.txn(tid=0) as tx:
                tx.write(a, 1)
            break
        except AbortTx:
            continue
    got = run(tm, lambda tx: tx.read(a), tid=0)
    tm.stop()
    assert got == 1


def test_atomic_decorator_returns_value_and_overrides_tid():
    tm = _make("multiverse", n_threads=2)
    a = tm.alloc(2, 0)

    @atomic(tm)
    def put(tx, i, v):
        tx.write(a + i, v)
        return v * 10

    assert put(0, 3) == 30
    assert put(1, 4, tid=1) == 40
    vals = run(tm, lambda tx: (tx.read(a), tx.read(a + 1)), tid=0)
    tm.stop()
    assert vals == (3, 4)


def test_txn_handles_are_uniform_type():
    for backend in ALL_BACKENDS:
        tm = _make(backend, n_threads=1)
        tm.alloc(1, 0)
        with tm.txn(tid=0) as tx:
            assert isinstance(tx, Txn)
            assert tx.read_count == 0
        tm.stop()


def test_mvstore_snapshot_is_a_read_only_txn():
    """Layer-B parity: the functional mv_snapshot view and a read-only
    transaction through the API observe the same committed state."""
    tm = _make("mvstore", n_threads=1)
    base = tm.alloc(8, 5)

    @atomic(tm)
    def bump(tx, i):
        tx.write(base + i, tx.read(base + i) + i)

    for i in range(8):
        bump(i)
    via_txn = run(tm, lambda tx: [tx.read(base + i) for i in range(8)],
                  tid=0)
    view, ok = tm.snapshot()
    import numpy as np
    via_snapshot = np.asarray(view["heap"])[base:base + 8].tolist()
    tm.stop()
    assert bool(ok)
    assert via_txn == via_snapshot == [5 + i for i in range(8)]
