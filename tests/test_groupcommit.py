"""Group commit + fused commit megakernel (PR 7).

Assurance layers, mirroring ``tests/test_commit_bulk.py``'s ladder:

  * packing: the ragged segment-offset layout round-trips exactly
    (``pack_segments`` offsets slice back to the inputs);
  * constants: the kernel-side MODE_* selectors are pinned equal to the
    engine's V_* validation modes (the kernels stay engine-import-free,
    so the mirror is enforced here);
  * kernel: the fused Pallas megakernel agrees with its in-file numpy
    twin element-for-element across modes, ragged batches and failed
    members — and beyond-int32 payloads route to the twin with exact
    int64 release words;
  * grouping: ``partition_disjoint`` enforces the
    ``write_i ∩ (read_j ∪ write_j) = ∅`` conflict rule (read-read
    sharing allowed, within-transaction duplicates allowed, sparse
    indices exercise the sort fallback);
  * engine: N disjoint transactions group-commit at ONE clock tick with
    serializable results identical to the solo pipeline; overlapping
    transactions degrade to exactly today's solo path; a member that
    fails validation aborts alone — claimed nothing, scattered nothing;
  * store: the MVStore publish path keeps the heap device-resident —
    no per-commit host materialization of any heap-sized array.

Plus the ``addr_lock_indices`` generator-input regression.
"""
import numpy as np
import pytest

from repro.core.engine import commit as C
from repro.core.engine import validation as V
from repro.core.engine.groupcommit import CommitBatcher, partition_disjoint
from repro.kernels import commit_fused as CF

from tests._backends import make_test_tm


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


def test_pack_segments_roundtrip_ragged():
    parts = [np.array([5, 3, 9], np.int64), np.zeros((0,), np.int64),
             np.array([7], np.int64), np.arange(4, dtype=np.int64)]
    flat, seg, offsets = CF.pack_segments(parts)
    assert flat.shape == (8,) and seg.shape == (8,)
    assert offsets.tolist() == [0, 3, 3, 4, 8]
    for t, p in enumerate(parts):
        np.testing.assert_array_equal(flat[offsets[t]:offsets[t + 1]], p)
        assert (seg[offsets[t]:offsets[t + 1]] == t).all()


def test_pack_segments_empty_batch():
    flat, seg, offsets = CF.pack_segments([])
    assert flat.size == 0 and seg.size == 0
    assert offsets.tolist() == [0]


# ---------------------------------------------------------------------------
# constants
# ---------------------------------------------------------------------------


def test_mode_constants_pinned_to_engine():
    assert CF.MODE_LT == V.V_LT
    assert CF.MODE_LE == V.V_LE
    assert CF.MODE_EQ == V.V_EQ


# ---------------------------------------------------------------------------
# kernel vs twin
# ---------------------------------------------------------------------------


def _random_batch(rng, n_txn, h, mode):
    """A packed commit batch with a mix of passing and failing members."""
    w_parts = [rng.choice(h, size=rng.integers(0, 9), replace=False)
               .astype(np.int64) for _ in range(n_txn)]
    w_flat, w_seg, _ = CF.pack_segments(w_parts)
    w_val = rng.integers(-1000, 1000, size=w_flat.size).astype(np.int64)
    L = int(rng.integers(1, 4 * n_txn))
    M = int(rng.integers(0, 4 * n_txn))
    l_seg = rng.integers(0, n_txn, size=L).astype(np.int64)
    r_seg = rng.integers(0, n_txn, size=M).astype(np.int64)
    mk = lambda k: (rng.integers(0, 50, size=k).astype(np.int64),   # noqa: E731
                    rng.integers(-1, 5, size=k).astype(np.int32),
                    rng.integers(0, 4, size=k).astype(np.int32))
    l_ver, l_own, l_meta = mk(L)
    r_ver, r_own, r_meta = mk(M)
    r_seen = rng.integers(0, 50, size=M).astype(np.int64)
    tids = np.arange(n_txn, dtype=np.int64)
    rcs = rng.integers(0, 50, size=n_txn).astype(np.int64)
    return (w_flat, w_val, w_seg, l_ver, l_own, l_meta, l_seg,
            r_ver, r_own, r_meta, r_seen, r_seg, tids, rcs)


@pytest.mark.parametrize("mode", [CF.MODE_LT, CF.MODE_LE, CF.MODE_EQ])
def test_fused_kernel_matches_numpy_twin(mode):
    rng = np.random.default_rng(11 + mode)
    h, n_txn, cv = 64, 4, 77
    for trial in range(6):
        heap = rng.integers(-100, 100, size=h).astype(np.int32)
        (w_flat, w_val, w_seg, l_ver, l_own, l_meta, l_seg,
         r_ver, r_own, r_meta, r_seen, r_seg, tids, rcs) = \
            _random_batch(rng, n_txn, h, mode)
        want_heap, want_ok, want_lver = CF.np_commit_fused(
            heap, w_flat, w_val, w_seg, l_ver, l_own, l_meta, l_seg,
            r_ver, r_own, r_meta, r_seen, r_seg, tids, rcs,
            cv, n_txn, mode)
        # pad the write batch to a tile multiple; pad addrs point
        # one-past-the-end (dropped), pad segs at a passing slot is
        # irrelevant since the address is out of range either way
        tile = 8
        pad = (-w_flat.size) % tile or tile
        a = np.concatenate([w_flat, np.full(pad, h, np.int64)])
        v = np.concatenate([w_val, np.zeros(pad, np.int64)])
        s = np.concatenate([w_seg, np.zeros(pad, np.int64)])

        def i32(x):
            return np.asarray(x, np.int32)

        got_heap, got_ok, got_lver = CF.commit_fused_flat(
            heap, i32(a), i32(v), i32(s),
            i32(l_ver), l_own, l_meta, i32(l_seg),
            i32(r_ver), r_own, r_meta, i32(r_seen), i32(r_seg),
            i32(tids), i32(rcs), np.array([cv], np.int32),
            mode=mode, tile=tile, interpret=True)
        np.testing.assert_array_equal(np.asarray(got_heap), want_heap)
        np.testing.assert_array_equal(np.asarray(got_ok) != 0, want_ok)
        np.testing.assert_array_equal(np.asarray(got_lver),
                                      want_lver.astype(np.int32))


def test_np_twin_failed_member_leaves_no_trace():
    heap = np.arange(10, dtype=np.int64)
    # txn 0 writes [2,3] and passes; txn 1 writes [7] but its lock is
    # held by a foreign owner -> fails, heap[7] untouched
    w_flat = np.array([2, 3, 7], np.int64)
    w_val = np.array([100, 200, 999], np.int64)
    w_seg = np.array([0, 0, 1], np.int64)
    l_ver = np.array([5, 5, 5], np.int64)
    l_own = np.array([-1, -1, 9], np.int32)
    l_meta = np.array([0, 0, 1], np.int32)     # bit0 locked
    l_seg = np.array([0, 0, 1], np.int64)
    z = np.zeros((0,), np.int64)
    zi = np.zeros((0,), np.int32)
    new_heap, ok, new_lver = CF.np_commit_fused(
        heap, w_flat, w_val, w_seg, l_ver, l_own, l_meta, l_seg,
        z, zi, zi, z, z, np.array([0, 1], np.int64),
        np.array([9, 9], np.int64), 42, 2, CF.MODE_LE)
    assert ok.tolist() == [True, False]
    assert new_heap[2] == 100 and new_heap[3] == 200
    assert new_heap[7] == 7                    # untouched
    assert new_lver.tolist() == [42, 42, 5]    # failed entry keeps its ver


def test_ops_commit_fused_beyond_int32_routes_to_twin():
    from repro.core.engine.arrayheap import _UNLOCKED_WORD, _VER_SHIFT
    from repro.kernels import ops

    big = (1 << 33) + 5
    heap = np.array([1, 2, 3, big], np.int64)
    w_addr = np.array([0, 2], np.int64)
    w_val = np.array([big + 1, -7], np.int64)
    w_seg = np.zeros(2, np.int64)
    # one free write lock at a beyond-int32 version
    l_words = np.array([(big << _VER_SHIFT) | _UNLOCKED_WORD], np.int64)
    l_seg = np.zeros(1, np.int64)
    z = np.zeros((0,), np.int64)
    cv = big + 9
    new_heap, ok, new_l = ops.commit_fused(
        heap, w_addr, w_val, w_seg, l_words, l_seg,
        z, z, z, np.array([0], np.int64), np.array([big], np.int64),
        cv, 1, mode=CF.MODE_LE)
    assert ok.tolist() == [True]
    got = np.asarray(new_heap)
    assert got[0] == big + 1 and got[2] == -7 and got[3] == big
    # release word reconstructed at full width, exactly
    assert new_l.tolist() == [(cv << _VER_SHIFT) | _UNLOCKED_WORD]


# ---------------------------------------------------------------------------
# partitioning
# ---------------------------------------------------------------------------


def _parts(groups):
    return sorted(sorted(g) for g in groups)


def test_partition_disjoint_rules():
    a = np.array([1, 2], np.int64)
    b = np.array([3, 4], np.int64)
    c = np.array([2, 5], np.int64)
    e = np.zeros((0,), np.int64)
    # fully disjoint -> one group
    assert _parts(partition_disjoint([a, b], [e, e])) == [[0, 1]]
    # write-write overlap separates
    groups = partition_disjoint([a, c], [e, e])
    assert len(groups) == 2
    # write-read overlap separates (txn 1 READS what txn 0 writes)
    groups = partition_disjoint([a, b], [e, np.array([1], np.int64)])
    assert len(groups) == 2
    # read-read sharing is harmless
    shared = np.array([9], np.int64)
    assert _parts(partition_disjoint([a, b], [shared, shared])) == [[0, 1]]
    # within-transaction duplicates are not a conflict
    dup = np.array([6, 6, 7], np.int64)
    assert _parts(partition_disjoint([dup, b], [e, e])) == [[0, 1]]


def test_partition_disjoint_sparse_indices_sort_fallback():
    # indices beyond the dense-bincount window exercise the argsort path
    hi = 1 << 40
    a = np.array([hi + 1, hi + 2], np.int64)
    b = np.array([hi + 3], np.int64)
    c = np.array([hi + 2], np.int64)
    e = np.zeros((0,), np.int64)
    assert _parts(partition_disjoint([a, b], [e, e])) == [[0, 1]]
    groups = partition_disjoint([a, c], [e, e])
    assert len(groups) == 2
    # read probe on the sparse path too
    groups = partition_disjoint([a, b], [e, np.array([hi + 1], np.int64)])
    assert len(groups) == 2


def test_partition_disjoint_three_way_split():
    a = np.array([1], np.int64)
    b = np.array([1, 2], np.int64)
    c = np.array([2, 3], np.int64)
    d = np.array([9], np.int64)
    e = np.zeros((0,), np.int64)
    groups = partition_disjoint([a, b, c, d], [e] * 4)
    got = _parts(groups)
    # a/b conflict and b/c conflict; d conflicts with nobody
    assert all(len(g) >= 1 for g in got)
    flat = sorted(i for g in got for i in g)
    assert flat == [0, 1, 2, 3]
    for g in got:
        ws = [([1], [1, 2], [2, 3], [9])[i] for i in g]
        seen = set()
        for w in ws:
            assert not (seen & set(w))
            seen |= set(w)


# ---------------------------------------------------------------------------
# engine: group == solo, one tick, degrade, individual abort
# ---------------------------------------------------------------------------

N_TXNS, WORDS = 4, 24


def _ready_batch(tm, base, stamp):
    raw = tm.raw
    txs = []
    for t in range(N_TXNS):
        tx = raw.begin(t)
        for i in range(WORDS):
            tx.write(base + t * WORDS + i, stamp + t * WORDS + i)
        txs.append(tx)
    return txs


def _heap_slice(raw, base, n):
    return np.asarray(raw.heap.gather(
        np.arange(base, base + n, dtype=np.int64)))


@pytest.mark.parametrize("backend", ["tl2", "dctl"])
def test_group_matches_solo_and_one_tick(backend):
    span = N_TXNS * WORDS
    tm_g = make_test_tm(backend, n_threads=N_TXNS, array_heap=True)
    tm_s = make_test_tm(backend, n_threads=N_TXNS, array_heap=True)
    base_g = tm_g.alloc(span)
    base_s = tm_s.alloc(span)

    txs = _ready_batch(tm_g, base_g, 1000)
    b = CommitBatcher(tm_g.raw)
    for tx in txs:
        b.add(tx)
    c0 = tm_g.raw.clock.load()
    ok = b.commit_all()
    c1 = tm_g.raw.clock.load()
    assert ok == [True] * N_TXNS
    assert b.stats["groups"] == 1 and b.stats["grouped"] == N_TXNS, b.stats
    if backend == "tl2":
        # the group invariant: ONE tick for the whole batch (solo pays
        # one per member); DCTL's deferred clock never ticks on commit
        assert c1 - c0 == 1
    else:
        assert c1 == c0

    for tx in _ready_batch(tm_s, base_s, 1000):
        tm_s.raw._try_commit(tx._ctx)
    np.testing.assert_array_equal(_heap_slice(tm_g.raw, base_g, span),
                                  _heap_slice(tm_s.raw, base_s, span))
    # serializability checker: every member's write set landed atomically
    got = _heap_slice(tm_g.raw, base_g, span)
    for t in range(N_TXNS):
        np.testing.assert_array_equal(
            got[t * WORDS:(t + 1) * WORDS],
            1000 + t * WORDS + np.arange(WORDS))
    tm_g.stop()
    tm_s.stop()


def test_overlapping_buffered_degrades_to_solo():
    tm = make_test_tm("tl2", n_threads=4, array_heap=True)
    raw = tm.raw
    base = tm.alloc(16)
    t1 = raw.begin(0)
    t2 = raw.begin(1)
    t1.write(base, 111)
    t1.write(base + 1, 1)
    t2.write(base, 222)     # same ADDRESS -> same lock word -> conflict
    t2.write(base + 2, 2)
    b = CommitBatcher(raw)
    b.add(t1)
    b.add(t2)
    ok = b.commit_all()
    # both still commit — serially, through today's solo pipeline
    assert ok == [True, True]
    assert b.stats == {"grouped": 0, "solo": 2, "groups": 0, "failed": 0}
    assert _heap_slice(raw, base, 3).tolist() == [222, 1, 2]
    tm.stop()


def test_group_member_failing_validation_aborts_alone():
    tm = make_test_tm("tl2", n_threads=4, array_heap=True)
    raw = tm.raw
    base = tm.alloc(16)
    # t0 READS base+8 then buffers a write elsewhere; a foreign commit
    # bumps base+8's version after t0's snapshot -> t0 must fail group
    # validation while its disjoint group-mates commit
    t0 = raw.begin(0)
    assert t0.read(base + 8) == 0
    t0.write(base, 7)
    bump = raw.begin(3)
    bump.write(base + 8, 55)
    raw._try_commit(bump._ctx)
    t1 = raw.begin(1)
    t1.write(base + 1, 8)
    t2 = raw.begin(2)
    t2.write(base + 2, 9)
    b = CommitBatcher(raw)
    for tx in (t0, t1, t2):
        b.add(tx)
    ok = b.commit_all()
    assert ok == [False, True, True]
    got = _heap_slice(raw, base, 9)
    assert got[0] == 0                  # failed member scattered nothing
    assert got[1] == 8 and got[2] == 9
    assert got[8] == 55
    # its write lock was never claimed: a fresh txn can take it at once
    t3 = raw.begin(0)
    t3.write(base, 77)
    raw._try_commit(t3._ctx)
    assert _heap_slice(raw, base, 1).tolist() == [77]
    tm.stop()


def test_ineligible_descriptors_fall_back_solo():
    # NOrec never opts into grouping: everything goes down today's path
    tm = make_test_tm("norec", n_threads=2, array_heap=True)
    raw = tm.raw
    base = tm.alloc(8)
    t1 = raw.begin(0)
    t1.write(base, 1)
    t2 = raw.begin(1)
    t2.write(base + 1, 2)
    b = CommitBatcher(raw)
    b.add(t1)
    b.add(t2)
    assert b.commit_all() == [True, True]
    assert b.stats["groups"] == 0 and b.stats["solo"] == 2
    assert _heap_slice(raw, base, 2).tolist() == [1, 2]
    tm.stop()


# ---------------------------------------------------------------------------
# regression: addr_lock_indices accepts generators
# ---------------------------------------------------------------------------


def test_addr_lock_indices_accepts_generator():
    tm = make_test_tm("tl2", array_heap=True)
    eng = tm.raw
    addrs = [3, 17, 255]
    want = C.addr_lock_indices(eng, np.asarray(addrs, np.int64))
    got = C.addr_lock_indices(eng, (a for a in addrs))
    np.testing.assert_array_equal(np.sort(got), np.sort(want))
    tm.stop()


# ---------------------------------------------------------------------------
# store: no per-commit host copy of the heap
# ---------------------------------------------------------------------------


class _NumpySpy:
    """Forwarding proxy for the ``numpy`` module that records the size
    of every array materialized through the patched namespace."""

    def __init__(self):
        self.max_size = 0

    def _rec(self, out):
        self.max_size = max(self.max_size, int(np.size(out)))
        return out

    def asarray(self, *a, **k):
        return self._rec(np.asarray(*a, **k))

    def array(self, *a, **k):
        return self._rec(np.array(*a, **k))

    def __getattr__(self, name):
        return getattr(np, name)


def test_mvstore_commit_keeps_heap_device_resident(monkeypatch):
    import jax

    from repro.api import mvhandle as H
    from repro.kernels import ops

    h = H.MVStoreHandle(1, start_bg=False)
    heap_len = 4096
    h.alloc(heap_len)
    spy = _NumpySpy()
    monkeypatch.setattr(H, "np", spy)

    calls = []
    real_fused = ops.commit_fused

    def spy_fused(heap, *a, **k):
        # the store hands the DEVICE buffer straight in ...
        assert isinstance(heap, jax.Array), type(heap)
        out = real_fused(heap, *a, **k)
        # ... and gets a device buffer straight back (donation path) —
        # the heap never detours through a host ndarray
        assert isinstance(out[0], jax.Array), type(out[0])
        calls.append(1)
        return out

    monkeypatch.setattr(ops, "commit_fused", spy_fused)
    for step in range(3):
        txn = h.begin(0)
        for i in range(8):
            h.write(txn._ctx, i, step * 100 + i)
        h.commit(txn)
        # the live block stays a device buffer, and the handle layer
        # never materialized a heap-sized array host-side
        assert isinstance(h.state.live["heap"], jax.Array)
        assert isinstance(h._snap[1], jax.Array)
        assert spy.max_size < heap_len, spy.max_size
    assert len(calls) == 3              # every publish took the fused path
    vals, ok = h.snapshot_bulk(np.arange(8))
    assert ok and np.asarray(vals).tolist() == [200 + i for i in range(8)]
    h.stop()


def test_mvstore_reader_losing_donation_race_aborts(monkeypatch):
    """Donation makes a stale read CRASH instead of returning stale
    data; the handle must translate that crash into the abort (inside a
    txn) or re-snapshot retry (outside) a seqlock reader would take."""
    from repro.api import mvhandle as H
    from repro.api.substrate import AbortTx

    h = H.MVStoreHandle(1, start_bg=False)
    h.alloc(16)
    txn = h.begin(0)

    boom = [RuntimeError("Array has been deleted with shape=int32[16].")]

    def raced_gather(row, a):
        if boom:
            raise boom.pop()
        return np.zeros(np.asarray(a).shape, np.int64)

    monkeypatch.setattr(h, "_gather_row", raced_gather)
    with np.testing.assert_raises(AbortTx):
        h.read_bulk(txn._ctx, range(4))
    assert not txn._ctx.active

    # outside a transaction the reader re-snapshots and retries
    boom.append(ValueError(
        "INVALID_ARGUMENT: Invalid buffer passed: buffer has been "
        "deleted or donated."))
    vals, ok = h.snapshot_bulk(range(4))
    assert ok and np.asarray(vals).shape == (4,)

    # unrelated errors still propagate untouched
    monkeypatch.setattr(
        h, "_gather_row",
        lambda row, a: (_ for _ in ()).throw(ValueError("bad addr")))
    with np.testing.assert_raises(ValueError):
        h.snapshot_bulk(range(4))
    h.stop()
