#!/usr/bin/env python
"""Execute the fenced ``python`` examples in the docs; fail on error.

    PYTHONPATH=src python scripts/check_docs.py [files...]

Default files: API.md, ARCHITECTURE.md, BENCHMARKS.md.  Every
```` ```python ```` block is executed; blocks within one file share a
namespace (so later examples may build on earlier ones), files are
isolated from each other.  A block preceded by an HTML comment line

    <!-- check_docs: skip -->

is parsed but not executed (for illustrative fragments that need
external state).  This is what keeps API.md honest: an example that no
longer runs fails CI instead of silently rotting.
"""
from __future__ import annotations

import os
import re
import sys
import traceback
from typing import List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

DEFAULT_FILES = ("API.md", "ARCHITECTURE.md", "BENCHMARKS.md")
SKIP_MARK = "<!-- check_docs: skip -->"
FENCE = re.compile(r"^```python\s*$")
END = re.compile(r"^```\s*$")


def extract_blocks(text: str) -> List[Tuple[int, bool, str]]:
    """-> [(start_line_1based, skipped, source)] for each python fence."""
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        if FENCE.match(lines[i]):
            skipped = any(SKIP_MARK in lines[j]
                          for j in range(max(0, i - 2), i))
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and not END.match(lines[i]):
                body.append(lines[i])
                i += 1
            blocks.append((start + 1, skipped, "\n".join(body)))
        i += 1
    return blocks


def check_file(path: str) -> Tuple[int, int]:
    """Run every block in ``path``; returns (run, skipped).  Raises on
    the first failing block after printing where it came from."""
    with open(path) as f:
        blocks = extract_blocks(f.read())
    ns: dict = {"__name__": f"docs:{os.path.basename(path)}"}
    ran = skipped = 0
    for line, skip, src in blocks:
        if skip or not src.strip():
            skipped += 1
            continue
        try:
            code = compile(src, f"{path}:{line}", "exec")
            exec(code, ns)  # noqa: S102 - the whole point of the script
            ran += 1
        except BaseException:
            print(f"FAILED example at {path}:{line}\n{'-' * 60}\n"
                  f"{src}\n{'-' * 60}", file=sys.stderr)
            traceback.print_exc()
            raise SystemExit(1)
    return ran, skipped


def main(argv: List[str]) -> int:
    files = argv or [f for f in DEFAULT_FILES
                     if os.path.exists(os.path.join(REPO, f))]
    total = 0
    for name in files:
        path = name if os.path.isabs(name) else os.path.join(REPO, name)
        ran, skipped = check_file(path)
        total += ran
        print(f"{os.path.basename(path)}: {ran} examples ran, "
              f"{skipped} skipped")
    if total == 0:
        print("no runnable examples found", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
