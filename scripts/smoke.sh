#!/usr/bin/env bash
# Smoke test: run the quickstart (transfer workers + consistent audits)
# under a timeout.  Exercises the repro.api surface end to end; the
# quickstart asserts on torn reads, so a non-zero exit means real breakage.
set -euo pipefail
cd "$(dirname "$0")/.."

TIMEOUT="${SMOKE_TIMEOUT:-120}"
BACKEND="${SMOKE_BACKEND:-multiverse}"

PYTHONPATH=src timeout "$TIMEOUT" \
    python examples/quickstart.py --backend "$BACKEND"
echo "smoke ok (backend=$BACKEND)"
