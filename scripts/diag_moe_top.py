"""Print top collectives for a 1-layer probe of an arch (hillclimb diag)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import sys

from repro.configs import MVStoreConfig, get_config, get_shape
from repro.configs.base import ShapeConfig
from repro.launch.dryrun import (_metrics, cell_rules, compile_once,
                                 default_parallel)
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw

arch = sys.argv[1] if len(sys.argv) > 1 else "moonshot-v1-16b-a3b"
cfg = dataclasses.replace(get_config(arch), n_layers=1)
shape0 = get_shape("train_4k")
mesh = make_production_mesh()
pcfg0 = default_parallel(cfg, shape0, mesh)
shape = ShapeConfig("train_4k", 4096,
                    shape0.global_batch // pcfg0.microbatches, "train")
pcfg = dataclasses.replace(pcfg0, microbatches=1, probe_unroll=True,
                           scan_layers=False)
rules = cell_rules(mesh, shape, pcfg, global_batch=shape.global_batch)
c, t = compile_once(cfg, shape, mesh, pcfg,
                    MVStoreConfig(enabled=True, mode="Q"),
                    adamw.AdamWConfig(), rules)
m = _metrics(c)
print(f"{arch} 1L/1mb: wire {m['wire_bytes']/1e9:.3f} GB/chip, "
      f"tpu_bytes {m['tpu_bytes']/1e9:.1f} GB")
for e in m["coll_top"][:10]:
    print(f"  {e['wire_bytes']/1e9:8.3f} GB {e['kind']:18s} "
          f"g={e['group']:4d} {e['type'][:100]}")
