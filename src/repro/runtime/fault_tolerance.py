"""Fault tolerance: checkpoint/restart supervision for the train loop.

`TrainSupervisor.run` drives step functions produced by launch/steps.py,
checkpoints through the MVStore snapshot reader (never pausing the step
pipeline), and on failure — a raised exception from the step, an injected
fault, or a straggler escalation — restores the latest checkpoint and
replays.  Because the data pipeline is counter-based, replay is exact.

At 1000+ nodes the same structure holds per-slice: each slice runs a
supervisor; a slice loss is recovered by restoring the shared manifest and
re-admitting the slice at the recorded step (see runtime/elastic.py for
the re-mesh path).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.snapshotter import (CheckpointManager,
                                          restore_checkpoint)
from repro.runtime.straggler import StragglerMonitor


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault injection for tests/demos."""

    fail_at_steps: tuple = ()
    exception: type = RuntimeError


class TrainSupervisor:
    def __init__(self, *, ckpt_dir: str, ckpt_every: int = 20,
                 max_restarts: int = 5, reader=None,
                 straggler: Optional[StragglerMonitor] = None,
                 wal=None):
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.manager = CheckpointManager(ckpt_dir, reader=reader)
        self.straggler = straggler or StragglerMonitor()
        # optional durable commit log (reliability/wal.WriteAheadLog):
        # checkpoints double as WAL truncation points (the base image
        # reclaims segments below the floor), and every restore logs the
        # journal's decided-but-unpublished tail so drills can assert
        # the committed prefix survived the restart
        self.wal = wal
        self.restarts = 0
        self.events = []

    def run(self, *, state, train_step: Callable, batch_at: Callable,
            n_steps: int, start_step: int = 0,
            fault_plan: Optional[FaultPlan] = None,
            on_step: Optional[Callable] = None):
        """Run to n_steps with checkpoint/restart.  ``batch_at(step)``
        must be deterministic; ``train_step(state, batch) -> (state,
        metrics)``."""
        step = start_step
        fault_plan = fault_plan or FaultPlan()
        fired = set()
        while step < n_steps:
            try:
                t0 = time.time()
                if step in fault_plan.fail_at_steps and step not in fired:
                    fired.add(step)
                    raise fault_plan.exception(
                        f"injected node failure at step {step}")
                state, metrics = train_step(state, batch_at(step))
                jax.block_until_ready(metrics["loss"])
                self.straggler.observe(step, time.time() - t0)
                step += 1
                if on_step is not None:
                    on_step(step, state, metrics)
                if step % self.ckpt_every == 0:
                    self._checkpoint(step, state)
            except Exception as e:  # noqa: BLE001 — node failure path
                self.events.append(("failure", step, repr(e)))
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                step, state = self._restore(state)
                self.events.append(("restored", step, ""))
        self.manager.wait_idle()
        return step, state

    def _checkpoint(self, step, state):
        outcome = self.manager.submit(step, state.mv, state.opt,
                                      extra={"restarts": self.restarts})
        if self.wal is not None:
            key = next(iter(state.mv.live))
            self.wal.checkpoint(np.asarray(state.mv.live[key]),
                                int(state.mv.clock))
        self.events.append(
            ("checkpoint", step,
             "ok" if outcome else getattr(outcome, "value", "aborted")))

    def _restore(self, template_state):
        self.manager.wait_idle()          # in-flight async save may be ours
        from repro.reliability.recovery import replay_from_checkpoint
        try:
            out = replay_from_checkpoint(self.ckpt_dir, template_state)
        except FileNotFoundError:
            # cold restart: no checkpoint landed yet -> replay from step 0
            self.events.append(("cold_restart", 0, ""))
            out = 0, template_state
        if self.wal is not None:
            # counter-based replay recomputes the lost steps exactly, so
            # the WAL tail is not re-applied here — but its decided
            # records ARE the committed prefix, and the scan both proves
            # they survived and journals the torn tail for the drills
            from repro.reliability.wal import scan_dir
            self.wal.flush()
            recs, torn, _base = scan_dir(self.wal.path)
            undrained = sum(1 for r in recs if r.decided and not r.completed)
            self.events.append(
                ("wal_scan", out[0],
                 f"records={len(recs)} undrained={undrained} torn={torn}"))
        return out


class _RingCfg:
    def __init__(self, r):
        self.ring_slots = r
