"""Elastic scaling: re-mesh a running job when the healthy chip count
changes (slice loss / addition).

The policy keeps the 'model' (TP/EP) axis fixed — it is baked into layout
decisions — and rescales the data(+pod) axes, so the global batch stays
constant while per-chip microbatching adapts.  `rescale_plan` computes the
new mesh + microbatching; `reshard_state` moves an existing TrainState
onto the new mesh with jax.device_put (GSPMD emits the minimal resharding
collectives).  The counter-based data pipeline repartitions exactly
(data/pipeline.py), so no sample is lost or duplicated across a rescale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.launch.sharding import Rules, default_rules


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    microbatches: int
    note: str = ""


def rescale_plan(*, n_devices: int, model_parallel: int,
                 global_batch: int, old_microbatches: int) -> RescalePlan:
    """Largest data axis that divides the fleet while keeping TP fixed."""
    if n_devices % model_parallel != 0:
        raise ValueError(
            f"{n_devices} devices not divisible by TP={model_parallel}")
    data = n_devices // model_parallel
    while data > 1 and global_batch % data != 0:
        data -= 1            # drop stragglers below a divisible count
    used = data * model_parallel
    micro = max(1, min(global_batch // data, old_microbatches))
    note = "" if used == n_devices else (
        f"parking {n_devices - used} chips (batch divisibility)")
    return RescalePlan((data, model_parallel), ("data", "model"), micro,
                       note)


def make_rescaled_mesh(plan: RescalePlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = 1
    for s in plan.mesh_shape:
        n *= s
    import numpy as np
    arr = np.asarray(devices[:n]).reshape(plan.mesh_shape)
    return Mesh(arr, plan.axis_names)


def reshard_state(state, new_mesh: Mesh, rules: Optional[Rules] = None,
                  spec_tree=None):
    """device_put the whole state onto the new mesh.

    ``spec_tree`` (PartitionSpec tree matching state) can be given
    directly; otherwise everything lands replicated-on-data per leaf spec
    derived from the old shardings' PartitionSpecs.
    """
    if spec_tree is not None:
        shardings = jax.tree.map(
            lambda s: NamedSharding(new_mesh, s), spec_tree)
    else:
        def move(x):
            try:
                spec = x.sharding.spec
            except AttributeError:
                from jax.sharding import PartitionSpec as P
                spec = P()
            return NamedSharding(new_mesh, spec)
        shardings = jax.tree.map(move, state)
    return jax.device_put(state, shardings)
