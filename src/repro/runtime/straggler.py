"""Straggler mitigation: step-time monitoring + escalation policy.

On a real pod, a straggling host shows up as a slow all-reduce for
everyone.  The monitor tracks a robust running median of step times and
flags steps exceeding ``threshold x median``.  Escalation is pluggable:
the default policy logs; the supervisor can be wired to treat a persistent
straggler as a failure (checkpoint-restore onto a healthy mesh via
runtime/elastic.py), which is the standard large-fleet response.
"""
from __future__ import annotations

import collections
import statistics
from typing import Callable, List, Optional


class StragglerMonitor:
    def __init__(self, *, window: int = 32, threshold: float = 3.0,
                 persist: int = 3,
                 escalate: Optional[Callable[[int, float], None]] = None):
        self.window = window
        self.threshold = threshold
        self.persist = persist
        self.escalate = escalate
        self._times = collections.deque(maxlen=window)
        self._consecutive = 0
        self.flagged: List[tuple] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step was flagged as straggling."""
        if len(self._times) >= 8:
            med = statistics.median(self._times)
            if seconds > self.threshold * med:
                self._consecutive += 1
                self.flagged.append((step, seconds, med))
                if self.escalate and self._consecutive >= self.persist:
                    self.escalate(step, seconds)
                    self._consecutive = 0
                self._times.append(seconds)
                return True
        self._consecutive = 0
        self._times.append(seconds)
        return False

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0
