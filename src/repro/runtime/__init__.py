from repro.runtime.fault_tolerance import TrainSupervisor  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.elastic import rescale_plan, reshard_state  # noqa: F401
