"""Roofline terms from a compiled dry-run artifact.

Hardware model (TPU v5e target, per assignment):
  peak bf16 compute   197 TFLOP/s / chip
  HBM bandwidth       819 GB/s / chip
  ICI bandwidth       ~50 GB/s / link / chip

cost_analysis() of the SPMD-partitioned executable reports *per-device*
flops and bytes.  Collective bytes are NOT in cost_analysis: we parse the
post-optimization HLO and sum wire bytes per collective op, converting each
op's result shape to bytes-on-the-wire with the standard ring-algorithm
factors (all-reduce moves 2x(n-1)/n of the tensor, all-gather and
reduce-scatter (n-1)/n of the *full* tensor, all-to-all (n-1)/n, permute
1x).  See EXPERIMENTS.md SSRoofline for the caveats.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# `%x = f32[128,1024]{1,0} all-reduce(...)`, possibly tuple-typed
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


_WIRE_FACTOR = {
    # multiplier applied to the op's RESULT bytes to estimate per-device
    # wire traffic, assuming ring algorithms over a group of size n
    "all-reduce": lambda n: 2.0 * (n - 1) / max(n, 1),
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: float(n - 1),   # result is 1/n of operand
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def collective_bytes(compiled_or_text, default_group: int = 1,
                     top_k: int = 8) -> Dict:
    """Parse the post-SPMD HLO; per-op-kind result-bytes and wire-bytes,
    plus the top-K largest collectives (shape + group) for debugging."""
    if isinstance(compiled_or_text, str):
        text = compiled_or_text
    else:
        try:
            text = compiled_or_text.as_text()
        except Exception:  # pragma: no cover
            return {"total_result_bytes": 0, "total_wire_bytes": 0,
                    "ops": {}, "top": []}
    ops: Dict[str, Dict[str, float]] = {}
    total_wire = 0.0
    total_res = 0
    top = []
    for line in text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        typestr, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; count the -start only
        if f"{kind}-done" in line:
            continue
        nbytes = _shape_bytes(typestr)
        if f"{kind}-start" in line:
            # start ops have tuple types (operand, result, ...): halve
            nbytes = nbytes // 2 if nbytes else nbytes
        n = _group_size(line, default_group)
        wire = nbytes * _WIRE_FACTOR[kind](n)
        d = ops.setdefault(kind, {"count": 0, "result_bytes": 0,
                                  "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += nbytes
        d["wire_bytes"] += wire
        total_wire += wire
        total_res += nbytes
        top.append((wire, kind, typestr.strip()[:120], n))
    top.sort(reverse=True)
    return {"total_result_bytes": total_res,
            "total_wire_bytes": total_wire, "ops": ops,
            "top": [{"wire_bytes": w, "kind": k, "type": t, "group": n}
                    for w, k, t, n in top[:top_k]]}


# ---------------------------------------------------------------------------
# TPU-realistic HBM bytes model (edge materialization)
# ---------------------------------------------------------------------------
#
# XLA:CPU fuses far less than XLA:TPU, so the raw 'bytes accessed' of the
# CPU-compiled artifact counts every elementwise intermediate as HBM
# traffic.  For the memory roofline term we instead simulate TPU-grade
# fusion on the optimized HLO's dataflow edges: an edge (producer ->
# consumer) moves HBM bytes iff at least one endpoint is NON-fusable
# (dot/conv/reduce/gather/scatter/sort/collective/parameter/while/...).
# Edges between fusable ops (fusions, bare elementwise, broadcasts,
# converts, reshapes) collapse — the TPU fuser would keep them in VMEM.
# Program outputs are charged once.  The raw cost-analysis number is kept
# alongside as the no-fusion upper bound (EXPERIMENTS.md SSRoofline).

_FUSABLE = {
    "fusion", "broadcast", "constant", "iota", "convert", "reshape",
    "bitcast", "get-tuple-element", "tuple", "copy", "add", "subtract",
    "multiply", "divide", "maximum", "minimum", "exponential", "log",
    "negate", "abs", "sign", "compare", "select", "and", "or", "not",
    "xor", "power", "rsqrt", "sqrt", "tanh", "floor", "ceil",
    "round-nearest-afz", "is-finite", "clamp", "pad", "slice",
    "concatenate", "transpose", "reverse", "reduce-precision",
    "exponential-minus-one", "log-plus-one", "logistic", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "remainder",
    "partition-id", "replica-id", "after-all",
}
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[^)]*?)\s*"
    r"([a-z][\w\-]*)\(([^)]*)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def tpu_bytes_model(compiled_or_text) -> Dict:
    """Fusion-collapsed HBM byte estimate from optimized HLO text."""
    if isinstance(compiled_or_text, str):
        text = compiled_or_text
    else:
        try:
            text = compiled_or_text.as_text()
        except Exception:  # pragma: no cover
            return {"tpu_bytes": 0.0}
    lines = text.splitlines()
    # computation spans; fusion bodies are interior (skipped)
    comp_of_line = []
    current = None
    for ln in lines:
        s = ln.strip()
        if s.endswith("{") and ("%" in s or s.startswith("ENTRY")):
            current = s.split("{")[0].strip()
        comp_of_line.append(current)
    fused_bodies = set()
    shapes: Dict[str, int] = {}
    producer_op: Dict[str, str] = {}
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, typestr, op = m.group(1), m.group(2), m.group(3)
        shapes[name] = _shape_bytes(typestr)
        producer_op[name] = op
        if op == "fusion":
            mm = re.search(r"calls=%?([\w.\-]+)", ln)
            if mm:
                fused_bodies.add(mm.group(1))
    total = 0.0
    root_bytes = 0
    materialized_writes = set()       # fusable producers read by non-fusable
    entries = []
    for ln, comp in zip(lines, comp_of_line):
        if comp and any(fb in comp for fb in fused_bodies):
            continue
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        entries.append((ln, m))
        name, typestr, op, operands = m.groups()
        if op not in _FUSABLE:
            for o in _OPERAND_RE.findall(operands):
                materialized_writes.add(o)
    for ln, m in entries:
        name, typestr, op, operands = m.groups()
        consumer_fusable = op in _FUSABLE
        # reads: materialized edges
        for o in _OPERAND_RE.findall(operands):
            if o not in shapes:
                continue
            pop = producer_op.get(o, "parameter")
            if consumer_fusable and pop in _FUSABLE:
                continue                      # stays in VMEM
            total += shapes[o]
        # writes: every non-fusable op writes its result (parameters are
        # inputs, not writes — their reads are counted at consumer edges);
        # a fusable chain's result is written once iff some non-fusable op
        # reads it
        if (op not in _FUSABLE and op != "parameter") or \
                (op in _FUSABLE and name in materialized_writes):
            total += shapes.get(name, 0)
        if ln.strip().startswith("ROOT"):
            root_bytes = shapes.get(name, 0)
    total += root_bytes
    return {"tpu_bytes": total}


def attention_score_bytes(compiled_or_text, block_q: int = 1024,
                          block_k: int = 1024) -> float:
    """HBM bytes attributable to materialized attention score/softmax
    tiles ([..., bq, bk] tensors at non-fusable edge endpoints).

    The XLA blockwise-attention lowering materializes these per pair-step;
    the Pallas flash kernel (kernels/flash_attention.py) keeps them in
    VMEM.  Subtracting this from tpu_bytes models deploying the kernel on
    TPU — used for the kernel-credit rows of EXPERIMENTS.md SSPerf.
    """
    if isinstance(compiled_or_text, str):
        text = compiled_or_text
    else:
        try:
            text = compiled_or_text.as_text()
        except Exception:  # pragma: no cover
            return 0.0
    total = 0.0
    suffixes = {f"{block_q},{block_k}]", f"{block_k},{block_q}]"}
    for ln in text.splitlines():
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, typestr, op, operands = m.groups()
        if op != "dot":
            continue
        ts = typestr.replace(" ", "").split("{")[0]
        # score-shaped dot outputs (fwd s, bwd ds/dp): each materializes
        # once and is re-read once by its consumer dot through the
        # (fused) softmax chain
        if any(ts.endswith(sfx) for sfx in suffixes):
            total += 2 * _shape_bytes(typestr)
    return total


def roofline_terms(cfg, shape, *, cost: Dict, collectives: Dict,
                   n_chips: int) -> Dict:
    """The three terms (seconds) + MODEL_FLOPS ratio for one cell."""
    from repro.models.model_zoo import model_flops

    flops_dev = float(cost.get("flops") or 0.0)
    bytes_dev = float(cost.get("bytes accessed") or 0.0)
    wire_dev = float(collectives.get("total_wire_bytes") or 0.0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    # per assignment: collective_bytes / (chips * link_bw), with
    # collective_bytes global = per-device wire * chips -> simplifies to
    # per-device wire / link_bw
    t_coll = wire_dev / ICI_BW
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * n_chips
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    total = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": (mf / hlo_global) if hlo_global else 0.0,
        "roofline_fraction": (
            (mf / (n_chips * PEAK_FLOPS)) / total if total else 0.0),
    }
