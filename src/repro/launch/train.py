"""End-to-end training driver.

Wires together: config registry -> mesh/rules -> MVStore(+controller) ->
step variants (the compiled-step-as-transaction scheme) -> data pipeline
-> fault-tolerant supervisor with snapshot-consistent checkpoints.

Runs on whatever devices exist (CPU smoke scale included):

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 40 --ckpt-dir /tmp/ckpt

The MVStore mode cycle is live: snapshot readers (the checkpointer, eval)
announce aborts; the controller flips Q->QtoU->U when they starve and back
when they drain, swapping compiled step variants at step boundaries.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, SMOKE_SHAPE, MVStoreConfig,
                           ParallelConfig, ShapeConfig, get_config,
                           smoke_config)
from repro.core import mvcontroller, mvstore
from repro.data.pipeline import make_batch_iterator
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import default_rules, use_rules
from repro.models import model_zoo as zoo
from repro.optim import adamw
from repro.runtime.fault_tolerance import FaultPlan, TrainSupervisor


class Trainer:
    """Owns the MVStore state and the compiled step variants."""

    def __init__(self, cfg, shape, *, pcfg=None, mvcfg=None, opt_cfg=None,
                 mesh=None, seed: int = 0, controller=None):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh if mesh is not None else make_host_mesh()
        self.pcfg = pcfg or ParallelConfig(
            attn_block_q=min(1024, shape.seq_len),
            attn_block_k=min(1024, shape.seq_len))
        self.mvcfg = mvcfg or MVStoreConfig()
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(warmup_steps=10)
        self.rules = default_rules(self.mesh)
        if shape.global_batch % self.mesh.devices.size != 0:
            self.rules = self.rules.with_(batch=None)
        self.controller = controller or mvcontroller.MVController(
            mvcfg=self.mvcfg, start_bg=True)
        with use_rules(self.rules, self.mesh):
            params = zoo.init_params(cfg, jax.random.PRNGKey(seed))
        versioned = "all" if self.mvcfg.mode in ("U", "QtoU", "UtoQ") \
            else "none"
        mv = mvstore.mv_init(params, self.mvcfg, versioned=versioned)
        opt = adamw.init(params, self.opt_cfg)
        self.state = steps_mod.TrainState(mv=mv, opt=opt)
        self._variants: Dict[tuple, callable] = {}
        self.step_times = []

    # -- compiled-step-variant cache (local mode fixed at trace time) ----
    def _variant(self, local_mode: str, versioned_key: frozenset):
        key = (local_mode, versioned_key)
        if key not in self._variants:
            mvcfg = self.mvcfg.replace(mode=local_mode)
            fn = steps_mod.make_train_step(self.cfg, self.pcfg, mvcfg,
                                           self.opt_cfg, self.rules,
                                           self.mesh)
            self._variants[key] = jax.jit(fn, donate_argnums=(0,))
        return self._variants[key]

    def train_step(self, state, batch):
        state = state._replace(mv=self.controller.trainer_tick(state.mv))
        local_mode = self.controller.current_local_mode()
        fn = self._variant(local_mode,
                           frozenset(state.mv.ring))
        batch = jax.tree.map(jnp.asarray, batch)
        t0 = time.time()
        state, metrics = fn(state, batch)
        self.step_times.append(time.time() - t0)
        return state, metrics

    def batch_at(self, step: int):
        it = make_batch_iterator(self.cfg, self.shape, start_step=step)
        return next(it)

    def snapshot_reader(self):
        return self.controller.reader()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mv-mode", default="Q", choices=["Q", "U"])
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("train_cli", args.seq, args.batch, "train")
    trainer = Trainer(cfg, shape,
                      mvcfg=MVStoreConfig(mode=args.mv_mode))
    sup = TrainSupervisor(ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every,
                          reader=trainer.snapshot_reader())
    fault = FaultPlan(fail_at_steps=(args.inject_failure_at,)) \
        if args.inject_failure_at >= 0 else None

    losses = []

    def on_step(step, state, metrics):
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 10 == 0 or step == 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"mode {trainer.controller.current_local_mode()} "
                  f"rings {len(state.mv.ring)}", flush=True)

    step, state = sup.run(state=trainer.state,
                          train_step=trainer.train_step,
                          batch_at=trainer.batch_at,
                          n_steps=args.steps, fault_plan=fault,
                          on_step=on_step)
    trainer.controller.stop()
    sup.manager.close()
    print(f"done: {step} steps, restarts={sup.restarts}, "
          f"first loss {losses[0]:.4f} last {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
