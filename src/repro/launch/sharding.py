"""Logical-axis sharding: one rule table maps model-code axis names to mesh axes.

Model code never mentions mesh axes directly; it annotates params and
activations with *logical* axes ('batch', 'tp', 'fsdp', 'experts', 'vocab',
'seq_shard', ...).  ``Rules`` maps logical -> mesh axes.  The dry-run, the
trainer and the hillclimb all reconfigure sharding by swapping rule tables,
never by touching model code (this is how SSPerf iterations change sharding).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule table
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Rules:
    """Mapping from logical axis names to mesh axis names (or None)."""

    table: Tuple[Tuple[str, Any], ...] = ()

    def get(self, logical: Optional[str]):
        if logical is None:
            return None
        for k, v in self.table:
            if k == logical:
                return v
        return None

    def with_(self, **kw) -> "Rules":
        tbl = dict(self.table)
        tbl.update(kw)
        return Rules(tuple(tbl.items()))

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        return P(*[self.get(a) for a in axes])


def default_rules(mesh: Mesh, *, fsdp: bool = True,
                  shard_seq: bool = False) -> Rules:
    """DP(+pod) / FSDP over 'data', Megatron TP + EP over 'model'.

    ``shard_seq`` activates sequence sharding over 'data' for cells whose
    global batch is smaller than the data axis (long-context decode).
    """
    axes = mesh.axis_names
    batch: Any = tuple(a for a in ("pod", "data") if a in axes) or None
    data = "data" if "data" in axes else None
    model = "model" if "model" in axes else None
    table = {
        "batch": batch,
        "fsdp": data if fsdp else None,        # param/optimizer ZeRO-3 dim
        "tp": model,                           # Megatron column/row dim
        "experts": model,                      # expert parallelism
        "vocab": model,                        # embedding/LM-head vocab dim
        "kv_flat": model,                      # flattened kv*dh cache dim
        "seq_shard": data if shard_seq else None,  # SP for long decode
        "ring": None,                          # MVStore version-ring dim
        "heap_shard": data,                    # sharded-store shard dim
    }
    return Rules(tuple(table.items()))


def shard_device_slices(mesh: Mesh, n_shards: int):
    """One device slice per store shard (``core/shardstore.py``).

    The sharded store partitions its heap at the ADDRESS level (spans
    round-robin over shards), so its unit of placement is a whole
    shard, not a tensor axis: shard ``s``'s buffers are ``device_put``
    onto slice ``s``.  Slices round-robin over the mesh's devices in
    row-major order — with fewer shards than devices each shard owns a
    distinct device; with more, shards wrap (clock independence is
    preserved either way, placement is only locality)."""
    import numpy as _np
    devs = list(_np.asarray(mesh.devices).flat)
    return [devs[s % len(devs)] for s in range(n_shards)]


# Current (rules, mesh), set by the launcher around trace time.
_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_rules", default=(None, None))


@contextlib.contextmanager
def use_rules(rules: Optional[Rules], mesh: Optional[Mesh] = None):
    tok = _RULES.set((rules, mesh))
    try:
        yield
    finally:
        _RULES.reset(tok)


def current_rules() -> Optional[Rules]:
    return _RULES.get()[0]


def current_mesh() -> Optional[Mesh]:
    return _RULES.get()[1]


def shard_act(x, axes: Sequence[Optional[str]]):
    """Annotate an activation with logical axes (no-op without rules)."""
    rules, mesh = _RULES.get()
    if rules is None:
        return x
    spec = rules.spec(axes)
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Abstract parameters: single source of truth for shape + sharding + init.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axes, len == len(shape)
    init: str = "normal"                 # normal | zeros | ones | embed
    scale: float = 1.0
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def materialize(meta_tree, key, dtype_override: Optional[str] = None):
    """Turn a tree of ParamMeta into concrete initialized arrays."""
    import jax.numpy as jnp

    leaves, treedef = jax.tree.flatten(
        meta_tree, is_leaf=lambda x: isinstance(x, ParamMeta))
    keys = jax.random.split(key, len(leaves))
    out = []
    for m, k in zip(leaves, keys):
        dt = jnp.dtype(dtype_override or m.dtype)
        if m.init == "zeros":
            a = jnp.zeros(m.shape, dt)
        elif m.init == "ones":
            a = jnp.ones(m.shape, dt)
        else:
            fan_in = m.shape[-2] if len(m.shape) >= 2 else m.shape[-1]
            std = m.scale / max(fan_in, 1) ** 0.5
            a = (jax.random.normal(k, m.shape, jnp.float32) * std).astype(dt)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def abstract_params(meta_tree, rules: Rules, mesh: Mesh,
                    dtype_override: Optional[str] = None):
    """ShapeDtypeStructs (with sharding) for a ParamMeta tree — dry-run use."""
    import jax.numpy as jnp

    def one(m: ParamMeta):
        dt = jnp.dtype(dtype_override or m.dtype)
        sh = NamedSharding(mesh, rules.spec(m.axes))
        return jax.ShapeDtypeStruct(m.shape, dt, sharding=sh)

    return jax.tree.map(one, meta_tree,
                        is_leaf=lambda x: isinstance(x, ParamMeta))


def param_specs(meta_tree, rules: Rules):
    """PartitionSpec tree matching a ParamMeta tree."""
    return jax.tree.map(lambda m: rules.spec(m.axes), meta_tree,
                        is_leaf=lambda x: isinstance(x, ParamMeta))


def stack_meta(meta_tree, n: int, axis_name: Optional[str] = None):
    """Prepend a stacking dim (layers) to every ParamMeta in a tree."""
    def one(m: ParamMeta):
        return dataclasses.replace(
            m, shape=(n,) + m.shape, axes=(axis_name,) + m.axes)
    return jax.tree.map(one, meta_tree,
                        is_leaf=lambda x: isinstance(x, ParamMeta))
