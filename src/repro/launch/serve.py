"""Serving driver: continuous-batching generation from MVStore snapshots.

The server is the paper's *versioned reader*: every decode step resolves
model parameters at a read clock via `mv_snapshot`, so serving can share
the store with a live trainer (serve-from-trainer) without ever reading
a torn update.  Batching is delegated to the ``repro.serve`` subsystem:
requests enter a ``RequestQueue``, the ``ContinuousBatchingScheduler``
keeps a fixed slot pool full (a freed slot is re-prefilled immediately,
the batch never drains to empty), and ``ModelSlotExecutor`` below maps
slots onto the compiled prefill/decode step functions.

Slot-level batching and per-request snapshot clocks meet in the decode
step: the hardware runs ONE parameter resolution per batched step, so
the executor resolves at the OLDEST active pinned clock — every step is
still a single consistent snapshot (never torn), and a request admitted
after a commit may simply be served a slightly staler consistent
version (bounded by the ring depth; telemetry reports the clocks each
request actually saw).  When the store is unversioned (Mode Q) and the
trainer commits mid-request, the snapshot read returns ok=False and the
affected requests restart at a fresh clock — the reader abort path,
now counted per request and surfaced through the normalized stats
schema (``Server.stats()``); sustained aborts flip the store to Mode U
through the controller heuristics.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 8 --gen 16
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, MVStoreConfig, ParallelConfig,
                           get_config, smoke_config)
from repro.core import mvstore
from repro.core.stats_schema import normalize_stats
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import default_rules, use_rules
from repro.models import model_zoo as zoo
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import Outcome, Request, RequestQueue
from repro.serve.scheduler import ContinuousBatchingScheduler, StepResult


class _ReaderMetrics(ServeMetrics):
    """ServeMetrics that also announces to a controller ReaderHandle, so
    serving aborts feed the K1/K2/K3 go-versioned heuristics."""

    def __init__(self, reader, **kw):
        super().__init__(**kw)
        self._reader = reader

    def on_snapshot_abort(self, n: int = 1) -> None:
        super().on_snapshot_abort(n)
        self._reader.on_abort(n)

    def on_prefill_retry(self, n: int = 1) -> None:
        super().on_prefill_retry(n)
        self._reader.on_abort(n)

    def on_complete(self, req, now=None, store_clock=None) -> None:
        super().on_complete(req, now=now, store_clock=store_clock)
        self._reader.on_commit(req.max_new, req.pinned_clock)


class ModelSlotExecutor:
    """SlotExecutor over the compiled prefill/decode step functions.

    Owns the batched decode cache ([group, n_slots, ...] leaves), a
    B=1 prefill jit and an insert jit that drops a freshly prefilled
    row into a freed slot (padding the k/v seq axis out to ``max_len``)
    — the continuous-batching primitive: one slot changes occupant,
    the other slots' decode stream never pauses.
    """

    def __init__(self, cfg, pcfg, mvcfg, rules, mesh, state_fn, *,
                 n_slots: int, max_len: int, reader=None):
        self.cfg = cfg
        self.mvcfg = mvcfg
        self.state_fn = state_fn
        self.n_slots = n_slots
        self.max_len = max_len
        self.reader = reader
        self._prefill1 = jax.jit(steps_mod.make_prefill_step(
            cfg, pcfg, mvcfg, rules, mesh))
        self._decode = jax.jit(steps_mod.make_decode_step(
            cfg, pcfg, mvcfg, rules, mesh), donate_argnums=(1,))
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))
        self.cache = None
        self.cache_len = jnp.zeros((n_slots,), jnp.int32)
        self.tokens = jnp.zeros((n_slots,), jnp.int32)

    def current_clock(self) -> int:
        return int(self.state_fn().clock)

    @staticmethod
    def _insert_fn(full, one, slot):
        """Write a B=1 cache into batch row ``slot`` of the full cache.

        Any axis the prefill left short of the full leaf's (the k/v seq
        axis at prompt_len vs max_len) is zero-padded at the end; decode
        masks by cache_len, so the padding is never attended.
        """
        def upd(f, o):
            o = o[:, 0]                            # drop the B=1 axis
            target = f.shape[:1] + f.shape[2:]
            if o.shape != target:
                o = jnp.pad(o, [(0, t - s)
                                for t, s in zip(target, o.shape)])
            return jax.lax.dynamic_update_index_in_dim(
                f, o.astype(f.dtype), slot, 1)
        return jax.tree.map(upd, full, one)

    def _ensure_cache(self, one) -> None:
        if self.cache is None:
            blank = zoo.init_cache(self.cfg, self.n_slots, self.max_len,
                                   jnp.float32)
            self.cache = jax.tree.map(
                lambda z, o: jnp.zeros(z.shape, o.dtype), blank, one)

    @staticmethod
    def _is_reclaimed(err: RuntimeError) -> bool:
        # A live trainer donates its state buffers into the next step;
        # a reader still holding the old reference sees them deleted.
        # That is the TM "memory reclaimed under the reader" race — the
        # read aborts and re-pins at the fresh state (whose ring still
        # holds the pinned version if it is within the ring depth).
        return "deleted" in str(err)

    # -- SlotExecutor ----------------------------------------------------
    def prefill(self, slot: int, req: Request, clock: int) -> StepResult:
        state = self.state_fn()
        if self.reader is not None:
            self.reader.begin(int(clock))
        try:
            logits, cache1, len1, ok = self._prefill1(
                state, {"tokens": jnp.asarray(req.payload)[None]}, clock)
        except RuntimeError as err:
            if not self._is_reclaimed(err):
                raise
            return StepResult(False, clock)
        if not bool(ok):
            return StepResult(False, clock)
        self._ensure_cache(cache1)
        self.cache = self._insert(self.cache, cache1, slot)
        self.cache_len = self.cache_len.at[slot].set(len1[0])
        tok = int(jnp.argmax(logits[0]))
        self.tokens = self.tokens.at[slot].set(tok)
        return StepResult(True, int(clock), token=tok)

    def decode(self, slots: Sequence[int], clocks: Sequence[int]
               ) -> List[StepResult]:
        # one parameter resolution per batched step, at the oldest
        # active pin (see module docstring for the staleness contract)
        rc = min(clocks)
        state = self.state_fn()
        try:
            logits, self.cache, self.cache_len, ok = self._decode(
                state, self.cache, self.cache_len, self.tokens, rc)
        except RuntimeError as err:
            if not self._is_reclaimed(err):
                raise
            # the donated cache may be gone too; rebuild on re-prefill
            self.cache = None
            self.cache_len = jnp.zeros((self.n_slots,), jnp.int32)
            self.tokens = jnp.zeros((self.n_slots,), jnp.int32)
            return [StepResult(False, rc) for _ in slots]
        self.tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        okb = bool(ok)
        toks = np.asarray(self.tokens)
        return [StepResult(okb, rc, token=int(toks[i])) for i in slots]


class Server:
    """Continuous-batching server over ``n_slots`` decode slots.

    ``serve_batch`` keeps its original synchronous contract (submit B
    prompts, return [B, max_new] tokens) but now rides the scheduler:
    requests beyond the slot count queue up and fill freed slots as
    earlier requests finish.  ``submit``/``pump`` expose the
    asynchronous surface (examples/serve_snapshots.py drives it
    against a live trainer); ``stats()`` reports the normalized TM
    stats schema, with Mode-Q snapshot-read retries counted as aborts.
    """

    def __init__(self, cfg, *, batch: int, prompt_len: int, max_len: int,
                 mvcfg=None, mesh=None, controller=None, seed: int = 0,
                 params=None, mv_state=None):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.mesh = mesh if mesh is not None else make_host_mesh()
        self.pcfg = ParallelConfig(
            remat="none", attn_block_q=min(512, prompt_len),
            attn_block_k=min(512, prompt_len))
        self.mvcfg = mvcfg or MVStoreConfig(mode="Q")
        self.rules = default_rules(self.mesh)
        if batch % self.mesh.devices.size != 0:
            self.rules = self.rules.with_(batch=None)
        self.controller = controller
        self.reader = controller.reader() if controller else None
        if mv_state is None:
            with use_rules(self.rules, self.mesh):
                params = params if params is not None else zoo.init_params(
                    cfg, jax.random.PRNGKey(seed))
            versioned = "all" if self.mvcfg.mode in ("U",) else "none"
            mv_state = mvstore.mv_init(params, self.mvcfg,
                                       versioned=versioned)
        self.mv_state = mv_state
        self.metrics = (_ReaderMetrics(self.reader, seed=seed)
                        if self.reader is not None
                        else ServeMetrics(seed=seed))
        self.queue = RequestQueue(max_depth=max(64, 4 * batch),
                                  n_servers=batch)
        self.executor = ModelSlotExecutor(
            cfg, self.pcfg, self.mvcfg, self.rules, self.mesh,
            lambda: self.mv_state, n_slots=batch, max_len=max_len,
            reader=self.reader)
        # retry-forever like the original per-batch loop; every retry is
        # still counted and surfaced through stats()
        self.scheduler = ContinuousBatchingScheduler(
            self.queue, self.executor, self.metrics,
            max_request_aborts=1 << 30)
        self._rid = 0

    @property
    def aborts(self) -> int:
        """Snapshot-read retries (prefill + in-flight decode aborts)."""
        return self.metrics.snapshot_aborts + self.metrics.prefill_retries

    # -- async surface ---------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        self._rid += 1
        req = Request(rid=self._rid, payload=np.asarray(prompt),
                      max_new=max_new)
        adm = self.queue.offer(req)
        if adm.value != "admitted":
            raise RuntimeError(f"request {req.rid} not admitted: {adm}")
        return req

    def pump(self) -> bool:
        """One scheduler iteration; returns False when idle."""
        return self.scheduler.step()

    # -- sync surface ----------------------------------------------------
    def serve_batch(self, prompts: np.ndarray, max_new: int
                    ) -> np.ndarray:
        """prompts: [B, S] int32 -> generated [B, max_new] int32."""
        reqs = [self.submit(p, max_new) for p in prompts]
        while any(r.outcome is Outcome.PENDING for r in reqs):
            if not self.pump():
                time.sleep(1e-5)
        return np.stack(
            [np.asarray(r.tokens[:max_new], np.int32) for r in reqs])

    def stats(self) -> Dict[str, object]:
        """Serving counters in the normalized TM stats schema."""
        return normalize_stats(
            {"commits": self.metrics.completed,
             "aborts": self.aborts,
             "ro_commits": self.metrics.completed},
            backend="mvserve", mode=self.mvcfg.mode)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend != "none" or cfg.is_encdec:
        print(f"note: {args.arch} needs frontend embeds; serving the "
              "text path only")
    server = Server(cfg, batch=args.batch, prompt_len=args.prompt_len,
                    max_len=args.prompt_len + args.gen)
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(args.requests, args.prompt_len),
        dtype=np.int32)
    t0 = time.time()
    out = server.serve_batch(prompts, args.gen)
    dt = time.time() - t0
    m = server.metrics
    print(f"done: {args.requests} requests x {args.gen} tokens in "
          f"{dt:.1f}s ({args.requests * args.gen / dt:.1f} tok/s) "
          f"occupancy={m.occupancy:.2f} "
          f"p50={m.latency.percentile(50) * 1e3:.0f}ms "
          f"p99={m.latency.percentile(99) * 1e3:.0f}ms "
          f"(out shape {out.shape})")
    print(f"stats: {server.stats()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
