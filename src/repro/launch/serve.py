"""Serving driver: batched prefill+decode against MVStore snapshots.

The server is the paper's *versioned reader*: every request batch resolves
model parameters at a read clock via `mv_snapshot`, so serving can share
the store with a live trainer (serve-from-trainer) without ever reading a
torn update.  When the store is unversioned (Mode Q) and the trainer
commits mid-request, the read returns ok=False and the batch retries with
a fresh clock — the reader abort path; sustained aborts flip the store to
Mode U through the controller heuristics.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 8 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import queue
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, MVStoreConfig, ParallelConfig,
                           ShapeConfig, get_config, smoke_config)
from repro.core import mvcontroller, mvstore
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import default_rules, use_rules
from repro.models import model_zoo as zoo


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int
    out: Optional[np.ndarray] = None


class Server:
    """Slot-batched server: fixed decode batch, per-batch snapshot read."""

    def __init__(self, cfg, *, batch: int, prompt_len: int, max_len: int,
                 mvcfg=None, mesh=None, controller=None, seed: int = 0,
                 params=None, mv_state=None):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.mesh = mesh if mesh is not None else make_host_mesh()
        self.pcfg = ParallelConfig(
            remat="none", attn_block_q=min(512, prompt_len),
            attn_block_k=min(512, prompt_len))
        self.mvcfg = mvcfg or MVStoreConfig(mode="Q")
        self.rules = default_rules(self.mesh)
        if batch % self.mesh.devices.size != 0:
            self.rules = self.rules.with_(batch=None)
        self.controller = controller
        self.reader = controller.reader() if controller else None
        if mv_state is None:
            with use_rules(self.rules, self.mesh):
                params = params if params is not None else zoo.init_params(
                    cfg, jax.random.PRNGKey(seed))
            versioned = "all" if self.mvcfg.mode in ("U",) else "none"
            mv_state = mvstore.mv_init(params, self.mvcfg,
                                       versioned=versioned)
        self.mv_state = mv_state
        self._prefill = jax.jit(steps_mod.make_prefill_step(
            cfg, self.pcfg, self.mvcfg, self.rules, self.mesh))
        self._decode = jax.jit(steps_mod.make_decode_step(
            cfg, self.pcfg, self.mvcfg, self.rules, self.mesh),
            donate_argnums=(1,))
        self.aborts = 0

    def _snapshot_clock(self) -> jnp.ndarray:
        return self.mv_state.clock

    def serve_batch(self, prompts: np.ndarray, max_new: int
                    ) -> np.ndarray:
        """prompts: [B, S] int32 -> generated [B, max_new] int32."""
        B, S = prompts.shape
        while True:
            rc = self._snapshot_clock()
            if self.reader is not None:
                self.reader.begin(int(rc))
            logits, cache, cache_len, ok = self._prefill(
                self.mv_state, {"tokens": jnp.asarray(prompts)}, rc)
            if bool(ok):
                break
            self.aborts += 1
            if self.reader is not None:
                self.reader.on_abort(S * B)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [toks]
        # pad the cache to max_len for decode appends
        cache = jax.tree.map(
            lambda x: _pad_seq(x, self.max_len) if x.ndim >= 3 else x,
            cache)
        for _ in range(max_new - 1):
            logits, cache, cache_len, ok = self._decode(
                self.mv_state, cache, cache_len, toks, rc)
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(toks)
        if self.reader is not None:
            self.reader.on_commit(B * (S + max_new), int(rc))
        return np.stack([np.asarray(t) for t in out], axis=1)


def _pad_seq(x, max_len):
    """Pad a [.., B, S, d] or [B, S, d] cache leaf's S dim to max_len."""
    seq_axis = x.ndim - 2
    cur = x.shape[seq_axis]
    if cur >= max_len:
        return x
    pad = [(0, 0)] * x.ndim
    pad[seq_axis] = (0, max_len - cur)
    return jnp.pad(x, pad)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend != "none" or cfg.is_encdec:
        print(f"note: {args.arch} needs frontend embeds; serving the "
              "text path only")
    server = Server(cfg, batch=args.batch, prompt_len=args.prompt_len,
                    max_len=args.prompt_len + args.gen)
    rng = np.random.default_rng(0)
    t0 = time.time()
    done = 0
    while done < args.requests:
        prompts = rng.integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len),
            dtype=np.int32)
        out = server.serve_batch(prompts, args.gen)
        done += args.batch
        print(f"served {done}/{args.requests} "
              f"(batch out shape {out.shape})", flush=True)
    dt = time.time() - t0
    print(f"done: {done} requests x {args.gen} tokens in {dt:.1f}s "
          f"({done * args.gen / dt:.1f} tok/s), aborts={server.aborts}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
