import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Two compile flavors per cell:

1. FIT compile (the deliverable): the full production config — scanned layer
   groups, gradient-accumulation scan — lowered with explicit shardings on
   the 16x16 or 2x16x16 mesh and compiled.  memory_analysis() proves the
   cell fits; compile success proves the sharding is coherent.

2. ROOFLINE probes (--probes, single-pod): XLA's cost analysis counts
   while-loop bodies ONCE, so the scanned fit artifact undercounts flops /
   bytes / collective traffic.  Probes re-lower small UNROLLED variants
   (1-2 layer periods, 1-2 microbatches, attention-pair / SSD-chunk /
   decode-chunk loops as python loops) on the SAME mesh and shardings, and
   reconstruct exact per-step totals from the linear structure:
     train:    P(g, m) = S(g) + m*F(g);  S, F linear in layer groups g
     prefill:  P(g)    linear in g  (jamba: quadratic-in-seq fit;
                                     mamba2: linear-in-seq scale)
     decode:   P(g)    linear in g
   Every reconstruction input is itself a compiled artifact's cost
   analysis — no hand-computed flops enter the table.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, MVStoreConfig, ParallelConfig,
                           get_config, get_shape)
from repro.configs.base import ShapeConfig
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import default_rules, use_rules
from repro.launch.steps import (cache_specs, make_decode_step,
                                make_prefill_step, make_train_step,
                                train_state_specs)
from repro.models import model_zoo as zoo
from repro.models import transformer as tfm
from repro.optim import adamw


def default_parallel(cfg, shape, mesh, overrides=None) -> ParallelConfig:
    """Per-cell parallelism defaults (the hillclimb overrides these)."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_ways = axes.get("data", 1) * axes.get("pod", 1)
    kw = {}
    if shape.kind == "train":
        tokens_per_chip = shape.global_batch * shape.seq_len // max(
            data_ways, 1)
        # wide residual streams need smaller microbatches to fit v5e HBM
        mb_tokens = 4096 if cfg.d_model >= 8192 else 8192
        kw["microbatches"] = max(1, min(shape.global_batch // data_ways,
                                        tokens_per_chip // mb_tokens))
        kw["remat"] = "block"
        # two-level remat when the per-period residual saves exceed ~4GB
        from repro.models import transformer as _tfm
        periods = cfg.n_layers // (_tfm.layer_period(cfg)
                                   if not cfg.is_encdec else cfg.n_layers)
        if not cfg.is_encdec:
            per_mb_tok = tokens_per_chip // kw["microbatches"]
            save_bytes = periods * per_mb_tok * cfg.d_model * 2
            if save_bytes > 4e9:
                for k in (2, 4, 8):
                    if periods % k == 0 and save_bytes / k <= 4e9:
                        kw["remat"] = f"group:{k}"
                        break
                else:
                    ks = [k for k in (2, 4, 8) if periods % k == 0]
                    if ks:
                        kw["remat"] = f"group:{ks[-1]}"
    else:
        kw["microbatches"] = 1
        kw["remat"] = "none"
    if shape.kind == "decode" and shape.seq_len >= 262144:
        kw["decode_attn_chunk"] = 8192
    if overrides:
        kw.update(overrides)
    return ParallelConfig(**kw)


def cell_rules(mesh, shape, pcfg, global_batch=None, rules_override=None):
    gb = global_batch if global_batch is not None else shape.global_batch
    ways = 1
    for ax in ("data", "pod"):
        if ax in mesh.axis_names:
            ways *= mesh.devices.shape[mesh.axis_names.index(ax)]
    rules = default_rules(mesh, fsdp=pcfg.fsdp,
                          shard_seq=shape.global_batch == 1)
    if gb % ways != 0:
        rules = rules.with_(batch=None)
    if rules_override:
        rules = rules.with_(**{
            k: (tuple(v) if isinstance(v, list) else v)
            for k, v in rules_override.items()})
    return rules


def shardings_of(tree):
    return jax.tree.map(lambda s: s.sharding, tree)


def compile_once(cfg, shape, mesh, pcfg, mvcfg, opt_cfg, rules):
    """Lower + compile one step; return (compiled, timings)."""
    t0 = time.time()
    with use_rules(rules, mesh):
        if shape.kind == "train":
            step = make_train_step(cfg, pcfg, mvcfg, opt_cfg, rules, mesh)
            state = train_state_specs(cfg, mvcfg, rules, mesh, opt_cfg)
            batch = zoo.input_specs(cfg, shape, rules, mesh)
            fn = jax.jit(step,
                         in_shardings=(shardings_of(state),
                                       shardings_of(batch)),
                         out_shardings=(shardings_of(state), None),
                         donate_argnums=(0,))
            lowered = fn.lower(state, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, pcfg, mvcfg, rules, mesh)
            state = train_state_specs(cfg, mvcfg, rules, mesh, opt_cfg).mv
            batch = zoo.input_specs(cfg, shape, rules, mesh)
            clock = jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P()))
            lowered = jax.jit(step).lower(state, batch, clock)
        else:  # decode
            step = make_decode_step(cfg, pcfg, mvcfg, rules, mesh)
            state = train_state_specs(cfg, mvcfg, rules, mesh, opt_cfg).mv
            cache = cache_specs(cfg, shape, rules, mesh)
            inp = zoo.input_specs(cfg, shape, rules, mesh)
            clock = jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P()))
            fn = jax.jit(step, donate_argnums=(1,))
            lowered = fn.lower(state, cache, inp["cache_len"],
                               inp["token"], clock)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    return compiled, {"lower_s": round(t_lower, 1),
                      "compile_s": round(time.time() - t0 - t_lower, 1)}


_NUM_KEYS = ("flops", "bytes", "tpu_bytes", "wire_bytes",
             "coll_result_bytes")


def _metrics(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax<=0.4.x: one dict per device
        cost = cost[0]
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    coll = roofline.collective_bytes(text)
    tb = roofline.tpu_bytes_model(text)
    return {
        "flops": float(cost.get("flops") or 0.0),
        "bytes": float(cost.get("bytes accessed") or 0.0),
        "tpu_bytes": float(tb.get("tpu_bytes") or 0.0),
        "wire_bytes": float(coll.get("total_wire_bytes") or 0.0),
        "coll_result_bytes": float(coll.get("total_result_bytes") or 0.0),
        "coll_ops": coll.get("ops", {}),
        "coll_top": coll.get("top", []),
    }


def _probe_cfgs(cfg):
    period = tfm.layer_period(cfg) if not cfg.is_encdec else 1

    def reduced(g):
        kw = {"n_layers": g * period}
        if cfg.is_encdec:
            kw["n_encoder_layers"] = g
        return dataclasses.replace(cfg, **kw)

    return reduced


def run_probes(arch, shape_name, *, mv_mode, overrides,
               rules_override=None):
    """Roofline probes on the single-pod mesh; returns reconstructed
    per-device metrics + the probe ledger."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=False)
    pcfg0 = default_parallel(cfg, shape, mesh, overrides)
    mvcfg = MVStoreConfig(enabled=True, mode=mv_mode)
    opt_cfg = adamw.AdamWConfig()
    reduced = _probe_cfgs(cfg)
    G = (cfg.n_layers // tfm.layer_period(cfg)) if not cfg.is_encdec \
        else cfg.n_layers
    M = pcfg0.microbatches
    ledger = []

    def probe(g, m=1, seq=None):
        cfg_g = reduced(g)
        gb = shape.global_batch
        sq = shape.seq_len
        if shape.kind == "train":
            gb = m * (shape.global_batch // M)
        if seq is not None:
            sq = seq
        shp = ShapeConfig(shape.name, sq, gb, shape.kind)
        pcfg = dataclasses.replace(pcfg0, microbatches=m, probe_unroll=True,
                                   scan_layers=False)
        rules = cell_rules(mesh, shape, pcfg, global_batch=gb,
                           rules_override=rules_override)
        c, t = compile_once(cfg_g, shp, mesh, pcfg, mvcfg, opt_cfg, rules)
        met = _metrics(c)
        ledger.append({"g": g, "m": m, "seq": sq, "batch": gb, **t,
                       **{k: met[k] for k in ("flops", "bytes", "tpu_bytes",
                                              "wire_bytes")}})
        return met

    ssm_prefill = (cfg.family in ("ssm", "hybrid")
                   and shape.kind == "prefill")
    if shape.kind == "train":
        p11, p21 = probe(1, 1), probe(2, 1)
        p12, p22 = probe(1, 2), probe(2, 2)
        F1 = {k: p12[k] - p11[k] for k in _NUM_KEYS}
        F2 = {k: p22[k] - p21[k] for k in _NUM_KEYS}
        S1 = {k: 2 * p11[k] - p12[k] for k in _NUM_KEYS}
        S2 = {k: 2 * p21[k] - p22[k] for k in _NUM_KEYS}
        total = {k: S1[k] + (G - 1) * (S2[k] - S1[k])
                 + M * (F1[k] + (G - 1) * (F2[k] - F1[k]))
                 for k in _NUM_KEYS}
    elif ssm_prefill and cfg.family == "ssm":
        s1 = 4096
        p1, p2 = probe(1, seq=s1), probe(2, seq=s1)
        scale = shape.seq_len / s1
        total = {k: scale * (p1[k] + (G - 1) * (p2[k] - p1[k]))
                 for k in _NUM_KEYS}
    elif ssm_prefill:  # hybrid: quadratic-in-seq fit (attention layers)
        s1, s2, st = 4096, 8192, shape.seq_len

        def fit(pa, pb):
            out = {}
            for k in _NUM_KEYS:
                c2 = (pb[k] - 2 * pa[k]) / (2.0 * s1 * s1)
                b1 = (4 * pa[k] - pb[k]) / (2.0 * s1)
                out[k] = b1 * st + c2 * st * st
            return out

        q1 = fit(probe(1, seq=s1), probe(1, seq=s2))
        q2 = fit(probe(2, seq=s1), probe(2, seq=s2))
        total = {k: q1[k] + (G - 1) * (q2[k] - q1[k]) for k in _NUM_KEYS}
    else:
        p1, p2 = probe(1), probe(2)
        total = {k: p1[k] + (G - 1) * (p2[k] - p1[k]) for k in _NUM_KEYS}
    total = {k: max(v, 0.0) for k, v in total.items()}
    return total, ledger


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               mv_mode: str = "Q", overrides=None, probes: bool = False,
               rules_override=None):
    """Fit-compile one cell (+ optional roofline probes); result dict."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = cfg.supports_shape(shape)
    mesh_name = "multipod" if multi_pod else "pod"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "mv_mode": mv_mode, "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    pcfg = default_parallel(cfg, shape, mesh, overrides)
    rules = cell_rules(mesh, shape, pcfg, rules_override=rules_override)
    mvcfg = MVStoreConfig(enabled=True, mode=mv_mode)
    opt_cfg = adamw.AdamWConfig()

    compiled, times = compile_once(cfg, shape, mesh, pcfg, mvcfg, opt_cfg,
                                   rules)
    mem = compiled.memory_analysis()
    fit_metrics = _metrics(compiled)
    n_chips = mesh.devices.size

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mv_mode": mv_mode, "status": "ok", "n_chips": n_chips,
        "microbatches": pcfg.microbatches, "overrides": overrides or {},
        "rules_override": rules_override or {},
        **times,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "fit_metrics_scanned": {k: fit_metrics[k]
                                for k in ("flops", "bytes", "wire_bytes")},
        "collective_ops": fit_metrics["coll_ops"],
    }
    if probes and not multi_pod:
        recon, ledger = run_probes(arch, shape_name, mv_mode=mv_mode,
                                   overrides=overrides,
                                   rules_override=rules_override)
        result["probe_metrics"] = recon
        result["probe_ledger"] = ledger
        result["roofline"] = roofline.roofline_terms(
            cfg, shape,
            cost={"flops": recon["flops"],
                  "bytes accessed": recon["tpu_bytes"],
                  "bytes_raw": recon["bytes"]},
            collectives={"total_wire_bytes": recon["wire_bytes"]},
            n_chips=n_chips)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--mvmode", default="Q", choices=["Q", "U"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--probes", action="store_true",
                    help="run roofline probes (single-pod cells only)")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ParallelConfig overrides")
    ap.add_argument("--rules-override", default=None,
                    help="JSON dict of logical-axis rule overrides, e.g. "
                         "'{\"tp\": null}' for no tensor parallelism")
    args = ap.parse_args(argv)

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    overrides = json.loads(args.override) if args.override else None
    rules_override = (json.loads(args.rules_override)
                      if args.rules_override else None)
    rc = 0
    for arch, shape, m in cells:
        try:
            res = lower_cell(arch, shape, multi_pod=(m == "multipod"),
                             mv_mode=args.mvmode, overrides=overrides,
                             probes=args.probes,
                             rules_override=rules_override)
        except Exception as e:  # noqa: BLE001 — record, keep sweeping
            res = {"arch": arch, "shape": shape, "mesh": m,
                   "mv_mode": args.mvmode, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            rc = 1
        line = json.dumps(res)
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")
        brief = {k: v for k, v in res.items()
                 if k not in ("trace", "probe_ledger", "collective_ops")}
        print(json.dumps(brief), flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
