"""Step functions: train / prefill / decode, with MVStore commit semantics.

These are the functions the dry-run lowers and the drivers execute.  The
MVStore mode is baked in at trace time (the compiled step's *local mode*,
DESIGN.md SS2); the controller swaps variants at step boundaries.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, MVStoreConfig, ParallelConfig,
                                RunConfig, ShapeConfig)
from repro.core import mvstore
from repro.core.mvstore import MVStoreState
from repro.launch.sharding import (Rules, abstract_params, param_specs,
                                   shard_act, use_rules)
from repro.models import model_zoo as zoo
from repro.optim import adamw


class TrainState(NamedTuple):
    mv: MVStoreState
    opt: adamw.AdamWState


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig,
                    mvcfg: MVStoreConfig, opt_cfg: adamw.AdamWConfig,
                    rules: Optional[Rules] = None, mesh=None):
    """Returns train_step(state, batch) -> (state', metrics)."""

    def loss_of(params, mb):
        return zoo.loss_fn(params, mb, cfg, pcfg)

    specs = (param_specs(zoo.model_meta(cfg), rules)
             if rules is not None and mesh is not None else None)

    def constrain(tree):
        """Pin gradient/accumulator sharding to the parameter sharding —
        GSPMD otherwise leaves the scan-carried accumulator unconstrained
        and can replicate multi-GB gradient buffers."""
        if specs is None:
            return tree
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)), tree, specs)

    def train_step(state: TrainState, batch):
        with use_rules(rules, mesh):
            params = state.mv.live
            M = pcfg.microbatches
            if M == 1:
                loss, grads = jax.value_and_grad(loss_of)(params, batch)
                grads = constrain(jax.tree.map(
                    lambda g: g.astype(jnp.float32), grads))
            else:
                mbs = jax.tree.map(
                    lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                    batch)

                def mb_body(acc, mb):
                    loss, g = jax.value_and_grad(loss_of)(params, mb)
                    acc = constrain(jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), acc, g))
                    return acc, loss

                acc0 = constrain(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params))
                if pcfg.probe_unroll:
                    losses = []
                    grads = acc0
                    for i in range(M):
                        grads, li = mb_body(
                            grads, jax.tree.map(lambda x: x[i], mbs))
                        losses.append(li)
                    losses = jnp.stack(losses)
                else:
                    grads, losses = jax.lax.scan(mb_body, acc0, mbs)
                grads = jax.tree.map(lambda g: g / M, grads)
                loss = jnp.mean(losses)

            new_params, new_opt = adamw.apply(grads, state.opt, params,
                                              opt_cfg)
            if mvcfg.enabled and mvcfg.fused_commit and state.mv.ring:
                new_mv = _fused_commit(state.mv, grads, state.opt, params,
                                       opt_cfg, mvcfg)
                new_opt = new_mv.pop("opt")
                new_mv = new_mv["mv"]
            elif mvcfg.enabled:
                new_mv = mvstore.mv_commit(state.mv, new_params,
                                           local_mode=mvcfg.mode, cfg=mvcfg)
            else:
                nc = state.mv.clock + 1
                bc = state.mv.block_clocks
                if bc is not None:      # whole-store step stamps every block
                    stamp = nc.astype(jnp.int32)
                    bc = {p: stamp for p in bc}
                new_mv = state.mv._replace(live=new_params, clock=nc,
                                           block_clocks=bc)
            metrics = {"loss": loss, "clock": new_mv.clock}
            return TrainState(new_mv, new_opt), metrics

    return train_step


def _fused_commit(mv, grads, opt, params, opt_cfg, mvcfg):
    """Fused AdamW + versioned ring write via the Pallas kernel path
    (beyond-paper SSPerf optimization; ref semantics = adamw.apply +
    mv_commit)."""
    from repro.kernels import ops as kops
    new_clock = mv.clock + 1
    slot = (new_clock % mvcfg.ring_slots).astype(jnp.int32)
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    gflat = jax.tree.leaves(grads)
    mflat = jax.tree.leaves(opt.mu)
    vflat = jax.tree.leaves(opt.nu)
    count = opt.count + 1
    gnorm = adamw.global_norm(grads)
    scale = jnp.minimum(1.0, opt_cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = adamw.schedule(count.astype(jnp.float32), opt_cfg)
    new_p, new_m, new_v, new_ring, new_ts = [], [], [], {}, {}
    for (pth, p), g, m, v in zip(flat, gflat, mflat, vflat):
        path = jax.tree_util.keystr(pth)
        ring = mv.ring.get(path)
        p2, m2, v2, r2 = kops.fused_adamw(
            p, g, m, v, ring, slot, lr=lr, scale=scale, count=count,
            b1=opt_cfg.b1, b2=opt_cfg.b2, eps=opt_cfg.eps,
            wd=opt_cfg.weight_decay if p.ndim >= 2 else 0.0)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
        if ring is not None:
            new_ring[path] = r2
            new_ts[path] = jax.lax.dynamic_update_index_in_dim(
                mv.ring_ts[path], new_clock.astype(jnp.int32), slot, 0)
    params2 = jax.tree.unflatten(tdef, new_p)
    mu2 = jax.tree.unflatten(tdef, new_m)
    nu2 = jax.tree.unflatten(tdef, new_v)
    bc = mv.block_clocks
    if bc is not None:                  # fused step stamps every block too
        stamp = new_clock.astype(jnp.int32)
        bc = {p: stamp for p in bc}
    return {"mv": MVStoreState(params2, new_ring, new_ts, new_clock, bc),
            "opt": adamw.AdamWState(mu2, nu2, count)}


# ---------------------------------------------------------------------------
# serve (prefill / decode) — versioned reads
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, pcfg: ParallelConfig,
                      mvcfg: MVStoreConfig, rules: Optional[Rules] = None,
                      mesh=None):
    def prefill_step(mv_state: MVStoreState, batch, read_clock):
        with use_rules(rules, mesh):
            params, ok = _read_params(mv_state, read_clock, mvcfg)
            logits, cache, cache_len = zoo.prefill_fn(params, batch, cfg,
                                                      pcfg)
            return logits, cache, cache_len, ok

    return prefill_step


def make_decode_step(cfg: ModelConfig, pcfg: ParallelConfig,
                     mvcfg: MVStoreConfig, rules: Optional[Rules] = None,
                     mesh=None):
    def decode_step(mv_state: MVStoreState, cache, cache_len, token,
                    read_clock):
        with use_rules(rules, mesh):
            params, ok = _read_params(mv_state, read_clock, mvcfg)
            logits, cache, cache_len = zoo.decode_fn(
                params, cache, cache_len, token, cfg, pcfg)
            return logits, cache, cache_len, ok

    return decode_step


def _read_params(mv_state: MVStoreState, read_clock, mvcfg: MVStoreConfig):
    if not mvcfg.enabled:
        return mv_state.live, jnp.asarray(True)
    return mvstore.mv_snapshot(
        mv_state, read_clock,
        assume_versioned=mvcfg.mode in ("U", "UtoQ"),
        impl="pallas" if mvcfg.fused_commit else "xla")


# ---------------------------------------------------------------------------
# abstract state builders (dry-run)
# ---------------------------------------------------------------------------


def train_state_specs(cfg: ModelConfig, mvcfg: MVStoreConfig, rules: Rules,
                      mesh, opt_cfg: adamw.AdamWConfig):
    """ShapeDtypeStructs for TrainState under the given sharding rules."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    meta = zoo.model_meta(cfg)
    live = abstract_params(meta, rules, mesh)
    moments_meta = jax.tree.map(
        lambda m: m.__class__(m.shape, m.axes, init="zeros",
                              dtype=opt_cfg.moment_dtype),
        meta, is_leaf=lambda x: hasattr(x, "axes"))
    mu = abstract_params(moments_meta, rules, mesh)
    nu = abstract_params(moments_meta, rules, mesh)
    scal = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    ring, ring_ts = {}, {}
    if mvcfg.enabled and mvcfg.mode in ("U", "QtoU", "UtoQ"):
        flat, _ = jax.tree_util.tree_flatten_with_path(live)
        for p, leaf in flat:
            path = jax.tree_util.keystr(p)
            rspec = P(*((None,) + tuple(leaf.sharding.spec)))
            ring[path] = jax.ShapeDtypeStruct(
                (mvcfg.ring_slots,) + leaf.shape, leaf.dtype,
                sharding=NamedSharding(mesh, rspec))
            ring_ts[path] = jax.ShapeDtypeStruct(
                (mvcfg.ring_slots,), jnp.int32,
                sharding=NamedSharding(mesh, P(None)))
    flat_live, _ = jax.tree_util.tree_flatten_with_path(live)
    bclocks = {jax.tree_util.keystr(p): scal for p, _ in flat_live}
    mv = MVStoreState(live=live, ring=ring, ring_ts=ring_ts, clock=scal,
                      block_clocks=bclocks)
    opt = adamw.AdamWState(mu=mu, nu=nu, count=scal)
    return TrainState(mv=mv, opt=opt)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, rules: Rules, mesh):
    from jax.sharding import NamedSharding

    axes = zoo.cache_axes(cfg)
    # shapes from a zero-cost eval_shape of init_cache
    struct = jax.eval_shape(
        lambda: zoo.init_cache(cfg, shape.global_batch, shape.seq_len,
                               jnp.bfloat16))

    def one(leaf_struct, ax):
        return jax.ShapeDtypeStruct(
            leaf_struct.shape, leaf_struct.dtype,
            sharding=NamedSharding(mesh, rules.spec(ax)))

    def walk(s, a):
        if isinstance(s, dict):
            return {k: walk(s[k], a[k]) for k in s}
        return one(s, a)

    return walk(struct, axes)
