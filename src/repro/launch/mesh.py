"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax


def _axis_types(n: int):
    """jax.sharding.AxisType landed in jax 0.4.35; older jax infers Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 (data, model).  Multi-pod: 2x16x16 (pod, data,
    model) — the 'pod' axis is DP by default and the pipeline axis when
    ``ParallelConfig.pipeline_stages > 1``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small-scale runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_types(len(axes)))


def make_host_mesh():
    """Whatever devices exist right now, as a 1D 'data' mesh (trainer)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
