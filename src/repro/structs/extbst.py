"""External (leaf-oriented) BST on the STM word heap (paper Appendix A).

Node layout: [0]=is_leaf, [1]=key, [2]=left, [3]=right, [4]=value.
Internal nodes route (keys < k go left); leaves hold the actual pairs.
Delete unlinks the leaf and replaces its parent with the sibling.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, List, Optional, Tuple

from repro.core.engine.traverse import traverse_bulk

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.api.substrate import Substrate, Txn

NULL = 0


class ExternalBST:
    NODE = 5

    def __init__(self, tm: "Substrate"):
        self.tm = tm
        tm.alloc(1)
        self.root_ptr = tm.alloc(1, NULL)

    def _leaf(self, tx: "Txn", key, value) -> int:
        n = tx.alloc(self.NODE)
        tx.write(n, 1)
        tx.write(n + 1, key)
        tx.write(n + 2, NULL)
        tx.write(n + 3, NULL)
        tx.write(n + 4, value)
        return n

    def _internal(self, tx: "Txn", key, left, right) -> int:
        n = tx.alloc(self.NODE)
        tx.write(n, 0)
        tx.write(n + 1, key)
        tx.write(n + 2, left)
        tx.write(n + 3, right)
        # routing nodes carry no value; NULL (not None) keeps the node
        # representable on numeric heaps (ArrayHeap / MVStore blocks)
        tx.write(n + 4, NULL)
        return n

    def search(self, tx: "Txn", key: int) -> Optional[object]:
        node = tx.read(self.root_ptr)
        if node == NULL:
            return None
        while not tx.read(node):
            node = tx.read(node + 2) if key < tx.read(node + 1) \
                else tx.read(node + 3)
        if tx.read(node + 1) == key:
            return tx.read(node + 4)
        return None

    def insert(self, tx: "Txn", key: int, value) -> bool:
        node = tx.read(self.root_ptr)
        if node == NULL:
            tx.write(self.root_ptr, self._leaf(tx, key, value))
            return True
        parent, went_left = NULL, False
        while not tx.read(node):
            parent = node
            went_left = key < tx.read(node + 1)
            node = tx.read(node + 2) if went_left else tx.read(node + 3)
        lk = tx.read(node + 1)
        if lk == key:
            tx.write(node + 4, value)
            return False
        new_leaf = self._leaf(tx, key, value)
        if key < lk:
            inner = self._internal(tx, lk, new_leaf, node)
        else:
            inner = self._internal(tx, key, node, new_leaf)
        if parent == NULL:
            tx.write(self.root_ptr, inner)
        else:
            tx.write(parent + (2 if went_left else 3), inner)
        return True

    def delete(self, tx: "Txn", key: int) -> bool:
        node = tx.read(self.root_ptr)
        if node == NULL:
            return False
        parent, grand, p_left, g_left = NULL, NULL, False, False
        while not tx.read(node):
            grand, g_left = parent, p_left
            parent = node
            p_left = key < tx.read(node + 1)
            node = tx.read(node + 2) if p_left else tx.read(node + 3)
        if tx.read(node + 1) != key:
            return False
        if parent == NULL:
            tx.write(self.root_ptr, NULL)
            return True
        sibling = tx.read(parent + (3 if p_left else 2))
        if grand == NULL:
            tx.write(self.root_ptr, sibling)
        else:
            tx.write(grand + (2 if g_left else 3), sibling)
        return True

    def upsert_touch(self, tx: "Txn", key: int, value) -> None:
        self.insert(tx, key, value)

    def range_query(self, tx: "Txn", lo: int, count: int) -> List[Tuple[int,
                                                                 object]]:
        """Collect up to `count` pairs with key >= lo (in key order).

        Frontier-at-a-time: the recursive DFS is an explicit ordered
        worklist (``engine.traverse.traverse_bulk``) — per round, ONE
        ``read_bulk`` batch gathers every pending node's 5 words, and
        each node expands in place into its in-order children / leaf
        emission.  Emission order and the ``count`` cutoff match the
        scalar DFS exactly, and tree depth costs worklist length, not
        Python stack — a degenerate (sorted-insert) tree deeper than
        ``sys.getrecursionlimit()`` traverses fine.
        """
        root = tx.read(self.root_ptr)
        if root == NULL:
            return []

        def expand(state, w, emit, push):
            if w[0]:                          # leaf
                k = w[1]
                if k >= lo:
                    emit((k, w[4]))
            else:                             # internal: keys < w[1] left
                if lo < w[1]:
                    push(w[2], self.NODE)
                push(w[3], self.NODE)

        return traverse_bulk(tx, [(root, self.NODE)], expand, limit=count)
