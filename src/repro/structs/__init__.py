"""Transactional data structures over the `repro.api` substrate surface.

Each structure takes any `make_tm(...)` product (or raw TM) at
construction and uniform `Txn` handles per operation, so one
implementation serves every backend — since the engine refactor that
means any `TMPolicy` over `repro.core.engine`, including third-party
backends registered via `register_backend`.  Long read-only operations
(range queries, size queries) can poll `tx.validate_bulk()` to fail fast
on staleness; the engine answers it with one vectorized pass over the
whole read set.  Contiguous regions (hashmap bucket heads, abtree nodes)
read through `tx.read_bulk`, so the long-running reads the paper studies
move in batches instead of word-at-a-time Python.
"""
from repro.structs.abtree import ABTree  # noqa: F401
from repro.structs.extbst import ExternalBST  # noqa: F401
from repro.structs.hashmap import HashMap  # noqa: F401

STRUCTS = {"abtree": ABTree, "hashmap": HashMap, "extbst": ExternalBST}
