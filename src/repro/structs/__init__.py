"""Transactional data structures over the `repro.api` substrate surface.

Each structure takes any `make_tm(...)` product (or raw TM) at
construction and uniform `Txn` handles per operation, so one
implementation serves every backend.
"""
from repro.structs.abtree import ABTree  # noqa: F401
from repro.structs.extbst import ExternalBST  # noqa: F401
from repro.structs.hashmap import HashMap  # noqa: F401

STRUCTS = {"abtree": ABTree, "hashmap": HashMap, "extbst": ExternalBST}
