"""Transactional data structures over the `repro.api` substrate surface.

Each structure takes any `make_tm(...)` product (or raw TM) at
construction and uniform `Txn` handles per operation, so one
implementation serves every backend — since the engine refactor that
means any `TMPolicy` over `repro.core.engine`, including third-party
backends registered via `register_backend`.  Long read-only operations
(range queries, size queries) can poll `tx.validate_bulk()` to fail fast
on staleness; the engine answers it with one vectorized pass over the
whole read set.  The long reads themselves are frontier-at-a-time
(`repro.core.engine.traverse`): contiguous regions move through
`tx.read_bulk`, hashmap overflow chains advance in lockstep
(`chase_bulk`), and the tree range queries are ordered frontier walks
(`traverse_bulk`, one batch per level) — so the long-running reads the
paper studies vectorize end-to-end instead of chasing pointers
word-at-a-time through Python.
"""
from repro.structs.abtree import ABTree  # noqa: F401
from repro.structs.extbst import ExternalBST  # noqa: F401
from repro.structs.hashmap import HashMap  # noqa: F401

STRUCTS = {"abtree": ABTree, "hashmap": HashMap, "extbst": ExternalBST}
