from repro.structs.abtree import ABTree  # noqa: F401
from repro.structs.extbst import ExternalBST  # noqa: F401
from repro.structs.hashmap import HashMap  # noqa: F401

STRUCTS = {"abtree": ABTree, "hashmap": HashMap, "extbst": ExternalBST}
