"""(a,b)-tree on the STM word heap (the paper's main benchmark, SS5).

Node layout (contiguous words):
  [0] is_leaf, [1] nkeys, [2:2+b] keys,
  leaf:     [2+b : 2+2b]   values
  internal: [2+b : 2+2b+1] children (nkeys+1 used)

Insertion splits full nodes preemptively on the way down (classic B-tree);
deletion is relaxed (keys removed in place, no merging) — a documented
simplification that preserves the workload's read/write shape.  Range
queries DFS the subtree in key order: the long read-only transactions the
paper studies.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, List, Optional, Tuple

from repro.core.engine.traverse import traverse_bulk

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.api.substrate import Substrate, Txn

NULL = 0


class ABTree:
    def __init__(self, tm: "Substrate", a: int = 4, b: int = 16):
        self.tm = tm
        self.a, self.b = a, b
        self.node_words = 2 + b + (b + 1)
        tm.alloc(1)                       # burn address 0 (NULL sentinel)
        self.root_ptr = tm.alloc(1, NULL)

    # -- node helpers (operate through a tx) -------------------------------
    def _new_node(self, tx: "Txn", is_leaf: bool) -> int:
        base = tx.alloc(self.node_words, None)
        tx.write(base, 1 if is_leaf else 0)
        tx.write(base + 1, 0)
        return base

    def _keys_off(self, i: int) -> int:
        return 2 + i

    def _vals_off(self, i: int) -> int:
        return 2 + self.b + i

    def _child_off(self, i: int) -> int:
        return 2 + self.b + i

    def _node_keys(self, tx: "Txn", node: int) -> List[int]:
        n = tx.read(node + 1)
        return [tx.read(node + self._keys_off(i)) for i in range(n)]

    # -- operations --------------------------------------------------------
    def search(self, tx: "Txn", key: int) -> Optional[object]:
        node = tx.read(self.root_ptr)
        if node == NULL:
            return None
        while True:
            is_leaf = tx.read(node)
            n = tx.read(node + 1)
            if is_leaf:
                for i in range(n):
                    if tx.read(node + self._keys_off(i)) == key:
                        return tx.read(node + self._vals_off(i))
                return None
            ci = 0
            while ci < n and key >= tx.read(node + self._keys_off(ci)):
                ci += 1
            node = tx.read(node + self._child_off(ci))

    def _split_child(self, tx: "Txn", parent: int, ci: int, child: int) -> None:
        """Split a full child; parent is guaranteed non-full."""
        b = self.b
        is_leaf = tx.read(child)
        mid = b // 2
        right = self._new_node(tx, bool(is_leaf))
        # move upper half keys (and values/children) to `right`
        if is_leaf:
            sep = tx.read(child + self._keys_off(mid))
            rn = b - mid
            for i in range(rn):
                tx.write(right + self._keys_off(i),
                         tx.read(child + self._keys_off(mid + i)))
                tx.write(right + self._vals_off(i),
                         tx.read(child + self._vals_off(mid + i)))
            tx.write(right + 1, rn)
            tx.write(child + 1, mid)
        else:
            sep = tx.read(child + self._keys_off(mid))
            rn = b - mid - 1
            for i in range(rn):
                tx.write(right + self._keys_off(i),
                         tx.read(child + self._keys_off(mid + 1 + i)))
            for i in range(rn + 1):
                tx.write(right + self._child_off(i),
                         tx.read(child + self._child_off(mid + 1 + i)))
            tx.write(right + 1, rn)
            tx.write(child + 1, mid)
        # shift parent entries right of ci
        pn = tx.read(parent + 1)
        for i in range(pn - 1, ci - 1, -1):
            tx.write(parent + self._keys_off(i + 1),
                     tx.read(parent + self._keys_off(i)))
        for i in range(pn, ci, -1):
            tx.write(parent + self._child_off(i + 1),
                     tx.read(parent + self._child_off(i)))
        tx.write(parent + self._keys_off(ci), sep)
        tx.write(parent + self._child_off(ci + 1), right)
        tx.write(parent + 1, pn + 1)

    def insert(self, tx: "Txn", key: int, value) -> bool:
        """Returns True if inserted, False if key existed (value updated)."""
        b = self.b
        root = tx.read(self.root_ptr)
        if root == NULL:
            leaf = self._new_node(tx, True)
            tx.write(leaf + self._keys_off(0), key)
            tx.write(leaf + self._vals_off(0), value)
            tx.write(leaf + 1, 1)
            tx.write(self.root_ptr, leaf)
            return True
        if tx.read(root + 1) == b:               # split full root
            new_root = self._new_node(tx, False)
            tx.write(new_root + self._child_off(0), root)
            self._split_child(tx, new_root, 0, root)
            tx.write(self.root_ptr, new_root)
            root = new_root
        node = root
        while True:
            n = tx.read(node + 1)
            if tx.read(node):                     # leaf
                pos = 0
                while pos < n and tx.read(node + self._keys_off(pos)) < key:
                    pos += 1
                if pos < n and tx.read(node + self._keys_off(pos)) == key:
                    tx.write(node + self._vals_off(pos), value)
                    return False
                for i in range(n - 1, pos - 1, -1):
                    tx.write(node + self._keys_off(i + 1),
                             tx.read(node + self._keys_off(i)))
                    tx.write(node + self._vals_off(i + 1),
                             tx.read(node + self._vals_off(i)))
                tx.write(node + self._keys_off(pos), key)
                tx.write(node + self._vals_off(pos), value)
                tx.write(node + 1, n + 1)
                return True
            ci = 0
            while ci < n and key >= tx.read(node + self._keys_off(ci)):
                ci += 1
            child = tx.read(node + self._child_off(ci))
            if tx.read(child + 1) == b:
                self._split_child(tx, node, ci, child)
                if key >= tx.read(node + self._keys_off(ci)):
                    child = tx.read(node + self._child_off(ci + 1))
            node = child

    def delete(self, tx: "Txn", key: int) -> bool:
        """Relaxed delete: remove from leaf, no rebalancing."""
        node = tx.read(self.root_ptr)
        if node == NULL:
            return False
        while True:
            n = tx.read(node + 1)
            if tx.read(node):
                for i in range(n):
                    if tx.read(node + self._keys_off(i)) == key:
                        for j in range(i, n - 1):
                            tx.write(node + self._keys_off(j),
                                     tx.read(node + self._keys_off(j + 1)))
                            tx.write(node + self._vals_off(j),
                                     tx.read(node + self._vals_off(j + 1)))
                        tx.write(node + 1, n - 1)
                        return True
                return False
            ci = 0
            while ci < n and key >= tx.read(node + self._keys_off(ci)):
                ci += 1
            node = tx.read(node + self._child_off(ci))

    def upsert_touch(self, tx: "Txn", key: int, value) -> None:
        """Dedicated-updater op: ALWAYS writes (never read-only, SS5)."""
        if not self.insert(tx, key, value):
            pass                                   # insert wrote the value

    def range_query(self, tx: "Txn", lo: int, count: int) -> List[Tuple[int,
                                                                 object]]:
        """Collect up to `count` pairs with key >= lo (in key order).

        Frontier-at-a-time (``engine.traverse.traverse_bulk``): per
        round, ONE ``read_bulk`` batch gathers the contiguous words of
        EVERY pending node (header + keys + values/children — unused
        slots ride along, a slightly wider conflict surface paid for the
        vectorized long read), and nodes expand in place into in-order
        children / leaf emissions, so a query costs one batch per tree
        LEVEL instead of one per node.
        """
        root = tx.read(self.root_ptr)
        if root == NULL:
            return []

        def expand(state, words, emit, push):
            n = int(words[1])
            if int(words[0]):                 # leaf
                for i in range(n):
                    k = int(words[self._keys_off(i)])
                    if k >= lo:
                        emit((k, words[self._vals_off(i)]))
            else:
                for ci in range(n + 1):
                    # child ci holds keys < keys[ci]: skip if all < lo
                    if ci < n and int(words[self._keys_off(ci)]) <= lo:
                        continue
                    child = int(words[self._child_off(ci)])
                    if child != NULL:
                        push(child, self.node_words)

        return traverse_bulk(tx, [(root, self.node_words)], expand,
                             limit=count)
