"""Hashmap with chained buckets on the STM word heap (paper Appendix A).

Node layout: [0]=key, [1]=value, [2]=next.  Size queries (SQ) — atomic
count over every bucket — replace range queries for this structure, as in
the paper (no order-preserving hash).

Structures are substrate-agnostic: `tm` is anything with the
`repro.api.Substrate` alloc surface and ops take the uniform `Txn` handle,
so the same structure runs on Multiverse and on every baseline.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.core.engine.traverse import chase_bulk

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.api.substrate import Substrate, Txn

NULL = 0


class HashMap:
    def __init__(self, tm: "Substrate", n_buckets: int = 1 << 16):
        self.tm = tm
        self.n_buckets = n_buckets
        tm.alloc(1)                      # burn address 0 (NULL)
        self.table = tm.alloc(n_buckets, NULL)

    def _bucket(self, key: int) -> int:
        return self.table + ((key * 0x9E3779B1) % self.n_buckets)

    def search(self, tx: "Txn", key: int) -> Optional[object]:
        node = tx.read(self._bucket(key))
        while node != NULL:
            if tx.read(node) == key:
                return tx.read(node + 1)
            node = tx.read(node + 2)
        return None

    def insert(self, tx: "Txn", key: int, value) -> bool:
        head_addr = self._bucket(key)
        node = tx.read(head_addr)
        while node != NULL:
            if tx.read(node) == key:
                tx.write(node + 1, value)
                return False
            node = tx.read(node + 2)
        new = tx.alloc(3)
        tx.write(new, key)
        tx.write(new + 1, value)
        tx.write(new + 2, tx.read(head_addr))
        tx.write(head_addr, new)
        return True

    def delete(self, tx: "Txn", key: int) -> bool:
        head_addr = self._bucket(key)
        prev = NULL
        node = tx.read(head_addr)
        while node != NULL:
            if tx.read(node) == key:
                nxt = tx.read(node + 2)
                if prev == NULL:
                    tx.write(head_addr, nxt)
                else:
                    tx.write(prev + 2, nxt)
                return True
            prev, node = node, tx.read(node + 2)
        return False

    def upsert_touch(self, tx: "Txn", key: int, value) -> None:
        """Dedicated-updater op: always writes."""
        self.insert(tx, key, value)

    def size_query(self, tx: "Txn") -> int:
        """Atomic size: the long-running read-only transaction (SQ).

        Fully frontier-at-a-time: the contiguous bucket-head array is ONE
        ``read_bulk`` batch, then every overflow chain advances in
        lockstep — round ``r`` gathers the ``r``-th next-pointer of ALL
        live chains in one batch (``engine.traverse.chase_bulk``), so the
        whole sweep costs ``O(max chain length)`` batched reads instead
        of ``O(keys)`` scalar hops.  Advancement is pure numpy; chains
        that end simply drop out of the cursor set.
        """
        heads = np.asarray(
            tx.read_bulk(range(self.table, self.table + self.n_buckets)),
            dtype=np.int64)
        total = 0

        def advance(cur, vals):
            nonlocal total
            total += cur.size              # one live node per cursor
            nxt = np.asarray(vals, dtype=np.int64)
            return nxt[nxt != NULL] + 2    # follow the survivors' next ptr

        chase_bulk(tx, heads[heads != NULL] + 2, advance)
        return total
