"""Hashmap with chained buckets on the STM word heap (paper Appendix A).

Node layout: [0]=key, [1]=value, [2]=next.  Size queries (SQ) — atomic
count over every bucket — replace range queries for this structure, as in
the paper (no order-preserving hash).

Structures are substrate-agnostic: `tm` is anything with the
`repro.api.Substrate` alloc surface and ops take the uniform `Txn` handle,
so the same structure runs on Multiverse and on every baseline.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.api.substrate import Substrate, Txn

NULL = 0


class HashMap:
    def __init__(self, tm: "Substrate", n_buckets: int = 1 << 16):
        self.tm = tm
        self.n_buckets = n_buckets
        tm.alloc(1)                      # burn address 0 (NULL)
        self.table = tm.alloc(n_buckets, NULL)

    def _bucket(self, key: int) -> int:
        return self.table + ((key * 0x9E3779B1) % self.n_buckets)

    def search(self, tx: "Txn", key: int) -> Optional[object]:
        node = tx.read(self._bucket(key))
        while node != NULL:
            if tx.read(node) == key:
                return tx.read(node + 1)
            node = tx.read(node + 2)
        return None

    def insert(self, tx: "Txn", key: int, value) -> bool:
        head_addr = self._bucket(key)
        node = tx.read(head_addr)
        while node != NULL:
            if tx.read(node) == key:
                tx.write(node + 1, value)
                return False
            node = tx.read(node + 2)
        new = tx.alloc(3)
        tx.write(new, key)
        tx.write(new + 1, value)
        tx.write(new + 2, tx.read(head_addr))
        tx.write(head_addr, new)
        return True

    def delete(self, tx: "Txn", key: int) -> bool:
        head_addr = self._bucket(key)
        prev = NULL
        node = tx.read(head_addr)
        while node != NULL:
            if tx.read(node) == key:
                nxt = tx.read(node + 2)
                if prev == NULL:
                    tx.write(head_addr, nxt)
                else:
                    tx.write(prev + 2, nxt)
                return True
            prev, node = node, tx.read(node + 2)
        return False

    def upsert_touch(self, tx: "Txn", key: int, value) -> None:
        """Dedicated-updater op: always writes."""
        self.insert(tx, key, value)

    def size_query(self, tx: "Txn") -> int:
        """Atomic size: the long-running read-only transaction (SQ).

        The bucket-head array is contiguous, so the whole sweep starts as
        ONE ``read_bulk`` batch — the dominant cost at realistic load
        factors, since most buckets are empty and never leave the batch —
        and only the non-empty chains are walked word-at-a-time (they are
        pointer-chases; a future PR could batch per chain hop).
        """
        total = 0
        heads = tx.read_bulk(range(self.table, self.table + self.n_buckets))
        for node in heads:
            node = int(node)
            while node != NULL:
                total += 1
                node = int(tx.read(node + 2))
        return total
