"""Sharded AdamW with global-norm clipping and a linear-warmup cosine
schedule.  Optimizer moments inherit the parameter sharding (ZeRO-style:
with FSDP rules each moment is sharded exactly like its weight)."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    moment_dtype: str = "float32"


def init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state).  All ops elementwise -> sharding of
    every moment/param is preserved (no resharding collectives)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(count.astype(jnp.float32), cfg)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = (p.astype(jnp.float32)
                - lr * (step + decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(params)
    outs = [upd(g, m, v, p)
            for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_p, AdamWState(new_m, new_v, count)
