from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    apply,
    global_norm,
    init,
    schedule,
)
