"""jit'd public wrappers over the Pallas kernels.

These adapt model-layer shapes (GQA heads, parameter pytrees, ring dicts)
to the flat kernel interfaces.  ``interpret`` defaults to True so the whole
suite runs on CPU; TPU deployments flip it via KERNEL_INTERPRET=0.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import fused_adamw as _fa
from repro.kernels import flash_attention as _fl
from repro.kernels import gather_read as _gr
from repro.kernels import scatter_write as _sw
from repro.kernels import snapshot_select as _ss
from repro.kernels import ssd_scan as _ssd
from repro.kernels import validate as _val
from repro.kernels import version_select as _vs

INTERPRET = os.environ.get("KERNEL_INTERPRET", "1") != "0"


def flash_attention(q, k, v, *, causal: bool, block_q: int = 128,
                    block_k: int = 128):
    """q: [B, S, H, D]; k, v: [B, Sk, KV, D] -> [B, S, H, D] (GQA)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * H, Sk, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * H, Sk, D)
    o = _fl.flash_attention_nhd(qf, kf, vf, causal=causal,
                                block_q=block_q, block_k=block_k,
                                interpret=INTERPRET)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


def ssd_scan(xh, dt, A, B_, C_, *, chunk: int = 256, init_state=None):
    """Kernel chunk-scan; final state recomputed via the jnp path when a
    carry is required (see ssd_scan.py)."""
    assert init_state is None, "kernel path serves the no-carry hot loop"
    y = _ssd.ssd_scan_pallas(xh, dt, A, B_, C_, chunk=chunk,
                             interpret=INTERPRET)
    return y, None


def snapshot_select(ring, ts, read_clock):
    """ring: [R, *shape] -> (value [*shape], ok)."""
    R = ring.shape[0]
    shape = ring.shape[1:]
    n = 1
    for s in shape:
        n *= s
    flat = ring.reshape(R, n)
    tile = n
    for cand in (2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            tile = cand
            break
    val, ok = _ss.snapshot_select_flat(flat, ts, read_clock, tile=tile,
                                       interpret=INTERPRET)
    return val.reshape(shape), ok


def snapshot_read(heap, addrs, tile: int = 512):
    """Batched snapshot read: ``heap[addrs]`` in one gather launch.

    ``heap``: [H] (any numeric dtype); ``addrs``: [N] int — returns the
    [N] gathered values as a jax array.  Adapts ragged batch lengths to
    the tiled kernel by padding with address 0 (always allocated — the
    heaps burn it as NULL) and slicing the result back to N.  This is the
    `Txn.read_bulk` / `snapshot_bulk` hot path on TPU
    (KERNEL_INTERPRET=0); on CPU the engine uses the numpy twin (a single
    fancy-index in ``engine.bulkread.heap_gather``) directly.
    """
    n = int(addrs.shape[0])
    if n == 0:
        return jnp.zeros((0,), heap.dtype)
    t = min(tile, 1 << (n - 1).bit_length())
    pad = (-n) % t
    a = jnp.asarray(addrs, jnp.int32)
    if pad:
        a = jnp.pad(a, (0, pad), constant_values=_gr.PAD_ADDR)
    out = _gr.gather_read_flat(jnp.asarray(heap), a, tile=t,
                               interpret=INTERPRET)
    return out[:n]


def write_back(heap, addrs, values, tile: int = 512):
    """Batched commit write-back: ``heap[addrs] = values`` in one launch.

    ``heap``: [H] (any numeric dtype); ``addrs``: [N] int (unique —
    write sets are dict-keyed); ``values``: [N] — returns the [H]
    updated row as an ndarray.  Adapts ragged batch lengths to the tiled
    kernel by padding with the one-past-the-end address (dropped by jax
    scatter semantics, so padding never clobbers a live word) and guards
    the int64 range per the ``version_select`` pattern: without jax x64
    the kernel would silently truncate int64 payloads — AND addresses —
    to int32, so such batches take the numpy twin
    (``scatter_write.np_write_back``, exact at any width) instead; an
    out-of-range address then raises there rather than truncating and
    scattering to the wrong word.  This is the commit-pipeline hot path
    on TPU (KERNEL_INTERPRET=0); on CPU the engine scatters through the
    numpy heap directly (``ArrayHeap.scatter``).
    """
    import numpy as np

    vals = np.asarray(values)
    addrs_np = np.asarray(addrs, np.int64)
    n = int(addrs_np.shape[0])
    if n == 0:
        return np.array(np.asarray(heap), copy=True)
    lo, hi = -(1 << 31) + 1, (1 << 31) - 1

    def _beyond_int32(a):
        return a.dtype == np.int64 and a.size and \
            (int(a.max()) > hi or int(a.min()) < lo)

    # heap CONTENTS are scanned only for host-side heaps: a jax int64
    # heap can only exist with x64 enabled, where ``jnp.asarray`` cannot
    # truncate it — so the device hot path (``scatter_row``) never pays
    # a device->host heap copy or an O(heap) reduction here.  The
    # addr/value guards stay unconditional: their int32 casts below are
    # explicit and would truncate regardless of x64.
    if not isinstance(heap, (np.ndarray, jax.Array)):
        heap = np.asarray(heap)            # lists/tuples: normalize once
    heap_np = heap if isinstance(heap, np.ndarray) else None
    if _beyond_int32(vals) or _beyond_int32(addrs_np) \
            or (heap_np is not None and _beyond_int32(heap_np)):
        return _sw.np_write_back(np.asarray(heap), addrs_np, vals)
    t = min(tile, 1 << (n - 1).bit_length())
    pad = (-n) % t
    hj = jnp.asarray(heap)
    a = jnp.asarray(addrs_np, jnp.int32)
    v = jnp.asarray(vals, hj.dtype)
    if pad:
        a = jnp.pad(a, (0, pad), constant_values=int(hj.shape[0]))
        v = jnp.pad(v, (0, pad))
    out = _sw.scatter_write_flat(hj, a, v, tile=t, interpret=INTERPRET)
    return np.asarray(out)


def validate_readset(ver, own, meta, seen, r_clock, tid, mode,
                     tile: int = 512) -> bool:
    """Bulk read-set validation: True iff every entry is still valid.

    Adapts ragged read-set lengths to the tiled kernel by padding with
    always-valid entries (see ``validate.PAD``), then AND-reduces the
    per-entry mask.  The engine calls this on the TPU path
    (KERNEL_INTERPRET=0); on CPU it uses the numpy twin directly.

    Versions are rebased to ``r_clock`` before the int32 cast: the packed
    lock word carries a 46-bit version and the clock bumps on every
    commit AND abort, so absolute versions can exceed int32 in long runs
    — but every predicate only compares versions against ``r_clock`` or
    ``seen``, and within one transaction's lifetime those deltas are
    tiny.  The clip is a belt-and-braces clamp that preserves the
    comparison's sign (a clamped entry is >= 2^31 commits away from the
    snapshot, i.e. unambiguously stale/fresh).
    """
    import numpy as np

    n = int(ver.shape[0])
    if n == 0:
        return True
    base = int(r_clock)
    lo, hi = -(1 << 31) + 1, (1 << 31) - 1
    ver_rel = np.clip(np.asarray(ver, np.int64) - base, lo, hi)
    seen_rel = np.clip(np.asarray(seen, np.int64) - base, lo, hi)
    t = min(tile, 1 << (n - 1).bit_length())
    pad = (-n) % t
    p = _val.PAD

    def prep(x, fill):
        x = jnp.asarray(np.asarray(x), jnp.int32)
        return jnp.pad(x, (0, pad), constant_values=fill) if pad else x

    mask = _val.validate_readset_flat(
        prep(ver_rel, p["ver"]), prep(own, p["own"]),
        prep(meta, p["meta"]), prep(seen_rel, p["seen"]),
        0, int(tid), int(mode), tile=t, interpret=INTERPRET)
    return bool(jnp.all(mask == 1))


def version_select(ts, data, r_clock, tile: int = 256):
    """Batched snapshot version select over packed VLT mirror rows.

    ``ts``/``data``: [N, D] newest-first (timestamps int, data numeric);
    returns ``(values [N] ndarray, ok [N] bool)`` — per row, the newest
    ``data`` whose timestamp is strictly below ``r_clock`` and whether
    any slot qualified.  Adapts ragged batch sizes to the tiled kernel
    by padding with always-invalid rows and rebases timestamps to
    ``r_clock`` before the int32 cast (absolute clocks exceed int32 in
    long runs; only the sign of ``ts - r_clock`` matters — same
    treatment as ``validate_readset``).  This is the Mode-U bulk
    versioned-read hot path on TPU (KERNEL_INTERPRET=0); on CPU the
    engine uses the numpy twin (``core.vlt.np_version_select``)
    directly.
    """
    import numpy as np

    n = int(ts.shape[0])
    if n == 0:
        return (np.zeros((0,), np.int64), np.zeros((0,), bool))
    lo, hi = -(1 << 31) + 1, (1 << 31) - 1
    data = np.asarray(data)
    if data.dtype == np.int64 and data.size and \
            (int(data.max()) > hi or int(data.min()) < lo):
        # without jax x64 the kernel would silently truncate int64
        # payloads to int32 — wrong values with ok=True; such batches
        # take the numpy twin (exact at any width) instead
        from repro.core.vlt import np_version_select
        return np_version_select(np.asarray(ts, np.int64), data,
                                 int(r_clock))
    rel = np.clip(np.asarray(ts, np.int64) - int(r_clock), lo, hi)
    t = min(tile, 1 << (n - 1).bit_length())
    pad = (-n) % t
    rel = jnp.asarray(rel, jnp.int32)
    d = jnp.asarray(data)
    if pad:
        rel = jnp.pad(rel, ((0, pad), (0, 0)), constant_values=_vs.PAD_TS)
        d = jnp.pad(d, ((0, pad), (0, 0)))
    vals, ok = _vs.version_select_flat(rel, d, 0, tile=t,
                                       interpret=INTERPRET)
    return np.asarray(vals[:n]), np.asarray(ok[:n]) != 0


def fused_adamw(p, g, m, v, ring, slot, *, lr, scale, count, b1, b2, eps,
                wd):
    """Pytree-leaf fused update.  p: any shape; ring: [R, *p.shape]|None."""
    shape = p.shape
    n = p.size
    cnt = count.astype(jnp.float32)
    b1c = 1 - b1 ** cnt
    b2c = 1 - b2 ** cnt
    tile = n
    for cand in (2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            tile = cand
            break
    rf = ring.reshape(ring.shape[0], n) if ring is not None else None
    p2, m2, v2, r2 = _fa.fused_adamw_flat(
        p.reshape(n), g.reshape(n), m.reshape(n), v.reshape(n), rf,
        jnp.asarray(slot, jnp.int32), lr=jnp.asarray(lr),
        scale=jnp.asarray(scale), b1c=b1c, b2c=b2c, b1=b1, b2=b2, eps=eps,
        wd=wd, tile=tile, interpret=INTERPRET)
    p2 = p2.reshape(shape)
    m2 = m2.reshape(shape)
    v2 = v2.reshape(shape)
    if ring is not None:
        r2 = r2.reshape(ring.shape)
    return p2, m2, v2, r2
