"""jit'd public wrappers over the Pallas kernels.

These adapt model-layer shapes (GQA heads, parameter pytrees, ring dicts)
to the flat kernel interfaces.  ``interpret`` defaults to True so the whole
suite runs on CPU; TPU deployments flip it via KERNEL_INTERPRET=0.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import fused_adamw as _fa
from repro.kernels import flash_attention as _fl
from repro.kernels import snapshot_select as _ss
from repro.kernels import ssd_scan as _ssd

INTERPRET = os.environ.get("KERNEL_INTERPRET", "1") != "0"


def flash_attention(q, k, v, *, causal: bool, block_q: int = 128,
                    block_k: int = 128):
    """q: [B, S, H, D]; k, v: [B, Sk, KV, D] -> [B, S, H, D] (GQA)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * H, Sk, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * H, Sk, D)
    o = _fl.flash_attention_nhd(qf, kf, vf, causal=causal,
                                block_q=block_q, block_k=block_k,
                                interpret=INTERPRET)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


def ssd_scan(xh, dt, A, B_, C_, *, chunk: int = 256, init_state=None):
    """Kernel chunk-scan; final state recomputed via the jnp path when a
    carry is required (see ssd_scan.py)."""
    assert init_state is None, "kernel path serves the no-carry hot loop"
    y = _ssd.ssd_scan_pallas(xh, dt, A, B_, C_, chunk=chunk,
                             interpret=INTERPRET)
    return y, None


def snapshot_select(ring, ts, read_clock):
    """ring: [R, *shape] -> (value [*shape], ok)."""
    R = ring.shape[0]
    shape = ring.shape[1:]
    n = 1
    for s in shape:
        n *= s
    flat = ring.reshape(R, n)
    tile = n
    for cand in (2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            tile = cand
            break
    val, ok = _ss.snapshot_select_flat(flat, ts, read_clock, tile=tile,
                                       interpret=INTERPRET)
    return val.reshape(shape), ok


def fused_adamw(p, g, m, v, ring, slot, *, lr, scale, count, b1, b2, eps,
                wd):
    """Pytree-leaf fused update.  p: any shape; ring: [R, *p.shape]|None."""
    shape = p.shape
    n = p.size
    cnt = count.astype(jnp.float32)
    b1c = 1 - b1 ** cnt
    b2c = 1 - b2 ** cnt
    tile = n
    for cand in (2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            tile = cand
            break
    rf = ring.reshape(ring.shape[0], n) if ring is not None else None
    p2, m2, v2, r2 = _fa.fused_adamw_flat(
        p.reshape(n), g.reshape(n), m.reshape(n), v.reshape(n), rf,
        jnp.asarray(slot, jnp.int32), lr=jnp.asarray(lr),
        scale=jnp.asarray(scale), b1c=b1c, b2c=b2c, b1=b1, b2=b2, eps=eps,
        wd=wd, tile=tile, interpret=INTERPRET)
    p2 = p2.reshape(shape)
    m2 = m2.reshape(shape)
    v2 = v2.reshape(shape)
    if ring is not None:
        r2 = r2.reshape(ring.shape)
    return p2, m2, v2, r2
