"""jit'd public wrappers over the Pallas kernels.

These adapt model-layer shapes (GQA heads, parameter pytrees, ring dicts)
to the flat kernel interfaces.  ``interpret`` defaults to True so the whole
suite runs on CPU; TPU deployments flip it via KERNEL_INTERPRET=0.
"""
from __future__ import annotations

import functools
import os
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import commit_fused as _cf
from repro.kernels import fused_adamw as _fa
from repro.kernels import flash_attention as _fl
from repro.kernels import gather_read as _gr
from repro.kernels import scatter_write as _sw
from repro.kernels import snapshot_select as _ss
from repro.kernels import ssd_scan as _ssd
from repro.kernels import validate as _val
from repro.kernels import version_select as _vs

INTERPRET = os.environ.get("KERNEL_INTERPRET", "1") != "0"

# the donated publish paths below request buffer donation unconditionally
# (on TPU it makes the heap/ring update in-place); the CPU backend cannot
# honor it and warns per call — scope the filter to exactly that message
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


def flash_attention(q, k, v, *, causal: bool, block_q: int = 128,
                    block_k: int = 128):
    """q: [B, S, H, D]; k, v: [B, Sk, KV, D] -> [B, S, H, D] (GQA)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * H, Sk, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * H, Sk, D)
    o = _fl.flash_attention_nhd(qf, kf, vf, causal=causal,
                                block_q=block_q, block_k=block_k,
                                interpret=INTERPRET)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


def ssd_scan(xh, dt, A, B_, C_, *, chunk: int = 256, init_state=None):
    """Kernel chunk-scan; final state recomputed via the jnp path when a
    carry is required (see ssd_scan.py)."""
    assert init_state is None, "kernel path serves the no-carry hot loop"
    y = _ssd.ssd_scan_pallas(xh, dt, A, B_, C_, chunk=chunk,
                             interpret=INTERPRET)
    return y, None


def snapshot_select(ring, ts, read_clock):
    """ring: [R, *shape] -> (value [*shape], ok)."""
    R = ring.shape[0]
    shape = ring.shape[1:]
    n = 1
    for s in shape:
        n *= s
    flat = ring.reshape(R, n)
    tile = n
    for cand in (2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            tile = cand
            break
    val, ok = _ss.snapshot_select_flat(flat, ts, read_clock, tile=tile,
                                       interpret=INTERPRET)
    return val.reshape(shape), ok


def snapshot_read(heap, addrs, tile: int = 512):
    """Batched snapshot read: ``heap[addrs]`` in one gather launch.

    ``heap``: [H] (any numeric dtype); ``addrs``: [N] int — returns the
    [N] gathered values as a jax array.  Adapts ragged batch lengths to
    the tiled kernel by padding with address 0 (always allocated — the
    heaps burn it as NULL) and slicing the result back to N.  This is the
    `Txn.read_bulk` / `snapshot_bulk` hot path on TPU
    (KERNEL_INTERPRET=0); on CPU the engine uses the numpy twin (a single
    fancy-index in ``engine.bulkread.heap_gather``) directly.
    """
    n = int(addrs.shape[0])
    if n == 0:
        return jnp.zeros((0,), heap.dtype)
    t = min(tile, 1 << (n - 1).bit_length())
    pad = (-n) % t
    a = jnp.asarray(addrs, jnp.int32)
    if pad:
        a = jnp.pad(a, (0, pad), constant_values=_gr.PAD_ADDR)
    out = _gr.gather_read_flat(jnp.asarray(heap), a, tile=t,
                               interpret=INTERPRET)
    return out[:n]


def write_back(heap, addrs, values, tile: int = 512):
    """Batched commit write-back: ``heap[addrs] = values`` in one launch.

    ``heap``: [H] (any numeric dtype); ``addrs``: [N] int (unique —
    write sets are dict-keyed); ``values``: [N] — returns the [H]
    updated row as an ndarray.  Adapts ragged batch lengths to the tiled
    kernel by padding with the one-past-the-end address (dropped by jax
    scatter semantics, so padding never clobbers a live word) and guards
    the int64 range per the ``version_select`` pattern: without jax x64
    the kernel would silently truncate int64 payloads — AND addresses —
    to int32, so such batches take the numpy twin
    (``scatter_write.np_write_back``, exact at any width) instead; an
    out-of-range address then raises there rather than truncating and
    scattering to the wrong word.  This is the commit-pipeline hot path
    on TPU (KERNEL_INTERPRET=0); on CPU the engine scatters through the
    numpy heap directly (``ArrayHeap.scatter``).
    """
    import numpy as np

    vals = np.asarray(values)
    addrs_np = np.asarray(addrs, np.int64)
    n = int(addrs_np.shape[0])
    if n == 0:
        return np.array(np.asarray(heap), copy=True)
    lo, hi = -(1 << 31) + 1, (1 << 31) - 1

    def _beyond_int32(a):
        return a.dtype == np.int64 and a.size and \
            (int(a.max()) > hi or int(a.min()) < lo)

    # heap CONTENTS are scanned only for host-side heaps: a jax int64
    # heap can only exist with x64 enabled, where ``jnp.asarray`` cannot
    # truncate it — so the device hot path (``scatter_row``) never pays
    # a device->host heap copy or an O(heap) reduction here.  The
    # addr/value guards stay unconditional: their int32 casts below are
    # explicit and would truncate regardless of x64.
    if not isinstance(heap, (np.ndarray, jax.Array)):
        heap = np.asarray(heap)            # lists/tuples: normalize once
    heap_np = heap if isinstance(heap, np.ndarray) else None
    if _beyond_int32(vals) or _beyond_int32(addrs_np) \
            or (heap_np is not None and _beyond_int32(heap_np)):
        return _sw.np_write_back(np.asarray(heap), addrs_np, vals)
    t = min(tile, 1 << (n - 1).bit_length())
    pad = (-n) % t
    hj = jnp.asarray(heap)
    a = jnp.asarray(addrs_np, jnp.int32)
    v = jnp.asarray(vals, hj.dtype)
    if pad:
        a = jnp.pad(a, (0, pad), constant_values=int(hj.shape[0]))
        v = jnp.pad(v, (0, pad))
    out = _sw.scatter_write_flat(hj, a, v, tile=t, interpret=INTERPRET)
    return np.asarray(out)


@functools.partial(jax.jit, donate_argnums=(0,))
def _publish_row_xla(row, addrs, values):
    return row.at[addrs].set(values)


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("tile",))
def _publish_row_pallas(row, addrs, values, *, tile):
    return _sw.scatter_write_flat(row, addrs, values, tile=tile,
                                  interpret=INTERPRET)


def publish_row(row, addrs, values, tile: int = 512):
    """Device-resident row publish: ``row.at[addrs].set(values)`` with
    the input row DONATED.

    The donation contract ``write_back`` cannot offer: that wrapper
    returns an ndarray (a device->host heap copy per call), which is
    fine for the in-place numpy engine heap but wrong for a commit path
    whose row should never leave the device.  Here the result stays a
    jax array, the jit requests donation of the row buffer (in-place on
    backends that honor it; the CPU backend ignores the request), and
    no host materialization of the row happens at any width the caller
    admits.  The caller owns the bounds check and the int64-range guard
    (``scatter_row`` / ``commit_fused`` route guarded batches to the
    numpy twins) — and, on device runtimes, ownership of ``row``: a
    donated buffer is invalidated, so snapshot-pinned readers must be
    handed a fresh alias first (see ``MVStoreHandle._install``).
    """
    import numpy as np

    a_np = np.asarray(addrs, np.int64)
    n = int(a_np.shape[0])
    rj = jnp.asarray(row)
    if n == 0:
        return rj
    if not INTERPRET:
        t = min(tile, 1 << (n - 1).bit_length())
        pad = (-n) % t
        a = jnp.asarray(a_np, jnp.int32)
        v = jnp.asarray(values, rj.dtype)
        if pad:
            a = jnp.pad(a, (0, pad), constant_values=int(rj.shape[0]))
            v = jnp.pad(v, (0, pad))
        return _publish_row_pallas(rj, a, v, tile=t)
    return _publish_row_xla(rj, jnp.asarray(a_np),
                            jnp.asarray(values, rj.dtype))


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("mode", "tile"))
def _commit_fused_jit(heap, wa, wv, ws, lv, lo, lm, ls,
                      rv, ro, rm, rn, rs, tids, rcs, cv, *, mode, tile):
    return _cf.commit_fused_flat(
        heap, wa, wv, ws, lv, lo, lm, ls, rv, ro, rm, rn, rs,
        tids, rcs, cv, mode=mode, tile=tile, interpret=INTERPRET)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _ring_refresh(ring, ring_ts, row, slot, ts):
    new_ring = jax.lax.dynamic_update_index_in_dim(
        ring, row.astype(ring.dtype), slot, 0)
    new_ts = jax.lax.dynamic_update_index_in_dim(
        ring_ts, ts.astype(ring_ts.dtype), slot, 0)
    return new_ring, new_ts


def commit_fused(heap, w_addr, w_val, w_seg,
                 l_words, l_seg, r_words, r_seen, r_seg,
                 tids, r_clocks, commit_ver, n_txn, *,
                 mode=None, tile: int = 512,
                 ring=None, ring_ts=None, ring_slot=None):
    """Group-commit megakernel: validate + claim-check + scatter + stamp
    for a batch of conflict-disjoint transactions in ONE launch.

    ``heap``: [H]; write batch ``(w_addr, w_val, w_seg)``: [N] flat
    segment layout (``commit_fused.pack_segments``); ``l_words``/
    ``r_words``: raw packed int64 lock words for the write-lock and
    read-set entries (gathered by the caller under its atomicity
    bracket), with ``l_seg``/``r_seg`` owner segments and ``r_seen``
    the versions recorded at read time; ``tids``/``r_clocks``: [T]
    per-member identity and snapshot.  Returns ``(new_heap, txn_ok,
    new_l_words)`` — ``new_heap`` a jax array (device-resident, heap
    buffer donated; never materialized to host here; the exact ndarray
    when the batch routes to the numpy twin), ``txn_ok`` a
    bool[n_txn] ndarray, ``new_l_words`` exact int64 release words:
    ``commit_ver`` stamped unlocked where the member survived, the
    original word otherwise.  With ``ring``/``ring_ts``/``ring_slot``
    given, the version-ring row refresh rides the same call (donated;
    the MVStore publish path — its commit lock is the held seqlock) and
    two more values ``(new_ring, new_ring_ts)`` are returned.

    Versions are REBASED to ``commit_ver`` before the int32 cast (the
    ``validate_readset`` treatment — the predicates only compare
    deltas) and the release words are reconstructed host-side at full
    width; batches whose payloads/addresses exceed int32 route to the
    in-file numpy twin (``np_commit_fused``) exactly like
    ``write_back``, as does an int64-range host heap.
    """
    import numpy as np

    from repro.core.engine.arrayheap import (_TID_BIAS, _TID_MASK,
                                             _UNLOCKED_WORD, _VER_SHIFT)

    if mode is None:
        mode = _cf.MODE_LE
    base = int(commit_ver)
    lo32, hi32 = -(1 << 31) + 1, (1 << 31) - 1

    def unpack(words):
        w = np.asarray(words, np.int64)
        ver = w >> _VER_SHIFT
        own = (((w >> 2) & _TID_MASK) - _TID_BIAS).astype(np.int32)
        meta = (((w >> 1) & 1) | ((w & 1) << 1)).astype(np.int32)
        return ver, own, meta

    l_ver, l_own, l_meta = unpack(l_words)
    r_ver, r_own, r_meta = unpack(r_words)
    w_addr = np.asarray(w_addr, np.int64)
    w_seg = np.asarray(w_seg, np.int64)
    l_seg = np.asarray(l_seg, np.int64)
    r_seg = np.asarray(r_seg, np.int64)
    r_seen = np.asarray(r_seen, np.int64)
    vals = np.asarray(w_val)

    def stamp(ok):
        return np.where(ok[l_seg] if l_seg.size else np.zeros((0,), bool),
                        (np.int64(base) << _VER_SHIFT)
                        | np.int64(_UNLOCKED_WORD),
                        np.asarray(l_words, np.int64))

    def _beyond_int32(a):
        return a.dtype == np.int64 and a.size and \
            (int(a.max()) > hi32 or int(a.min()) < lo32)

    if not isinstance(heap, (np.ndarray, jax.Array)):
        heap = np.asarray(heap)
    heap_np = heap if isinstance(heap, np.ndarray) else None
    if _beyond_int32(vals) or _beyond_int32(w_addr) \
            or (heap_np is not None and _beyond_int32(heap_np)):
        new_heap, ok, _ = _cf.np_commit_fused(
            np.asarray(heap), w_addr, vals, w_seg,
            l_ver, l_own, l_meta, l_seg,
            r_ver, r_own, r_meta, r_seen, r_seg,
            tids, r_clocks, base, n_txn, mode)
        # stay numpy on this route: jnp.asarray without x64 would
        # truncate the very int64 payloads that routed us here
        out = (new_heap, ok, stamp(ok))
    else:
        hj = jnp.asarray(heap)
        h = int(hj.shape[0])
        n = int(w_addr.shape[0])
        t = min(tile, 1 << (max(n, 1) - 1).bit_length())
        pad = (-n) % t if n else t        # >=1 grid step runs the verdict
        a32 = np.concatenate([w_addr, np.full(pad, h, np.int64)])
        s32 = np.concatenate([w_seg, np.zeros(pad, np.int64)])
        v = jnp.concatenate([jnp.asarray(vals, hj.dtype),
                             jnp.zeros((pad,), hj.dtype)]) if pad \
            else jnp.asarray(vals, hj.dtype)

        def rel(x):
            return np.clip(np.asarray(x, np.int64) - base, lo32, hi32)

        # dummy txn slot T absorbs the pad rows of empty side batches
        tids_p = np.concatenate([np.asarray(tids, np.int64), [0]])
        rcs_p = np.concatenate([rel(r_clocks), [0]])
        dummy = len(tids_p) - 1

        def side(ver_rel, own, meta, seen_rel, seg):
            if seg.size:
                return ver_rel, own, meta, seen_rel, seg
            z = np.zeros(1, np.int64)
            return z, z.astype(np.int32), z.astype(np.int32), z, \
                np.full(1, dummy, np.int64)

        lv, lo_, lm, _, ls = side(rel(l_ver), l_own, l_meta,
                                  np.zeros_like(l_ver), l_seg)
        rv, ro, rm, rn, rs = side(rel(r_ver), r_own, r_meta,
                                  rel(r_seen), r_seg)

        def i32(x):
            return jnp.asarray(np.asarray(x), jnp.int32)

        new_heap, ok32, _ = _commit_fused_jit(
            hj, i32(a32), v, i32(s32),
            i32(lv), i32(lo_), i32(lm), i32(ls),
            i32(rv), i32(ro), i32(rm), i32(rn), i32(rs),
            i32(tids_p), i32(rcs_p), jnp.zeros((1,), jnp.int32),
            mode=int(mode), tile=t)
        ok = np.asarray(ok32[:n_txn]) != 0
        out = (new_heap, ok, stamp(ok))
    if ring is None:
        return out
    new_heap, ok, new_l = out
    new_ring, new_ts = _ring_refresh(
        jnp.asarray(ring), jnp.asarray(ring_ts), jnp.asarray(new_heap),
        jnp.asarray(int(ring_slot), jnp.int32),
        jnp.asarray(np.int64(base) if ring_ts.dtype == np.int64
                    else np.int32(base)))
    return new_heap, ok, new_l, new_ring, new_ts


def validate_readset(ver, own, meta, seen, r_clock, tid, mode,
                     tile: int = 512) -> bool:
    """Bulk read-set validation: True iff every entry is still valid.

    Adapts ragged read-set lengths to the tiled kernel by padding with
    always-valid entries (see ``validate.PAD``), then AND-reduces the
    per-entry mask.  The engine calls this on the TPU path
    (KERNEL_INTERPRET=0); on CPU it uses the numpy twin directly.

    Versions are rebased to ``r_clock`` before the int32 cast: the packed
    lock word carries a 46-bit version and the clock bumps on every
    commit AND abort, so absolute versions can exceed int32 in long runs
    — but every predicate only compares versions against ``r_clock`` or
    ``seen``, and within one transaction's lifetime those deltas are
    tiny.  The clip is a belt-and-braces clamp that preserves the
    comparison's sign (a clamped entry is >= 2^31 commits away from the
    snapshot, i.e. unambiguously stale/fresh).
    """
    import numpy as np

    n = int(ver.shape[0])
    if n == 0:
        return True
    base = int(r_clock)
    lo, hi = -(1 << 31) + 1, (1 << 31) - 1
    ver_rel = np.clip(np.asarray(ver, np.int64) - base, lo, hi)
    seen_rel = np.clip(np.asarray(seen, np.int64) - base, lo, hi)
    t = min(tile, 1 << (n - 1).bit_length())
    pad = (-n) % t
    p = _val.PAD

    def prep(x, fill):
        x = jnp.asarray(np.asarray(x), jnp.int32)
        return jnp.pad(x, (0, pad), constant_values=fill) if pad else x

    mask = _val.validate_readset_flat(
        prep(ver_rel, p["ver"]), prep(own, p["own"]),
        prep(meta, p["meta"]), prep(seen_rel, p["seen"]),
        0, int(tid), int(mode), tile=t, interpret=INTERPRET)
    return bool(jnp.all(mask == 1))


def version_select(ts, data, r_clock, tile: int = 256):
    """Batched snapshot version select over packed VLT mirror rows.

    ``ts``/``data``: [N, D] newest-first (timestamps int, data numeric);
    returns ``(values [N] ndarray, ok [N] bool)`` — per row, the newest
    ``data`` whose timestamp is strictly below ``r_clock`` and whether
    any slot qualified.  Adapts ragged batch sizes to the tiled kernel
    by padding with always-invalid rows and rebases timestamps to
    ``r_clock`` before the int32 cast (absolute clocks exceed int32 in
    long runs; only the sign of ``ts - r_clock`` matters — same
    treatment as ``validate_readset``).  This is the Mode-U bulk
    versioned-read hot path on TPU (KERNEL_INTERPRET=0); on CPU the
    engine uses the numpy twin (``core.vlt.np_version_select``)
    directly.
    """
    import numpy as np

    n = int(ts.shape[0])
    if n == 0:
        return (np.zeros((0,), np.int64), np.zeros((0,), bool))
    lo, hi = -(1 << 31) + 1, (1 << 31) - 1
    data = np.asarray(data)
    if data.dtype == np.int64 and data.size and \
            (int(data.max()) > hi or int(data.min()) < lo):
        # without jax x64 the kernel would silently truncate int64
        # payloads to int32 — wrong values with ok=True; such batches
        # take the numpy twin (exact at any width) instead
        from repro.core.vlt import np_version_select
        return np_version_select(np.asarray(ts, np.int64), data,
                                 int(r_clock))
    rel = np.clip(np.asarray(ts, np.int64) - int(r_clock), lo, hi)
    t = min(tile, 1 << (n - 1).bit_length())
    pad = (-n) % t
    rel = jnp.asarray(rel, jnp.int32)
    d = jnp.asarray(data)
    if pad:
        rel = jnp.pad(rel, ((0, pad), (0, 0)), constant_values=_vs.PAD_TS)
        d = jnp.pad(d, ((0, pad), (0, 0)))
    vals, ok = _vs.version_select_flat(rel, d, 0, tile=t,
                                       interpret=INTERPRET)
    return np.asarray(vals[:n]), np.asarray(ok[:n]) != 0


def fused_adamw(p, g, m, v, ring, slot, *, lr, scale, count, b1, b2, eps,
                wd):
    """Pytree-leaf fused update.  p: any shape; ring: [R, *p.shape]|None."""
    shape = p.shape
    n = p.size
    cnt = count.astype(jnp.float32)
    b1c = 1 - b1 ** cnt
    b2c = 1 - b2 ** cnt
    tile = n
    for cand in (2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            tile = cand
            break
    rf = ring.reshape(ring.shape[0], n) if ring is not None else None
    p2, m2, v2, r2 = _fa.fused_adamw_flat(
        p.reshape(n), g.reshape(n), m.reshape(n), v.reshape(n), rf,
        jnp.asarray(slot, jnp.int32), lr=jnp.asarray(lr),
        scale=jnp.asarray(scale), b1c=b1c, b2c=b2c, b1=b1, b2=b2, eps=eps,
        wd=wd, tile=tile, interpret=INTERPRET)
    p2 = p2.reshape(shape)
    m2 = m2.reshape(shape)
    v2 = v2.reshape(shape)
    if ring is not None:
        r2 = r2.reshape(ring.shape)
    return p2, m2, v2, r2
