"""Batched snapshot read — the long-running-read hot path as a Pallas kernel.

The paper's headline workload is a transaction that reads THOUSANDS of
words (a range query / audit / scan) while updaters commit around it.
Word-at-a-time that read is bottlenecked by the interpreter, not the TM;
this kernel gathers an entire address batch from the heap in ONE launch:

    values[i] = heap[addrs[i]]          for i in [0, N)

so a `Txn.read_bulk` costs one heap gather + one lock-word gather + one
vectorized validation pass instead of N Python round-trips.

The same kernel serves both layers:

  * word level — ``heap`` is the live ``ArrayHeap`` buffer (int64 words);
  * store level — ``heap`` is the ring row ``snapshot_select`` (or the
    host-side slot scan) picked for the reader's clock, so a versioned
    bulk read is slot-select + this gather.

Layout: the heap rides in as one full block (the whole live heap must fit
the kernel's memory budget — at this repro's scales it is KBs..MBs); the
address vector and output are tiled over the grid, so the gather runs
tile-by-tile on the VPU.  ``interpret=True`` is the CPU fallback path;
for CPU *production* reads the engine uses the numpy twin (a single
fancy-index in ``engine.bulkread.heap_gather``), mirroring the
``validate.py`` / ``engine.validation.np_validate`` split — the kernel
test pins the two implementations together element-for-element.

Out-of-range addresses are the caller's bug (the engine bounds-checks
against the allocation frontier before launching); padding uses address 0,
which every heap has (structures burn it as NULL).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: padding address: always allocated (address 0), gathered then discarded
PAD_ADDR = 0


def _gather_kernel(heap_ref, addr_ref, o_ref):
    o_ref[...] = jnp.take(heap_ref[...], addr_ref[...], axis=0)


def gather_read_flat(heap, addrs, *, tile: int = 512,
                     interpret: bool = True):
    """heap: [H]; addrs: [N] int32 (N a multiple of ``tile``).

    Returns the [N] gathered values (``heap.dtype``).  The heap is one
    full block per grid step; addresses/outputs are tiled.
    """
    (h,) = heap.shape
    n = addrs.shape[0]
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    return pl.pallas_call(
        _gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), heap.dtype),
        interpret=interpret,
    )(heap, addrs)
