"""FlashAttention forward kernel (pl.pallas_call + explicit BlockSpec).

TPU mapping: grid (batch*heads, q-blocks, kv-blocks) with the kv dimension
innermost — TPU grids execute sequentially over the last axis, so the
online-softmax statistics live in VMEM scratch across kv iterations and
the output tile is written once on the final kv block.  Block shapes are
MXU-aligned (multiples of 128 on the contracting dims).

Validated in interpret mode against ref.naive_attention (tests sweep
shapes/dtypes); the blockwise XLA lowering in models/attention.py is the
same schedule for the dry-run path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[0].astype(jnp.float32)              # [bq, D]
        k = k_ref[0].astype(jnp.float32)              # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    if causal:
        # blocks strictly above the diagonal contribute nothing: skip
        pl.when(ki * block_k <= qi * block_q + (block_q - 1))(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_nhd(q, k, v, *, causal: bool, block_q: int = 128,
                        block_k: int = 128, scale=None,
                        interpret: bool = True):
    """q: [N, Sq, D]; k, v: [N, Sk, D] (N = batch*heads, kv pre-repeated).

    Returns [N, Sq, D].  ``interpret=True`` executes on CPU; on a real TPU
    pass interpret=False.
    """
    N, Sq, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=bq, block_k=bk,
        nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(N, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda n, qi, ki: (n, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda n, qi, ki: (n, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda n, qi, ki: (n, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda n, qi, ki: (n, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),      # acc
            pltpu.VMEM((bq, 1), jnp.float32),      # m
            pltpu.VMEM((bq, 1), jnp.float32),      # l
        ],
        interpret=interpret,
    )(q, k, v)
