"""Mamba-2 SSD chunk kernel (pl.pallas_call + BlockSpec).

Grid (batch, chunks) with chunks innermost: the inter-chunk SSM state
lives in VMEM scratch and persists across sequential grid steps (the same
carry idiom as the flash kernel).  Within a chunk the kernel loops over
heads (fori) so the [Q, Q] decay/score matrix for one head stays VMEM-
sized; the intra-chunk compute is MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int, n_heads: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, H, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q, H]
    A = a_ref[...].astype(jnp.float32)        # [H]
    b = b_ref[0].astype(jnp.float32)          # [Q, N]
    c = c_ref[0].astype(jnp.float32)          # [Q, N]
    Q = chunk

    dA = dt * A[None, :]                      # [Q, H]
    cum = jnp.cumsum(dA, axis=0)              # [Q, H]
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)

    def head_body(h, _):
        cum_h = cum[:, h]                                  # [Q]
        decay = jnp.exp(cum_h[:, None] - cum_h[None, :])   # [Q, Q]
        mmat = jnp.where(tri, cb * decay * dt[None, :, h], 0.0)
        y_intra = jax.lax.dot_general(
            mmat, x[:, h, :], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # [Q, P]
        y_inter = jax.lax.dot_general(
            c, state_ref[h], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) \
            * jnp.exp(cum_h)[:, None]                      # [Q, P]
        y_ref[0, :, h, :] = (y_intra + y_inter).astype(y_ref.dtype)
        # state update: S' = exp(cum[-1]) S + sum_j decay_j dt_j b_j x_j
        sdecay = jnp.exp(cum_h[-1] - cum_h) * dt[:, h]     # [Q]
        s_new = jax.lax.dot_general(
            b * sdecay[:, None], x[:, h, :], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # [N, P]
        state_ref[h] = state_ref[h] * jnp.exp(cum_h[-1]) + s_new
        return 0

    jax.lax.fori_loop(0, n_heads, head_body, 0)


def ssd_scan_pallas(xh, dt, A, B_, C_, *, chunk: int = 256,
                    interpret: bool = True):
    """xh: [B, S, H, P]; dt: [B, S, H]; A: [H]; B_, C_: [B, S, N].

    Returns (y [B, S, H, P], final_state [B, H, N, P]).
    """
    Bsz, S, H, Pd = xh.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    kernel = functools.partial(_ssd_kernel, chunk=Q, n_heads=H)
    y = pl.pallas_call(
        kernel,
        grid=(Bsz, nc),
        in_specs=[
            pl.BlockSpec((1, Q, H, Pd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, H, Pd), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, S, H, Pd), xh.dtype),
        scratch_shapes=[pltpu.VMEM((H, N, Pd), jnp.float32)],
        interpret=interpret,
    )(xh, dt, A, B_, C_)
    # the final state is recomputed cheaply with the jnp path when callers
    # need to carry it (prefill -> decode); kernel users in the hot loop
    # (training) do not consume it
    return y
