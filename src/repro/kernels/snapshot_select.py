"""snapshot_select — the MVStore versioned read, as a Pallas TPU kernel.

The paper's hot read path is the version-list traversal ("newest version
with ts <= read_clock").  TPU adaptation: the ring timestamps are SCALAR-
PREFETCHED (SMEM) and the slot selection happens inside the BlockSpec
index map, so the kernel fetches ONLY the selected version's tiles from
HBM — the traversal costs zero extra HBM traffic, unlike a naive gather
that would read all R slots.  This is the Pallas analogue of following
exactly one list pointer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NO_TS = -1


def _select_slot(ts, clock):
    """Newest slot with NO_TS < ts <= clock (0 if none: caller checks ok)."""
    valid = jnp.logical_and(ts != NO_TS, ts <= clock)
    masked = jnp.where(valid, ts, NO_TS)
    return jnp.argmax(masked).astype(jnp.int32)


def _copy_kernel(ts_ref, clock_ref, ring_ref, o_ref):
    del ts_ref, clock_ref
    o_ref[...] = ring_ref[0]


def snapshot_select_flat(ring, ts, read_clock, *, tile: int = 2048,
                         interpret: bool = True):
    """ring: [R, n]; ts: [R] int32; read_clock: scalar int32.

    Returns (value [n], ok bool).  Only the selected slot's row is read.
    """
    R, n = ring.shape
    t = min(tile, n)
    assert n % t == 0, (n, t)
    grid = (n // t,)

    def ring_index(i, ts_ref, clock_ref):
        return (_select_slot(ts_ref[...], clock_ref[0]), i)

    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((1, t), ring_index)],
            out_specs=pl.BlockSpec((t,), lambda i, ts_ref, clock_ref: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((n,), ring.dtype),
        interpret=interpret,
    )(ts, jnp.asarray(read_clock, jnp.int32).reshape(1), ring)
    ok = jnp.any(jnp.logical_and(ts != NO_TS, ts <= read_clock))
    return out, ok
