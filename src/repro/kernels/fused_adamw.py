"""fused_adamw — AdamW update + in-place write + MVStore ring append.

The measured Mode-U overhead is one extra full-parameter HBM write (the
copy-on-write version).  The paper fuses the version-list update into the
encounter-time write path (Alg. 3: in-place write + tryWriteToVersionList
under one lock hold); the TPU analogue fuses the optimizer's parameter
write and the ring-slot write into ONE kernel pass so the parameter tile
is read once and written twice while resident in VMEM — instead of a
second read-modify-write round trip.

The ring output aliases the ring input (input_output_aliasing): only the
selected slot row is touched, the other R-1 slots are never transferred.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(slot_ref, scal_ref, p_ref, g_ref, m_ref, v_ref, ring_ref,
                  p_out, m_out, v_out, ring_out, *, b1, b2, eps, wd,
                  has_ring):
    del slot_ref
    lr = scal_ref[0]
    scale = scal_ref[1]
    b1c = scal_ref[2]
    b2c = scal_ref[3]
    g = g_ref[...].astype(jnp.float32) * scale
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m / b1c
    vhat = v / b2c
    p32 = p_ref[...].astype(jnp.float32)
    step = mhat / (jnp.sqrt(vhat) + eps) + wd * p32
    newp = p32 - lr * step
    p_out[...] = newp.astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v
    if has_ring:
        ring_out[0] = newp.astype(ring_out.dtype)   # versioned commit


def fused_adamw_flat(p, g, m, v, ring, slot, *, lr, scale, b1c, b2c,
                     b1, b2, eps, wd, tile: int = 2048,
                     interpret: bool = True):
    """p: [n] params; g: [n] f32 grads; m, v: [n] f32 moments;
    ring: [R, n] or None; slot: int32 ring row to write.

    Returns (p', m', v', ring') with ring' aliasing ring.
    """
    n = p.shape[0]
    t = min(tile, n)
    assert n % t == 0, (n, t)
    has_ring = ring is not None
    scalars = jnp.stack([lr.astype(jnp.float32),
                         scale.astype(jnp.float32),
                         b1c.astype(jnp.float32),
                         b2c.astype(jnp.float32)])
    if not has_ring:
        ring = jnp.zeros((1, n), p.dtype)
        slot = jnp.zeros((), jnp.int32)

    kernel = functools.partial(_fused_kernel, b1=b1, b2=b2, eps=eps, wd=wd,
                               has_ring=has_ring)
    grid = (n // t,)
    outs = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,          # slot, scalars
            grid=grid,
            in_specs=[
                pl.BlockSpec((t,), lambda i, s, _: (i,)),   # p
                pl.BlockSpec((t,), lambda i, s, _: (i,)),   # g
                pl.BlockSpec((t,), lambda i, s, _: (i,)),   # m
                pl.BlockSpec((t,), lambda i, s, _: (i,)),   # v
                pl.BlockSpec((1, t), lambda i, s, _: (s[0], i)),  # ring
            ],
            out_specs=[
                pl.BlockSpec((t,), lambda i, s, _: (i,)),
                pl.BlockSpec((t,), lambda i, s, _: (i,)),
                pl.BlockSpec((t,), lambda i, s, _: (i,)),
                pl.BlockSpec((1, t), lambda i, s, _: (s[0], i)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((n,), p.dtype),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct(ring.shape, ring.dtype),
        ],
        input_output_aliases={6: 3},        # ring in -> ring out
        interpret=interpret,
    )(slot.reshape(1), scalars, p, g, m, v, ring)
    p2, m2, v2, ring2 = outs
    return p2, m2, v2, (ring2 if has_ring else None)
