"""Pure-jnp oracles for every kernel (the allclose targets of the tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NO_TS = -1


def flash_attention_ref(q, k, v, *, causal: bool, scale=None):
    """q, k, v: [N, S, D] (kv pre-repeated for GQA)."""
    N, Sq, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("nqk,nkd->nqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_scan_ref(xh, dt, A, B_, C_):
    """Sequential (timestep-by-timestep) SSD recurrence — the ground truth
    the chunked forms must match.  xh: [B, S, H, P]; dt: [B, S, H];
    A: [H]; B_, C_: [B, S, N].  Returns (y, final_state [B, H, N, P])."""
    Bsz, S, H, Pd = xh.shape
    N = B_.shape[-1]

    def step(state, t):
        x_t, dt_t, b_t, c_t = t
        dA = jnp.exp(dt_t * A[None, :])                        # [B, H]
        upd = jnp.einsum("bn,bhp->bhnp", b_t.astype(jnp.float32),
                         x_t.astype(jnp.float32) * dt_t[..., None])
        state = state * dA[:, :, None, None] + upd
        y = jnp.einsum("bhnp,bn->bhp", state, c_t.astype(jnp.float32))
        return state, y

    state0 = jnp.zeros((Bsz, H, N, Pd), jnp.float32)
    xs = (xh.swapaxes(0, 1), dt.swapaxes(0, 1), B_.swapaxes(0, 1),
          C_.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(xh.dtype), state


def snapshot_select_ref(ring, ts, read_clock):
    """ring: [R, n]; ts: [R].  Newest slot with NO_TS < ts <= clock."""
    valid = jnp.logical_and(ts != NO_TS, ts <= read_clock)
    masked = jnp.where(valid, ts, NO_TS)
    idx = jnp.argmax(masked)
    ok = jnp.any(valid)
    return ring[idx], ok


def fused_adamw_ref(p, g, m, v, ring, slot, *, lr, scale, b1c, b2c, b1, b2,
                    eps, wd):
    g = g.astype(jnp.float32) * scale
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    step = m2 / b1c / (jnp.sqrt(v2 / b2c) + eps) + wd * p.astype(
        jnp.float32)
    p2 = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
    ring2 = None
    if ring is not None:
        ring2 = ring.at[slot].set(p2.astype(ring.dtype))
    return p2, m2, v2, ring2
