"""Batched commit write-back — the scatter half of the snapshot gather.

``kernels/gather_read.py`` made the long-running READ an array operation
(``values[i] = heap[addrs[i]]``); this kernel is its commit-side twin.
An update transaction that buffered (or undo-logged) a large write set
publishes it to the heap in ONE launch instead of N interpreter
round-trips:

    out = heap;  out[addrs[i]] = values[i]      for i in [0, N)

Layout mirrors the gather kernel: the heap rides in as one full block
(KBs..MBs at this repro's scales), the address/value vectors are tiled
over the grid, and the OUTPUT is the full heap block revisited by every
grid step (constant index map) — step 0 copies the heap through, each
step then scatters its tile into the block, so the final block holds
every update.  Addresses are the caller's responsibility to keep unique
(write sets are dict-keyed, so they are); an out-of-range address is
DROPPED by jax scatter semantics, which is exactly what the ragged-batch
padding relies on (``ops.write_back`` pads with ``heap.size``, one past
the end).

``interpret=True`` is the CPU fallback path; for CPU *production*
write-back the engine uses the numpy twin (``np_write_back`` below — a
single fancy-index assignment, the same split as ``validate.py`` /
``gather_read.py``); the kernel test pins the two element-for-element.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def np_write_back(heap: np.ndarray, addrs: np.ndarray,
                  values: np.ndarray) -> np.ndarray:
    """Numpy twin: a copy of ``heap`` with ``out[addrs] = values``.

    Exact at any integer width (the wrapper routes int64-range payloads
    here instead of letting an x64-less jax truncate them — the
    ``version_select`` guard pattern).  Addresses must be in range and
    unique; the in-place engine path (``ArrayHeap.scatter``) shares this
    contract, and BOTH ends fail loudly — a negative address would wrap
    under numpy fancy indexing and silently overwrite a word near the
    end of the heap, so it raises like an out-of-range positive one.
    """
    a = np.asarray(addrs)
    if a.size and int(a.min(initial=0)) < 0:
        raise IndexError(int(a.min()))
    out = np.array(heap, copy=True)
    out[a] = values
    return out


def _scatter_kernel(heap_ref, addr_ref, val_ref, o_ref):
    # constant-index output block: step 0 seeds it with the heap, every
    # step scatters its (addr, val) tile into it; out-of-range pad
    # addresses are dropped by scatter semantics
    @pl.when(pl.program_id(0) == 0)
    def _seed():
        o_ref[...] = heap_ref[...]

    o_ref[...] = o_ref[...].at[addr_ref[...]].set(val_ref[...])


def scatter_write_flat(heap, addrs, values, *, tile: int = 512,
                       interpret: bool = True):
    """heap: [H]; addrs: [N] int32; values: [N] heap.dtype (N a multiple
    of ``tile``).  Returns the [H] updated heap row.
    """
    (h,) = heap.shape
    n = addrs.shape[0]
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    return pl.pallas_call(
        _scatter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((h,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((h,), heap.dtype),
        interpret=interpret,
    )(heap, addrs, values)
