"""Snapshot version select — the word-level versioned read as a kernel.

Multiverse resolves a versioned read by walking an address's version
list for the newest committed timestamp strictly below the reader's
snapshot clock (paper Alg. 2 traverse).  The packed VLT mirror
(``core/vlt.py``) keeps each lock bucket's newest ``D`` committed
``(timestamp, data)`` pairs in two int arrays, newest-first, so the walk
becomes an elementwise selection this kernel evaluates for an ENTIRE
batch of recently-written addresses in one launch:

    valid[n, j] = ts[n, j] < r_clock            (strict: the deferred
                                                 clock shares timestamps
                                                 across commits)
    value[n]    = data[n, first j with valid]   (rows are newest-first)
    ok[n]       = any(valid[n, :])

Timestamps arrive REBASED to the reader's clock (the ``ops`` wrapper
subtracts ``r_clock`` in int64 and clips to int32 — same treatment as
``kernels/validate.py``), so the predicate inside is ``ts < 0`` with the
clock scalar pinned to 0; empty slots carry the positive-saturated
sentinel and fail it naturally.  ``interpret=True`` is the CPU fallback
path; for CPU *production* reads the engine uses the numpy twin
(``core.vlt.np_version_select``) per the validate.py / gather_read.py
pattern — the kernel test pins the two element-for-element.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: rebased-timestamp padding for ragged batches: positive-saturated, so
#: the ``ts < clock`` predicate rejects it for every clock value
PAD_TS = (1 << 31) - 1


def _select_kernel(params_ref, ts_ref, data_ref, val_ref, ok_ref):
    clock = params_ref[0]
    valid = ts_ref[...] < clock            # [tile, D], newest-first rows
    first = jnp.argmax(valid, axis=1)      # first True == newest valid
    val = jnp.take_along_axis(data_ref[...], first[:, None], axis=1)
    val_ref[...] = val[:, 0]
    ok_ref[...] = jnp.any(valid, axis=1).astype(jnp.int32)


def version_select_flat(ts, data, clock, *, tile: int = 256,
                        interpret: bool = True):
    """ts: [N, D] int32 (rebased); data: [N, D]; clock: int32 scalar.

    Returns ``(values [N] data.dtype, ok [N] int32)``: per row, the
    newest ``data`` whose ``ts`` is strictly below ``clock``, and
    whether any slot qualified (``values`` is only meaningful where
    ``ok``).  Rows are tiled over the grid; ``D`` rides whole.
    """
    n, depth = ts.shape
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    row2d = pl.BlockSpec((tile, depth), lambda i, params_ref: (i, 0))
    row1d = pl.BlockSpec((tile,), lambda i, params_ref: (i,))
    params = jnp.asarray([clock], jnp.int32)
    return pl.pallas_call(
        _select_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[row2d, row2d],
            out_specs=[row1d, row1d],
        ),
        out_shape=[jax.ShapeDtypeStruct((n,), data.dtype),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
    )(params, ts, data)
