"""Fused commit megakernel — one launch per batch of disjoint transactions.

``scatter_write.py`` made the WRITE-BACK of one commit a single launch;
this kernel fuses the whole commit decision for a GROUP of
conflict-disjoint transactions:

    validate each member's read-set lock words        (validate.py math)
  + check each member's write locks are claimable      (try_lock_bulk math)
  + scatter every surviving member's values            (scatter_write math)
  + stamp the release versions for the claimed words

in ONE launch over a segment-offset layout: ragged per-transaction
read/write sets are packed into flat ``(addrs, values, txn_id)`` /
``(lock fields, txn_id)`` batches (``pack_segments`` below), and a
per-transaction verdict is accumulated with a scatter-min into a
constant-index ``ok`` block — a member is publishable iff EVERY one of
its read entries validates and EVERY one of its write locks is free.

Layout mirrors the gather/scatter kernels: the heap rides in as one
full block, the write batch is tiled over the grid, the (small)
read/lock/txn vectors are full constant-index blocks.  Grid step 0
computes the verdict, seeds the output heap and stamps the release
versions; every step then scatters its write tile, with the addresses
of FAILED members redirected to one-past-the-end (dropped by jax
scatter semantics — the same ragged-padding trick ``ops.write_back``
uses, so a failed member's writes never touch the heap).

The caller owns atomicity: on the CPU engine the covering lock stripes
are held around the decision + claim (``groupcommit.py``); at the
MVStore layer the commit lock (the seqlock analogue) brackets the call.
Versions ride in REBASED to the commit version and clipped to int32
(the ``validate.py`` treatment — only deltas matter to the predicates);
``ops.commit_fused`` reconstructs exact int64 release words host-side.

``np_commit_fused`` is the in-file numpy twin (exact at any width, the
CPU-production path); ``np_commit_decide`` is its verdict half, shared
with the engine's group-commit pipeline, which scatters through the
in-place heap instead of the functional row.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# fault injection only (stdlib-only module — keeps the kernels
# engine-import-free): np_commit_fused splits its scatter around the
# ``mid_scatter`` point so crash drills can freeze a partial-lane image
from repro.reliability import faultpoints as FP

# validation predicate selectors — same encoding as engine/validation.py
# (kernels stay engine-import-free, so the constants are mirrored here
# and pinned equal by tests/test_groupcommit.py)
MODE_LT = 0      # version <  r_clock   (Multiverse / DCTL deferred clock)
MODE_LE = 1      # version <= r_clock   (TL2-style commit-bumped clock)
MODE_EQ = 2      # version == seen      (TinySTM timestamp extension)


def pack_segments(per_txn) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Ragged per-transaction vectors -> one flat batch + segment ids.

    ``per_txn`` is a list of 1-D arrays (one per transaction, any
    lengths including zero).  Returns ``(flat, seg, offsets)`` where
    ``flat`` is the concatenation, ``seg[i]`` is the transaction index
    owning ``flat[i]``, and ``offsets`` is the int64[T+1] segment-offset
    vector (``flat[offsets[t]:offsets[t+1]]`` is transaction ``t``'s
    slice — the round-trip ``tests/test_groupcommit.py`` pins).
    """
    arrs = [np.asarray(a) for a in per_txn]
    lens = np.fromiter((a.shape[0] for a in arrs), np.int64, len(arrs))
    offsets = np.zeros(len(arrs) + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    flat = (np.concatenate(arrs) if arrs
            else np.zeros((0,), np.int64))
    seg = np.repeat(np.arange(len(arrs), dtype=np.int64), lens)
    return flat, seg, offsets


def np_commit_decide(l_ver, l_own, l_meta, l_seg,
                     r_ver, r_own, r_meta, r_seen, r_seg,
                     tids, r_clocks, n_txn: int, mode: int) -> np.ndarray:
    """Per-transaction verdict: bool[n_txn], True iff every read entry
    validates (at the member's OWN ``r_clock``/mode) and every write
    lock is claimable (free and unflagged, or already held by the
    member — ``try_lock_bulk``'s conflict rule).  Field layout matches
    ``ArrayLockTable.gather``: meta bit0 = locked, bit1 = flag.
    """
    tids = np.asarray(tids, np.int64)
    r_clocks = np.asarray(r_clocks, np.int64)
    ok = np.ones(n_txn, bool)
    r_seg = np.asarray(r_seg, np.int64)
    if r_seg.size:
        ver = np.asarray(r_ver, np.int64)
        meta = np.asarray(r_meta)
        locked = (meta & 1) != 0
        flagged = (meta & 2) != 0
        mine = locked & (np.asarray(r_own) == tids[r_seg])
        rc = r_clocks[r_seg]
        if mode == MODE_LT:
            valid = mine | (~locked & ~flagged & (ver < rc))
        elif mode == MODE_LE:
            valid = (~locked | mine) & (ver <= rc)
        else:
            valid = (~locked | mine) & (ver == np.asarray(r_seen, np.int64))
        # scatter-AND via a bincount of FAILURES: the common all-valid
        # batch reduces an empty array (ufunc.at would walk every entry)
        ok &= np.bincount(r_seg[~valid], minlength=n_txn) == 0
    l_seg = np.asarray(l_seg, np.int64)
    if l_seg.size:
        meta = np.asarray(l_meta)
        locked = (meta & 1) != 0
        flagged = (meta & 2) != 0
        own = locked & (np.asarray(l_own) == tids[l_seg])
        claimable = ~((locked | flagged) & ~own)
        ok &= np.bincount(l_seg[~claimable], minlength=n_txn) == 0
    return ok


def np_commit_fused(heap, w_addr, w_val, w_seg,
                    l_ver, l_own, l_meta, l_seg,
                    r_ver, r_own, r_meta, r_seen, r_seg,
                    tids, r_clocks, commit_ver: int, n_txn: int,
                    mode: int = MODE_LE):
    """Numpy twin: ``(new_heap, txn_ok, new_l_ver)`` — exact at any
    integer width (the wrapper routes int64-range batches here, the
    ``write_back`` guard pattern).

    ``new_heap`` is a copy with every SURVIVING member's ``(addr, val)``
    entries applied; failed members leave no trace.  ``new_l_ver[e]`` is
    the release version for write-lock entry ``e``: ``commit_ver`` where
    the owning member survived, the entry's original version otherwise.
    Addresses must be in range; a negative one raises (it would wrap
    under fancy indexing) exactly like ``np_write_back``.
    """
    ok = np_commit_decide(l_ver, l_own, l_meta, l_seg,
                          r_ver, r_own, r_meta, r_seen, r_seg,
                          tids, r_clocks, n_txn, mode)
    l_seg = np.asarray(l_seg, np.int64)
    new_l_ver = np.where(ok[l_seg] if l_seg.size else
                         np.zeros((0,), bool),
                         np.int64(commit_ver), np.asarray(l_ver, np.int64))
    out = np.array(heap, copy=True)
    w_seg = np.asarray(w_seg, np.int64)
    if w_seg.size:
        sel = ok[w_seg]
        a = np.asarray(w_addr, np.int64)[sel]
        if a.size and int(a.min(initial=0)) < 0:
            raise IndexError(int(a.min()))
        v = np.asarray(w_val)[sel]
        if FP.ACTIVE is not None and a.size > 1:
            # partial-lane completion fault: half the surviving lanes
            # land, then the injection point — a crash here freezes the
            # batch mid-scatter, the torn image whole-record idempotent
            # WAL redo must heal (the caller's claim words are already
            # stamped, so in-process recovery rolls the group forward)
            h = a.size // 2
            out[a[:h]] = v[:h]
            FP.fire("mid_scatter",
                    int(np.asarray(tids)[0]) if len(tids) else -1)
            out[a[h:]] = v[h:]
        else:
            out[a] = v
    return out, ok, new_l_ver


def _fused_kernel(mode, n_heap,
                  heap_ref, wa_ref, wv_ref, ws_ref,
                  lv_ref, lo_ref, lm_ref, ls_ref,
                  rv_ref, ro_ref, rm_ref, rn_ref, rs_ref,
                  tid_ref, rc_ref, cv_ref,
                  o_heap, o_ok, o_lver):
    # step 0: the whole verdict in one pass over the constant-index
    # read/lock blocks (scatter-min accumulates per-member AND), then
    # seed the heap and stamp the release versions
    @pl.when(pl.program_id(0) == 0)
    def _decide():
        tids = tid_ref[...]
        rcs = rc_ref[...]
        ok = jnp.ones(o_ok.shape, jnp.int32)
        rm = rm_ref[...]
        locked = (rm & 1) != 0
        flagged = (rm & 2) != 0
        seg = rs_ref[...]
        mine = locked & (ro_ref[...] == tids[seg])
        ver = rv_ref[...]
        rc = rcs[seg]
        if mode == MODE_LT:
            valid = mine | ((~locked) & (~flagged) & (ver < rc))
        elif mode == MODE_LE:
            valid = ((~locked) | mine) & (ver <= rc)
        else:
            valid = ((~locked) | mine) & (ver == rn_ref[...])
        ok = ok.at[seg].min(valid.astype(jnp.int32))
        lm = lm_ref[...]
        llocked = (lm & 1) != 0
        lflag = (lm & 2) != 0
        lseg = ls_ref[...]
        lown = llocked & (lo_ref[...] == tids[lseg])
        claim = jnp.logical_not((llocked | lflag) & (~lown))
        ok = ok.at[lseg].min(claim.astype(jnp.int32))
        o_ok[...] = ok
        o_lver[...] = jnp.where(ok[lseg] == 1, cv_ref[0], lv_ref[...])
        o_heap[...] = heap_ref[...]

    # every step (incl. 0, after the decide above): scatter this write
    # tile — failed members' addresses redirect one past the end, which
    # jax scatter drops, so their values never land
    okv = o_ok[...][ws_ref[...]]
    addr = jnp.where(okv == 1, wa_ref[...], n_heap)
    o_heap[...] = o_heap[...].at[addr].set(wv_ref[...])


def commit_fused_flat(heap, w_addr, w_val, w_seg,
                      l_ver, l_own, l_meta, l_seg,
                      r_ver, r_own, r_meta, r_seen, r_seg,
                      tids, r_clocks, commit_ver, *, mode: int = MODE_LE,
                      tile: int = 512, interpret: bool = True):
    """heap: [H]; write batch [N] (N a multiple of ``tile``, int32 addrs
    and segs, values heap.dtype); lock batch [L]; read batch [M]; txn
    vectors [T] (int32); commit_ver: [1] int32 (REBASED — 0 by the
    wrapper's convention).  Returns ``(heap' [H], ok [T] int32,
    lver' [L] int32)``.  Pad rows must point their seg at a dummy txn
    slot (read/lock batches) or carry an out-of-range address (write
    batch) — ``ops.commit_fused`` owns those conventions.
    """
    (h,) = heap.shape
    n = w_addr.shape[0]
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    t = tids.shape[0]
    L = l_ver.shape[0]
    m = r_ver.shape[0]
    const = lambda s: pl.BlockSpec((s,), lambda i: (0,))   # noqa: E731
    tiled = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        lambda *refs: _fused_kernel(mode, h, *refs),
        grid=grid,
        in_specs=[
            const(h),                      # heap
            tiled, tiled, tiled,           # w_addr, w_val, w_seg
            const(L), const(L), const(L), const(L),   # l_*
            const(m), const(m), const(m), const(m), const(m),  # r_*
            const(t), const(t),            # tids, r_clocks
            const(1),                      # commit_ver
        ],
        out_specs=[const(h), const(t), const(L)],
        out_shape=[
            jax.ShapeDtypeStruct((h,), heap.dtype),
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((L,), jnp.int32),
        ],
        interpret=interpret,
    )(heap, w_addr, w_val, w_seg, l_ver, l_own, l_meta, l_seg,
      r_ver, r_own, r_meta, r_seen, r_seg, tids, r_clocks, commit_ver)
