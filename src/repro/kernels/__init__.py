"""Pallas TPU kernels for the compute hot spots (DESIGN.md SS7):
flash_attention, ssd_scan (Mamba-2 chunk scan), snapshot_select (MVStore
versioned read), fused_adamw (optimizer + versioned commit), validate
(bulk read-set revalidation), gather_read (batched snapshot read —
`Txn.read_bulk`/`snapshot_bulk`), scatter_write (batched commit
write-back — the scatter half of the commit pipeline), version_select
(newest-committed-version select over packed VLT mirror rows).  ops.py
holds the jit.d wrappers, ref.py the pure-jnp oracles."""
