"""Bulk read-set validation — the commit-time hot path as a Pallas kernel.

The paper's update-transaction commit revalidates every read-set entry
against the lock table (Alg. 2 validateLock); the engine's scalar path
does this word-at-a-time in Python.  This kernel checks an ENTIRE
read-set's gathered lock words in one launch: the caller (the engine's
``ArrayLockTable.gather``) fancy-indexes the packed lock array once —
each element a consistent (locked, version, tid, flag) tuple — and the
kernel evaluates the per-backend validation predicate elementwise on the
VPU, tiled over the read set.

Three predicates cover every lock-version backend (``mode`` scalar):

    0 (V_LT)  own locks pass; foreign locks/flags fail; version <  rClock
              (Multiverse / DCTL, deferred clock)
    1 (V_LE)  locked-by-other fails;                    version <= rClock
              (TL2)
    2 (V_EQ)  locked-by-other fails;                    version == seen
              (TinySTM exact-snapshot)

Scalars ride in via ``PrefetchScalarGridSpec`` (SMEM), so one compiled
kernel serves every (r_clock, tid, mode) triple.  ``interpret=True`` is
the CPU fallback path; for CPU *production* validation the engine uses
the numpy twin (``engine.validation.np_validate``) because interpret-mode
tiling costs more than it saves — the kernel test pins the two
implementations together element-for-element.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: padding element that every mode accepts: unlocked, unflagged,
#: version -1 (< and <= any clock), seen -1 (== its own version)
PAD = dict(ver=-1, own=-1, meta=0, seen=-1)


def _validate_kernel(params_ref, ver_ref, own_ref, meta_ref, seen_ref,
                     o_ref):
    r_clock = params_ref[0]
    tid = params_ref[1]
    mode = params_ref[2]
    ver = ver_ref[...]
    own = own_ref[...]
    meta = meta_ref[...]
    seen = seen_ref[...]
    locked = (meta & 1) != 0
    flagged = (meta & 2) != 0
    mine = jnp.logical_and(locked, own == tid)
    free = jnp.logical_and(~locked, ~flagged)
    unheld = jnp.logical_or(~locked, mine)
    ok_lt = jnp.logical_or(mine, jnp.logical_and(free, ver < r_clock))
    ok_le = jnp.logical_and(unheld, ver <= r_clock)
    ok_eq = jnp.logical_and(unheld, ver == seen)
    ok = jnp.where(mode == 0, ok_lt, jnp.where(mode == 1, ok_le, ok_eq))
    o_ref[...] = ok.astype(jnp.int32)


def validate_readset_flat(ver, own, meta, seen, r_clock, tid, mode, *,
                          tile: int = 512, interpret: bool = True):
    """ver/own/meta/seen: [N] int32 (N a multiple of ``tile``).

    Returns the [N] int32 validity mask (1 = entry still valid).  The
    caller reduces with ``jnp.all`` — keeping the mask exposed lets
    diagnostics name WHICH reads went stale, not just that one did.
    """
    n = ver.shape[0]
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    spec = pl.BlockSpec((tile,), lambda i, params_ref: (i,))
    params = jnp.asarray([r_clock, tid, mode], jnp.int32)
    return pl.pallas_call(
        _validate_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec, spec, spec, spec],
            out_specs=spec,
        ),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(params, ver, own, meta, seen)
