"""repro.api — the sole public transactional surface of this repo.

The paper's single programming model over both layers:

    from repro.api import make_tm, atomic, run

    tm = make_tm("multiverse", n_threads=4)     # or tl2/dctl/norec/
    base = tm.alloc(100, 0)                     #    tinystm/mvstore

    @atomic(tm)
    def incr(tx, i):
        tx.write(base + i, tx.read(base + i) + 1)

    with tm.txn(tid=1) as tx:                   # single attempt
        total = sum(tx.read(base + i) for i in range(100))

    run(tm, lambda tx: sum(tx.read_bulk(range(base, base + 100))))
                                                # batched long read:
                                                # one gather, not 100
                                                # interpreter round-trips
    tm.stats()                                  # normalized schema
    tm.stop()

See API.md for the full contract.  `repro.core.stm.run()` remains as a
deprecation shim over `run` here.
"""
from repro.api.adapters import WordSubstrate  # noqa: F401
from repro.api.registry import (  # noqa: F401
    backend_names,
    make_tm,
    register_backend,
)
from repro.api.substrate import (  # noqa: F401
    AbortTx,
    MaxRetriesExceeded,
    Substrate,
    SubstrateBase,
    Txn,
    as_substrate,
    atomic,
    run,
)
from repro.core.stats_schema import (  # noqa: F401
    STATS_KEYS,
    base_stats,
    normalize_stats,
)

__all__ = [
    "AbortTx", "MaxRetriesExceeded", "MVStoreHandle", "STATS_KEYS",
    "Substrate", "SubstrateBase", "Txn", "WordSubstrate", "as_substrate",
    "atomic", "backend_names", "base_stats", "make_tm", "normalize_stats",
    "register_backend", "run",
]


def __getattr__(name):
    # MVStoreHandle pulls in jax; load it lazily so word-level users
    # (benchmarks, the STM tests) never pay the import
    if name == "MVStoreHandle":
        from repro.api.mvhandle import MVStoreHandle
        return MVStoreHandle
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
