"""The one transactional surface (`Substrate` protocol + `Txn` handle).

The paper's claim is that versioned and unversioned transactions share a
single programming model; this module is that model for the repo.  Every
backend — the word-level Multiverse STM, the TL2/DCTL/NOrec/TinySTM
baselines, and the Layer-B MVStore — is driven through the same five verbs:

    tm = make_tm("multiverse", n_threads=4)
    a = tm.alloc(2, 100)

    with tm.txn(tid=0) as tx:          # one attempt; AbortTx on conflict
        tx.write(a, tx.read(a) + 1)

    @atomic(tm, tid=0)                 # retry loop built in
    def transfer(tx, src, dst, amt):
        tx.write(src, tx.read(src) - amt)
        tx.write(dst, tx.read(dst) + amt)

    run(tm, lambda tx: tx.read(a), tid=1)   # functional form

Retry/backoff policy lives HERE (in `run`), not in any backend: aborts
raise `AbortTx` (the setjmp/longjmp analogue), `run` rolls the transaction
back if the backend has not already, and retries up to `max_retries`
(0 = unbounded) with optional exponential backoff.
"""
from __future__ import annotations

import functools
import random
import time
from typing import Any, Callable, Optional, Protocol, runtime_checkable

from repro.core.engine import AbortTx, MaxRetriesExceeded

__all__ = [
    "AbortTx", "MaxRetriesExceeded", "Substrate", "SubstrateBase", "Txn",
    "atomic", "run", "as_substrate",
]


class Txn:
    """Uniform transaction handle: what user code sees inside a txn body.

    The same handle type is used on every substrate; it only forwards to
    the owning substrate, which interprets `addr` for its layer (heap word
    index at the word level, block offset at the store level).
    """

    __slots__ = ("_sub", "_ctx", "tid")

    def __init__(self, sub: "SubstrateBase", ctx: Any, tid: int):
        self._sub = sub
        self._ctx = ctx
        self.tid = tid

    def read(self, addr: int) -> Any:
        return self._sub.read(self._ctx, addr)

    def read_bulk(self, addrs) -> Any:
        """Batched transactional read: ``[self.read(a) for a in addrs]``
        semantics, one substrate call.

        ``addrs`` is any address sequence (``range``, list, ndarray).
        On engine-backed word substrates the batch runs as one heap
        gather bracketed by two consistent lock-word gathers plus a
        vectorized predicate (the ``kernels/gather_read.py`` path on
        TPU); on `MVStoreHandle` it is one slice of the live block or the
        snapshot ring row.  Elements the fast path cannot prove
        consistent are transparently re-read through the scalar protocol.

        SAFETY is never weakened: every accepted element is provably the
        value at the transaction's snapshot, and unprovable elements get
        the policy's exact scalar semantics.  One LIVENESS caveat: on
        Multiverse's Mode-Q versioned path, batching accepts stable words
        by validation instead of seeding version lists for them (the
        scalar reader-triggered versioning), so a later re-read of a word
        an updater has since overwritten — or another versioned reader of
        it — may abort where the all-scalar protocol would have found a
        version.  Long scans read each word once and are unaffected.
        Returns a sequence (ndarray on array-backed heaps when the whole
        batch gathered clean, list otherwise).
        """
        fn = getattr(self._sub, "read_bulk", None)
        if fn is not None:
            return fn(self._ctx, addrs)
        return [self._sub.read(self._ctx, int(a)) for a in addrs]

    def traverse_bulk(self, roots, expand, *, limit: Optional[int] = None):
        """Ordered frontier-at-a-time traversal over ``read_bulk``.

        ``roots`` is an iterable of ``(addr, span[, state])`` items;
        ``expand(state, words, emit, push)`` turns each item's gathered
        words into in-order emissions and child pushes.  Per round the
        WHOLE pending frontier gathers in one ``read_bulk`` batch, so a
        pointer-chasing long read costs one batch per level instead of
        one scalar read per word — with each backend's exact scalar
        semantics preserved per element (the batch itself guarantees
        that).  See ``repro.core.engine.traverse`` and API.md "Batched
        traversals" for the full contract and runnable examples.
        """
        from repro.core.engine.traverse import traverse_bulk
        return traverse_bulk(self, roots, expand, limit=limit)

    def chase_bulk(self, cursors, advance) -> int:
        """Vectorized pointer chase for single-word frontiers (chains):
        per round, ``read_bulk`` gathers the words at every cursor and
        ``advance(cursors, values)`` returns the next cursor array —
        accumulation lives in the caller's closure.  Returns the number
        of rounds.  See ``repro.core.engine.traverse.chase_bulk``.
        """
        from repro.core.engine.traverse import chase_bulk
        return chase_bulk(self, cursors, advance)

    def write(self, addr: int, value: Any) -> None:
        self._sub.write(self._ctx, addr, value)

    def write_bulk(self, addrs, values) -> None:
        """Batched transactional write: ``for a, v: self.write(a, v)``
        semantics, one substrate call.

        Buffered backends (TL2/NOrec/MVStore) fold the batch into the
        write buffer in one update; encounter-time backends (DCTL,
        TinySTM, Multiverse Mode Q) validate and claim every lock in ONE
        all-or-nothing ``try_lock_bulk`` CAS sweep, record pre-images in
        one undo gather, and publish in one heap scatter — the write
        half of the batched commit pipeline (API.md "Batched commits").
        Semantics are never weakened: batches the sweep cannot claim
        take the policy's exact scalar path word by word.
        """
        fn = getattr(self._sub, "write_bulk", None)
        if fn is not None:
            fn(self._ctx, addrs, values)
            return
        for a, v in zip(addrs, values):
            self._sub.write(self._ctx, int(a), v)

    def alloc(self, n: int, init: Any = None) -> int:
        """Transactional allocation.  Word-level backends free it again
        if this txn aborts; MVStoreHandle applies growth immediately
        (block shapes are step-boundary state, not txn state)."""
        return self._sub.txn_alloc(self._ctx, n, init)

    @property
    def read_count(self) -> int:
        return self._sub.read_count(self._ctx)

    def validate_bulk(self) -> bool:
        """Batched mid-transaction validation: is everything this txn has
        read still consistent right now?

        Routes to the substrate's engine-level validator — the word-level
        engine checks the whole read set in one vectorized pass (numpy
        gather on CPU, the ``kernels/validate.py`` Pallas kernel on TPU)
        once it exceeds ``engine.BULK_MIN`` entries; `MVStoreHandle`
        checks its snapshot clock / ring window.  Read-only: never aborts
        and never mutates txn state, so long readers can poll it to fail
        fast instead of discovering staleness only at commit.
        """
        fn = getattr(self._sub, "validate", None)
        return bool(fn(self._ctx)) if fn is not None else True


@runtime_checkable
class Substrate(Protocol):
    """What a backend must provide to plug into `run`/`atomic`/`txn`.

    `begin` hands out a `Txn`; `read`/`write`/`txn_alloc` take the context
    the substrate itself put into that handle; `commit`/`abort` finish it.
    `abort` must be IDEMPOTENT: called on an already-rolled-back txn it is
    a no-op (the retry loop cannot know whether the backend unwound state
    before raising `AbortTx`).
    """

    name: str

    def begin(self, tid: int = 0) -> Txn: ...
    def read(self, ctx: Any, addr: int) -> Any: ...
    def write(self, ctx: Any, addr: int, value: Any) -> None: ...
    def txn_alloc(self, ctx: Any, n: int, init: Any = None) -> int: ...
    def commit(self, txn: Txn) -> None: ...
    def abort(self, txn: Txn) -> None: ...
    def alloc(self, n: int, init: Any = None) -> int: ...
    def stats(self) -> dict: ...
    def stop(self) -> None: ...


class _TxnScope:
    """Single-attempt context manager returned by `SubstrateBase.txn`.

    Commits on clean exit; a conflict (`AbortTx`) propagates to the caller
    — pair with `run`/`atomic` when you want automatic retry.  Any other
    exception rolls the attempt back before propagating, so user errors
    can never poison the TM (locks held, writes unrolled).
    """

    __slots__ = ("_sub", "_tid", "_txn")

    def __init__(self, sub: "SubstrateBase", tid: int):
        self._sub = sub
        self._tid = tid
        self._txn: Optional[Txn] = None

    def __enter__(self) -> Txn:
        self._txn = self._sub.begin(self._tid)
        return self._txn

    def __exit__(self, exc_type, exc, tb) -> bool:
        assert self._txn is not None
        if exc_type is None:
            self._sub.commit(self._txn)      # may raise AbortTx
            return False
        # AbortTx from inside the body: the backend already rolled back
        # (abort() is idempotent, so a voluntary user-raised AbortTx is
        # unwound here too); other exceptions must roll back before
        # propagating.  A simulated crash (reliability/faultpoints) is
        # the one exception that must NOT roll back: a real crash never
        # runs this frame, and recovery needs the crash image intact.
        if getattr(exc, "simulated_crash", False):
            return False
        self._sub.abort(self._txn)
        return False


class SubstrateBase:
    """Shared convenience surface every substrate inherits.

    Subclasses implement the `Substrate` protocol verbs; this base adds the
    context-manager / decorator / stats plumbing on top of them.
    """

    name = "substrate"

    # -- protocol hooks subclasses may refine ---------------------------
    def begin_operation(self, tid: int) -> None:
        """Reset per-OPERATION state before a fresh retry loop.

        Per-transaction state (versioned flag, attempt count) persists
        only across RETRIES of one logical operation — the paper resets
        these thread-locals when a NEW transaction starts (Alg. 1 l.10).
        """

    def read_count(self, ctx: Any) -> int:
        return getattr(ctx, "read_cnt", 0)

    def read_bulk(self, ctx: Any, addrs) -> Any:
        """`Txn.read_bulk` hook: default is the scalar loop, so every
        substrate supports the batched surface even before it vectorizes
        (`WordSubstrate`/`MVStoreHandle` override with real batches)."""
        return [self.read(ctx, int(a)) for a in addrs]

    def validate(self, ctx: Any) -> bool:
        """`Txn.validate_bulk` hook: read-only consistency check."""
        return True

    def on_retries_exhausted(self, tid: int) -> None:
        """Retry-cap cleanup hook: `run` calls this before raising
        `MaxRetriesExceeded` so a capped transaction can never leave
        encounter-time locks held or retire buffers unflushed (a wedged
        thread must not block later writers — paper SS5's abort cap)."""

    # -- uniform user surface -------------------------------------------
    def txn(self, tid: int = 0) -> _TxnScope:
        """One transaction attempt as a context manager."""
        self.begin_operation(tid)
        return _TxnScope(self, tid)

    def run(self, fn: Callable[[Txn], Any], tid: int = 0,
            max_retries: int = 0, backoff_s: float = 0.0) -> Any:
        return run(self, fn, tid=tid, max_retries=max_retries,
                   backoff_s=backoff_s)

    def atomic(self, tid: int = 0, max_retries: int = 0,
               backoff_s: float = 0.0):
        return atomic(self, tid=tid, max_retries=max_retries,
                      backoff_s=backoff_s)

    def __enter__(self) -> "SubstrateBase":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        return False

    def stop(self) -> None:  # pragma: no cover - overridden
        pass


def as_substrate(tm: Any) -> Any:
    """Coerce a raw TM (Multiverse / baseline) into the Substrate surface.

    Already-wrapped substrates — and any third-party object implementing
    the `Substrate` protocol — pass through untouched, so every entry
    point accepts `make_tm(...)` products, protocol implementations, and
    hand-built TM instances alike.
    """
    if isinstance(tm, SubstrateBase) or isinstance(tm, Substrate):
        return tm
    from repro.api.adapters import WordSubstrate
    return WordSubstrate(tm)


_BACKOFF_CAP_S = 0.01


def run(tm: Any, fn: Callable[[Txn], Any], tid: int = 0,
        max_retries: int = 0, backoff_s: float = 0.0) -> Any:
    """Run `fn(tx)` as one atomic operation, retrying on conflict.

    max_retries=0 means unbounded (the paper's workers); a bounded cap
    raises `MaxRetriesExceeded` (the paper's SS5 'maximum allowed aborts').
    `backoff_s` > 0 sleeps an exponentially growing, jittered interval
    between attempts (capped at 10ms) — off by default because the GIL
    already serializes this port's contention.
    """
    sub = as_substrate(tm)
    op_reset = getattr(sub, "begin_operation", None)
    if op_reset is not None:        # optional hook; bare Substrate
        op_reset(tid)               # implementations may omit it
    tries = 0
    while True:
        txn = sub.begin(tid)
        try:
            result = fn(txn)
            sub.commit(txn)
            return result
        except AbortTx:
            sub.abort(txn)               # no-op if the backend rolled back
            tries += 1
            if max_retries and tries >= max_retries:
                cleanup = getattr(sub, "on_retries_exhausted", None)
                if cleanup is not None:
                    cleanup(tid)         # release locks, flush retires
                raise MaxRetriesExceeded(
                    f"{sub.name}: txn exceeded {max_retries} retries")
            if backoff_s:
                delay = min(_BACKOFF_CAP_S, backoff_s * (1 << min(tries, 10)))
                time.sleep(delay * random.random())
        except BaseException as e:
            # user-code exception mid-attempt: roll back so the TM is not
            # poisoned (locks held / writes unrolled), then propagate —
            # unless it's a simulated crash (reliability/faultpoints),
            # whose whole point is that no cleanup frame ever runs and
            # recovery must reconstruct consistency from the wreckage
            if not getattr(e, "simulated_crash", False):
                sub.abort(txn)
            raise


def atomic(tm: Any, tid: int = 0, max_retries: int = 0,
           backoff_s: float = 0.0):
    """Decorator form: the function body becomes a transaction.

    The decorated function gains keyword-only `tid=` / `max_retries=`
    overrides at call time (so one decorated body can serve many worker
    threads):

        @atomic(tm, tid=0)
        def transfer(tx, src, dst, amt): ...
        transfer(a, b, 5)          # runs as thread 0
        transfer(a, b, 5, tid=3)   # same body, thread 3
    """
    sub = as_substrate(tm)

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args, tid=tid, max_retries=max_retries,
                    backoff_s=backoff_s, **kwargs):
            return run(sub, lambda tx: fn(tx, *args, **kwargs), tid=tid,
                       max_retries=max_retries, backoff_s=backoff_s)
        wrapper.__substrate__ = sub
        return wrapper
    return deco
