"""Thin adapter that puts the word-level engine behind the Substrate protocol.

`WordSubstrate` wraps any `TransactionEngine` (the Multiverse STM or a
TL2/DCTL/NOrec/TinySTM baseline — all policies over `repro.core.engine`).
It owns none of the transactional logic — begin/read/write/commit stay in
the engine — it only normalizes the lifecycle so the shared retry loop
(`repro.api.run`), the `txn()` context manager and `@atomic` work
identically on every TM:

  * `abort` delegates to the engine's idempotent `_abort` (policy-specific
    rollback included), so a voluntary or user-error unwind can never
    leave locks held or writes unrolled;
  * `validate` routes `Txn.validate_bulk` to the engine's batched
    read-set validator (scalar below `BULK_MIN`, vectorized above);
  * `on_retries_exhausted` lets the retry loop force-release anything a
    capped transaction still holds (locks, retire buffers);
  * `stats()` reports the shared schema with the registry backend name;
  * unknown attributes fall through to the raw TM, so instrumentation
    that pokes backend internals (`tm.vlt`, `tm.mode_counter`, ...)
    keeps working on the wrapped object.

Pre-engine TMs (third-party `TMBase` descendants) still work: every
engine-specific call falls back to the old attribute-poking behavior.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.api.substrate import SubstrateBase, Txn
from repro.core.engine import AbortTx
from repro.core.stats_schema import normalize_stats

__all__ = ["WordSubstrate"]


class WordSubstrate(SubstrateBase):
    def __init__(self, raw: Any, name: Optional[str] = None):
        self.raw = raw
        self.name = name or type(raw).__name__.lower()

    # -- lifecycle -------------------------------------------------------
    def begin_operation(self, tid: int) -> None:
        op = getattr(self.raw, "begin_operation", None)
        if op is not None:                # engine path
            op(tid)
            return
        ctx = self.raw.ctx(tid)           # legacy raw-TM fallback
        if hasattr(ctx, "versioned"):
            ctx.versioned = False
            ctx.no_versioning = False
            ctx.initial_versioned_ts = None
        ctx.attempts = 0

    def begin(self, tid: int = 0) -> Txn:
        self.raw.begin(tid)
        ctx = self.raw.ctx(tid)
        ctx.active = True
        return Txn(self, ctx, tid)

    def commit(self, txn: Txn) -> None:
        self.raw._try_commit(txn._ctx)
        txn._ctx.active = False

    def abort(self, txn: Txn) -> None:
        ctx = txn._ctx
        if not getattr(ctx, "active", False):
            return                        # backend already rolled back
        try:
            self.raw._abort(ctx)          # engine: idempotent, no raise
        except AbortTx:
            pass                          # legacy TMs raise from _abort
        ctx.active = False

    # -- accesses --------------------------------------------------------
    def read(self, ctx: Any, addr: int) -> Any:
        return self.raw.tm_read(ctx, addr)

    def read_bulk(self, ctx: Any, addrs) -> Any:
        """`Txn.read_bulk`: engine-routed batch (one heap gather + lock
        gathers + vectorized predicate); legacy raw TMs without
        `tm_read_bulk` fall back to the scalar loop."""
        fn = getattr(self.raw, "tm_read_bulk", None)
        if fn is not None:
            return fn(ctx, addrs)
        return [self.raw.tm_read(ctx, int(a)) for a in addrs]

    def write(self, ctx: Any, addr: int, value: Any) -> None:
        self.raw.tm_write(ctx, addr, value)

    def write_bulk(self, ctx: Any, addrs, values) -> None:
        """`Txn.write_bulk`: engine-routed batch (one lock-claim sweep +
        undo gather + heap scatter for encounter-time policies, one
        write-map update for buffered ones); legacy raw TMs without
        `tm_write_bulk` fall back to the scalar loop."""
        fn = getattr(self.raw, "tm_write_bulk", None)
        if fn is not None:
            fn(ctx, addrs, values)
            return
        for a, v in zip(addrs, values):
            self.raw.tm_write(ctx, int(a), v)

    def txn_alloc(self, ctx: Any, n: int, init: Any = None) -> int:
        return self.raw.tx_alloc(ctx, n, init)

    def read_count(self, ctx: Any) -> int:
        if getattr(ctx, "read_cnt", 0):
            return ctx.read_cnt
        return len(getattr(ctx, "read_set", ())) + \
            len(getattr(ctx, "read_vals", ()))

    # -- validation / exhaustion ------------------------------------------
    def validate(self, ctx: Any) -> bool:
        """`Txn.validate_bulk`: batched read-set check, engine-routed."""
        fn = getattr(self.raw, "validate_ctx", None)
        return bool(fn(ctx)) if fn is not None else True

    def on_retries_exhausted(self, tid: int) -> None:
        fn = getattr(self.raw, "on_retries_exhausted", None)
        if fn is not None:
            fn(tid)

    # -- heap / lifecycle pass-through ------------------------------------
    def alloc(self, n: int, init: Any = None) -> int:
        return self.raw.alloc(n, init)

    def peek(self, addr: int) -> Any:
        return self.raw.peek(addr)

    def stats(self) -> dict:
        return normalize_stats(self.raw.stats(), backend=self.name)

    def stop(self) -> None:
        self.raw.stop()

    def __getattr__(self, item: str) -> Any:
        # instrumentation escape hatch: vlt, mode_counter, announce, ...
        return getattr(self.raw, item)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"WordSubstrate({self.name})"
