"""Thin adapters that put the word-level TMs behind the Substrate protocol.

`WordSubstrate` wraps any `TMBase` descendant (the Multiverse STM or a
TL2/DCTL/NOrec/TinySTM baseline).  It owns none of the transactional logic
— begin/read/write/commit stay in the backend — it only normalizes the
lifecycle so the shared retry loop (`repro.api.run`), the `txn()` context
manager and `@atomic` work identically on every TM:

  * `abort` is idempotent and backend-aware: it unwinds in-place writes
    via `_rollback_abort` where the backend has one (DCTL/TinySTM), via
    `_abort` otherwise, and does nothing when the backend already rolled
    back before raising `AbortTx`;
  * `stats()` reports the shared schema with the registry backend name;
  * unknown attributes fall through to the raw TM, so instrumentation
    that pokes backend internals (`tm.vlt`, `tm.mode_counter`, ...)
    keeps working on the wrapped object.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.api.substrate import SubstrateBase, Txn
from repro.core.stats_schema import normalize_stats
from repro.core.stm import AbortTx

__all__ = ["WordSubstrate"]


class WordSubstrate(SubstrateBase):
    def __init__(self, raw: Any, name: Optional[str] = None):
        self.raw = raw
        self.name = name or type(raw).__name__.lower()

    # -- lifecycle -------------------------------------------------------
    def begin_operation(self, tid: int) -> None:
        ctx = self.raw.ctx(tid)
        if hasattr(ctx, "versioned"):
            ctx.versioned = False
            ctx.no_versioning = False
            ctx.initial_versioned_ts = None
        ctx.attempts = 0

    def begin(self, tid: int = 0) -> Txn:
        self.raw.begin(tid)
        ctx = self.raw.ctx(tid)
        ctx.active = True
        return Txn(self, ctx, tid)

    def commit(self, txn: Txn) -> None:
        self.raw._try_commit(txn._ctx)
        txn._ctx.active = False

    def abort(self, txn: Txn) -> None:
        ctx = txn._ctx
        if not getattr(ctx, "active", False):
            return                        # backend already rolled back
        raw = self.raw
        try:
            if hasattr(raw, "_rollback_abort") and (
                    getattr(ctx, "undo", None) or
                    getattr(ctx, "write_map", None)):
                raw._rollback_abort(ctx)  # encounter-time in-place writes
            else:
                raw._abort(ctx)
        except AbortTx:
            pass                          # baselines raise from _abort
        ctx.active = False

    # -- accesses --------------------------------------------------------
    def read(self, ctx: Any, addr: int) -> Any:
        return self.raw.tm_read(ctx, addr)

    def write(self, ctx: Any, addr: int, value: Any) -> None:
        self.raw.tm_write(ctx, addr, value)

    def txn_alloc(self, ctx: Any, n: int, init: Any = None) -> int:
        return self.raw.tx_alloc(ctx, n, init)

    def read_count(self, ctx: Any) -> int:
        if hasattr(ctx, "read_cnt"):
            return ctx.read_cnt
        return len(ctx.read_set) + len(ctx.read_vals)

    # -- heap / lifecycle pass-through ------------------------------------
    def alloc(self, n: int, init: Any = None) -> int:
        return self.raw.alloc(n, init)

    def peek(self, addr: int) -> Any:
        return self.raw.peek(addr)

    def stats(self) -> dict:
        return normalize_stats(self.raw.stats(), backend=self.name)

    def stop(self) -> None:
        self.raw.stop()

    def __getattr__(self, item: str) -> Any:
        # instrumentation escape hatch: vlt, mode_counter, announce, ...
        return getattr(self.raw, item)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"WordSubstrate({self.name})"
