"""MVStoreHandle — the Layer-B MVStore behind the same Substrate protocol.

Wraps `mv_init/mv_commit/mv_snapshot` plus an `MVController` in the
begin/read/write/commit vocabulary of `repro.api`, so a snapshot read is
LITERALLY a read-only transaction — the same `@atomic` audit that runs on
the word-level Multiverse STM runs unchanged here:

  * the heap is ONE parameter block (an int32 vector); `alloc` grows it,
    `Txn.read/write` index into it;
  * an update transaction buffers writes (TL2-style) and publishes them as
    one `mv_commit` under a single-writer lock — the optimizer-step
    analogue — validating that the global clock has not advanced past its
    begin snapshot;
  * a read-only transaction validates the clock on the unversioned path
    (the Mode-Q reader that aborts when the writer commits first) and
    resolves ring versions at its read clock on the versioned path;
  * aborts feed the SAME K1/K2/K3 heuristics as the word level, via
    `MVController.ReaderHandle`: after K1 aborts a reader goes versioned
    (requesting ring versioning of the block), K2/K3 CAS the global mode
    Q -> QtoU, and the controller's background thread cycles the modes.

Values are numeric (this layer models parameter blocks); word substrates
additionally store arbitrary Python objects.
"""
from __future__ import annotations

import threading
from typing import Any, Optional, Tuple

import numpy as np

from repro.api.substrate import SubstrateBase, Txn
from repro.core import modes as M
from repro.core.engine import AbortTx
from repro.core.stats_schema import RECOVERY_STAT_KEYS, base_stats
from repro.reliability import faultpoints as FP

__all__ = ["MVStoreHandle"]

_COUNTER_KEYS = ("commits", "aborts", "ro_commits", "versioned_commits")

_RACED = object()   # a device read lost the race against a donating commit


def _donation_raced(e: BaseException) -> bool:
    """True when a device read hit a buffer that ``mv_commit_fused``
    donated out from under it (jax spells it RuntimeError "Array has
    been deleted", XLA ValueError "buffer has been deleted or
    donated")."""
    msg = str(e)
    return "deleted" in msg or "donated" in msg


def _ring_slot(ring_ts, read_clock: int) -> Optional[int]:
    """Newest ring slot with a timestamp at/below ``read_clock``, or
    ``None`` when the clock fell out of the ring window (the one place
    the slot-selection idiom lives: scalar read, bulk read,
    ``snapshot_bulk`` and ``validate`` all route here)."""
    if ring_ts is None:
        return None
    valid = (ring_ts != -1) & (ring_ts <= read_clock)
    if not valid.any():
        return None
    return int(np.argmax(np.where(valid, ring_ts, -1)))


class _MVCtx:
    """Per-transaction context at the store level."""

    __slots__ = ("tid", "read_clock", "write_buf", "read_only", "read_cnt",
                 "active", "versioned")

    def __init__(self, tid: int):
        self.tid = tid
        self.read_clock = 0
        self.write_buf: dict = {}
        self.read_only = True
        self.read_cnt = 0
        self.active = False
        self.versioned = False


class MVStoreHandle(SubstrateBase):
    name = "mvstore"

    def __init__(self, n_threads: int = 1, *, cfg=None, params=None,
                 controller=None, versioned: str = "none",
                 start_bg: bool = True):
        import jax.numpy as jnp
        from repro.configs.base import MVStoreConfig
        from repro.configs.paper_stm import MultiverseParams
        from repro.core import mvstore
        from repro.core.mvcontroller import MVController

        self._jnp = jnp
        self._mvstore = mvstore
        self.n_threads = n_threads
        self.cfg = cfg or MVStoreConfig(ring_slots=8)
        self.params = params or MultiverseParams()
        self.controller = controller or MVController(
            params=self.params, mvcfg=self.cfg, start_bg=start_bg)
        self._own_controller = controller is None
        self._key = "heap"
        live = {self._key: jnp.zeros((0,), jnp.int32)}
        self._path = mvstore.block_paths(live)[0]
        self._commit_lock = threading.Lock()
        # crash-recovery slot (reliability/recovery.recover_handle): the
        # fused commit DONATES the old live/ring buffers, so between the
        # fused call and _install the ONLY reachable copy of the store is
        # this in-flight state — a crash there strands readers on deleted
        # buffers until recovery completes the install
        self._inflight = None
        # durable commit log (reliability/wal.py, via attach_wal): when
        # set, _publish_locked appends PREPARE + fsync'd DECIDE before
        # the donating fused call — the only window whole-process
        # recovery cannot rebuild from in-memory state
        self.wal = None
        self.wal_shard = -1
        self.recovery_counters = {k: 0 for k in RECOVERY_STAT_KEYS}
        self._readers = [self.controller.reader() for _ in range(n_threads)]
        self._counters = [{k: 0 for k in _COUNTER_KEYS}
                         for _ in range(n_threads)]
        self._no_version = [False] * n_threads
        self._state = None
        self._snap: Tuple = (0, np.zeros((0,), np.int32), None, None)
        self._install(mvstore.mv_init(live, self.cfg, versioned=versioned))

    # -- state installation ----------------------------------------------
    def _install(self, state) -> None:
        """Publish a new MVStoreState plus the reader-visible snapshot.

        Readers only ever dereference `self._snap` — one immutable tuple
        replaced wholesale, so a read never sees half of a commit (the JAX
        buffer-immutability analogue of the paper's EBR argument).  The
        live block and ring stay DEVICE-RESIDENT jax buffers (scalar
        reads ``.item()`` one element; bulk reads gather on device via
        ``gather_row``'s jax-row branch) — only the tiny ``ring_ts``
        vector is materialized host-side, where ``_ring_slot``'s numpy
        scan wants it.  No per-commit host copy of the heap survives:
        the commit path hands the previous live/ring buffers to
        ``mv_commit_fused``, which DONATES them.  A reader pinned on
        the old ``_snap`` can therefore find its row deleted mid-read;
        that crash carries exactly the information a seqlock retry
        does — a commit raced us — so ``_read_device`` turns it into
        the abort the clock check would have issued an instant later
        (or a re-snapshot outside a transaction)."""
        ring = state.ring.get(self._path)
        if ring is not None:
            snap = (int(state.clock), state.live[self._key], ring,
                    np.asarray(state.ring_ts[self._path]))
        else:
            snap = (int(state.clock), state.live[self._key], None, None)
        self._state = state
        self._snap = snap

    def _read_device(self, fn, ctx: Optional[_MVCtx] = None):
        """One device read against snapshotted row buffers.

        ``mv_commit_fused`` donates the live/ring buffers it replaces,
        so a reader holding a pre-commit ``self._snap`` can lose its
        row mid-gather.  Inside a transaction that race IS the conflict
        the clock validation exists to catch — abort; outside one,
        return ``_RACED`` so the caller re-snapshots and retries."""
        try:
            return fn()
        except (RuntimeError, ValueError) as e:
            if not _donation_raced(e):
                raise
            if ctx is not None:
                self._abort_ctx(ctx)
            return _RACED

    # -- Substrate protocol ----------------------------------------------
    def begin_operation(self, tid: int) -> None:
        # no_versioning is per OPERATION: a versioned txn that writes must
        # restart unversioned, and must not be re-promoted on the next
        # abort of the same operation (the word-level livelock guard)
        self._no_version[tid] = False

    def begin(self, tid: int = 0) -> Txn:
        h = self._readers[tid]
        if self._no_version[tid]:
            h.versioned = False
        snap = self._snap
        ctx = _MVCtx(tid)
        ctx.read_clock = snap[0]
        h.begin(ctx.read_clock)
        ctx.versioned = h.versioned
        ctx.active = True
        return Txn(self, ctx, tid)

    def read(self, ctx: _MVCtx, addr: int) -> Any:
        ctx.read_cnt += 1
        if addr in ctx.write_buf:
            return ctx.write_buf[addr]
        clock, live, ring, ring_ts = self._snap
        if ctx.versioned and ctx.read_only:
            if ring is None:
                # Mode-Q reader versions the block itself (paper SS4.1's
                # reader-triggered versioning, at block granularity)
                clock, live, ring, ring_ts = self._version_block()
            slot = _ring_slot(ring_ts, ctx.read_clock)
            if slot is None:
                self._abort_ctx(ctx)       # fell out of the ring window
            return self._read_device(lambda: ring[slot, addr].item(), ctx)
        # unversioned (Mode-Q reader / writer encounter read): validate
        # that no commit has advanced the clock past our begin snapshot
        if clock > ctx.read_clock:
            self._abort_ctx(ctx)
        return self._read_device(lambda: live[addr].item(), ctx)

    def read_bulk(self, ctx: _MVCtx, addrs) -> Any:
        """`Txn.read_bulk` at the store level: one slice per batch.

        The store is already array-shaped, so the batch is literally one
        gather — of the live block on the unversioned path (after the
        same clock check every scalar read makes), or of the ONE ring row
        the reader's clock selects on the versioned path (slot selection
        is a host-side scan of the tiny timestamp vector; the row gather
        runs through ``kernels/gather_read.py`` on TPU).  A scanner that
        reads the whole block thus costs one launch, not N interpreter
        round-trips — the measurement the eval subsystem is built on.
        This is also the store-level substrate of the traversal layer:
        ``Txn.traverse_bulk``/``chase_bulk`` issue only ``read_bulk``
        calls, so struct walks over an MVStore block batch per frontier
        step exactly like the word-level engine.
        """
        from repro.core.engine.bulkread import as_addr_array
        a = as_addr_array(addrs)
        ctx.read_cnt += a.size
        clock, live, ring, ring_ts = self._snap
        if ctx.versioned and ctx.read_only:
            if ring is None:
                clock, live, ring, ring_ts = self._version_block()
            slot = _ring_slot(ring_ts, ctx.read_clock)
            if slot is None:
                self._abort_ctx(ctx)       # fell out of the ring window
            vals = self._read_device(
                lambda: self._gather_row(ring[slot], a), ctx)
        else:
            if clock > ctx.read_clock:
                self._abort_ctx(ctx)
            vals = self._read_device(
                lambda: self._gather_row(live, a), ctx)
        if ctx.write_buf:
            return [ctx.write_buf.get(int(x), v)
                    for x, v in zip(a, vals.tolist())]
        return vals

    def _gather_row(self, row: np.ndarray, a: np.ndarray) -> np.ndarray:
        """One row gather (kernel-dispatched): the engine's shared
        ``gather_row`` serves the store's live block and ring rows too."""
        from repro.core.engine.bulkread import gather_row
        return gather_row(row, a)

    def write(self, ctx: _MVCtx, addr: int, value: Any) -> None:
        if ctx.versioned:
            # versioned reads are of the PAST and cannot anchor writes to
            # the present: restart on the unversioned path, sticky for
            # this operation (mirrors Multiverse.tm_write)
            self._no_version[ctx.tid] = True
            self._abort_ctx(ctx)
        ctx.read_only = False
        ctx.write_buf[addr] = value

    def write_bulk(self, ctx: _MVCtx, addrs, values) -> None:
        """`Txn.write_bulk` at the store level: writes buffer until the
        single `mv_commit`, so the batch is one dict update — the commit
        itself already publishes the whole buffer through the shared
        scatter (``engine/commit.scatter_row``)."""
        if ctx.versioned:
            self._no_version[ctx.tid] = True
            self._abort_ctx(ctx)
        ctx.read_only = False
        ctx.write_buf.update(zip((int(a) for a in addrs), values))

    def txn_alloc(self, ctx: _MVCtx, n: int, init: Any = None) -> int:
        # applied immediately, NOT rolled back on abort: block shapes are
        # step-boundary state at this layer, and an orphaned tail of the
        # heap block is harmless (unreachable until a committed write
        # publishes its address)
        return self.alloc(n, init)

    def _version_block(self) -> Tuple:
        """Seed a ring for the heap block with the live value.  Timestamp
        is the earliest safe one — firstObsModeUTs when valid, else the
        current clock (paper SS4.2); a reader whose snapshot is older than
        the seed then aborts via the no-valid-slot check."""
        with self._commit_lock:
            state = self._state
            if self._path not in state.ring:
                state = self._mvstore.version_blocks(
                    state, {self._path}, self.cfg,
                    first_obs_mode_u_ts=self.controller.first_obs_mode_u_ts)
                self._install(state)
        return self._snap

    def commit(self, txn: Txn) -> None:
        ctx = txn._ctx
        h = self._readers[ctx.tid]
        c = self._counters[ctx.tid]
        if ctx.read_only:
            c["ro_commits"] += 1
            if ctx.versioned:
                c["versioned_commits"] += 1
            h.on_commit(ctx.read_cnt, commit_clock=self._snap[0])
            ctx.active = False
            return
        conflict = False
        with self._commit_lock:
            if self._check_conflict(ctx):
                conflict = True            # another step committed first
            else:
                self._publish_locked(ctx)
        if conflict:
            self._abort_ctx(ctx)
        c["commits"] += 1
        h.attempts = 0
        ctx.active = False

    def _check_conflict(self, ctx: _MVCtx) -> bool:
        """Commit-time validation, ``self._commit_lock`` held: has any
        block this transaction touched been committed past its begin
        pin?  Per-block last-writer stamps (``mvstore.blocks_conflict``)
        — for the single-block handle this equals the old global
        ``clock != read_clock`` check (every commit stamps the one heap
        block); the sharded store calls it per shard so disjoint-shard
        commits never conflict."""
        return self._mvstore.blocks_conflict(
            self._state, (self._path,), ctx.read_clock)

    def _publish_locked(self, ctx: _MVCtx, wal_log: bool = True) -> None:
        """The publish half of commit, ``self._commit_lock`` held and
        validation already passed.  Also the recovery redo entry point:
        the cross-shard epoch roll-forward and the WAL replay drive a
        crashed member's parked context through exactly this path with
        ``wal_log=False`` (replay must not re-journal itself; the
        cross-shard caller journals the EPOCH instead)."""
        if FP.ACTIVE is not None:
            FP.fire("pre_clock_tick", ctx.tid)
        state = self.controller.trainer_tick(self._state)
        mode = self.controller.current_local_mode()
        idx = np.array(sorted(ctx.write_buf), dtype=np.int64)
        vals = np.array([ctx.write_buf[int(i)] for i in idx])
        lsn = None
        if wal_log and self.wal is not None and idx.size:
            # PREPARE + DECIDE before the donating fused call: past the
            # donation the old buffers are GONE, so the WAL record is
            # the only thing a whole-process crash can recover from
            lsn = self.wal.append_prepare(
                ctx.tid, idx, vals,
                clocks=(int(self._state.clock) + 1,),
                shard=self.wal_shard)
            self.wal.append_decide(lsn)
        # ONE fused publish under the held commit lock (the
        # seqlock bracket): scatter into the live row AND the
        # PackedVLT ring refresh ride a single device-resident
        # ``ops.commit_fused`` call — no scatter-then-rotate
        # host round trip (``mvstore.mv_commit_fused``).  The
        # fused call fires pre_scatter itself (before donation);
        # from the call's return until _install the new state is
        # parked in _inflight so recovery can finish the publish
        state = self._mvstore.mv_commit_fused(
            state, self._key, idx, vals, local_mode=mode,
            cfg=self.cfg)
        self._inflight = state
        if FP.ACTIVE is not None:
            FP.fire("post_scatter", ctx.tid)
            FP.fire("pre_release", ctx.tid)
        self._install(state)
        self._inflight = None
        if lsn is not None:
            self.wal.append_complete(lsn)

    def abort(self, txn: Txn) -> None:
        ctx = txn._ctx
        if not getattr(ctx, "active", False):
            return
        try:
            self._abort_ctx(ctx)
        except AbortTx:
            pass

    def validate(self, ctx: _MVCtx) -> bool:
        """`Txn.validate_bulk` at the store level (read-only check).

        Unversioned transactions are valid while no commit has advanced
        the clock past their begin snapshot; versioned readers while the
        ring still holds a slot at/below their read clock.  One clock
        compare / one vectorized timestamp scan — the block-granularity
        analogue of the word engine's bulk read-set validation.
        """
        clock, live, ring, ring_ts = self._snap
        if ctx.versioned and ctx.read_only:
            if ring_ts is None:
                return True               # block not versioned yet
            return _ring_slot(ring_ts, ctx.read_clock) is not None
        return clock <= ctx.read_clock

    def _abort_ctx(self, ctx: _MVCtx) -> None:
        self._counters[ctx.tid]["aborts"] += 1
        h = self._readers[ctx.tid]
        if ctx.read_only:
            # read-only aborts drive the paper's heuristics (K1 go-
            # versioned, K2/K3 mode CAS, block-versioning requests)
            h.on_abort(ctx.read_cnt, wanted_blocks=(self._path,))
        else:
            h.attempts += 1
        ctx.active = False
        raise AbortTx()

    # -- heap -------------------------------------------------------------
    def alloc(self, n: int, init: Any = None) -> int:
        jnp = self._jnp
        fill = 0 if init is None else init
        with self._commit_lock:
            state = self._state
            live = state.live[self._key]
            base = int(live.shape[0])
            was_versioned = self._path in state.ring
            new_live = {self._key: jnp.concatenate(
                [live, jnp.full((n,), fill, live.dtype)])}
            state = self._mvstore.MVStoreState(
                live=new_live, ring={}, ring_ts={}, clock=state.clock,
                block_clocks=state.block_clocks)
            if was_versioned:   # reseed the ring at the new block shape
                state = self._mvstore.version_blocks(
                    state, {self._path}, self.cfg,
                    first_obs_mode_u_ts=self.controller.first_obs_mode_u_ts)
            self._install(state)
        return base

    def peek(self, addr: int) -> Any:
        while True:
            v = self._read_device(lambda: self._snap[1][addr].item())
            if v is not _RACED:
                return v

    # -- Layer-B extras ----------------------------------------------------
    def snapshot(self, read_clock: Optional[int] = None):
        """(params_view, ok) via mv_snapshot — the functional spelling of a
        read-only transaction at `read_clock` (default: now)."""
        state = self._state
        if read_clock is None:
            read_clock = int(state.clock)
        return self._mvstore.mv_snapshot(state, read_clock)

    def snapshot_bulk(self, addrs, read_clock: Optional[int] = None):
        """``(values, ok)``: batched snapshot read outside any transaction.

        The functional spelling of `read_bulk` in a read-only transaction
        at ``read_clock`` (default: now): the current clock serves from
        the live block; a stale clock resolves through the ring (``ok``
        False when the block is unversioned or the clock fell out of the
        ring window — the cases a transactional reader would abort on).
        """
        from repro.core.engine.bulkread import as_addr_array
        a = as_addr_array(addrs)
        while True:
            clock, live, ring, ring_ts = self._snap
            if read_clock is None or read_clock >= clock:
                vals = self._read_device(lambda: self._gather_row(live, a))
            else:
                slot = _ring_slot(ring_ts, read_clock)
                if slot is None:
                    return None, False
                vals = self._read_device(
                    lambda: self._gather_row(ring[slot], a))
            if vals is not _RACED:
                return vals, True

    @property
    def state(self):
        """The underlying MVStoreState (trainer integration)."""
        return self._state

    @property
    def clock(self) -> int:
        return self._snap[0]

    # -- stats / lifecycle -------------------------------------------------
    def stats(self) -> dict:
        out = base_stats(backend=self.name,
                         mode=M.mode_name(self.controller.mode_counter))
        for c in self._counters:
            for k in _COUNTER_KEYS:
                out[k] += c[k]
        out["mode_cas"] = sum(h.stats["mode_cas"] for h in self._readers)
        out["mode_transitions"] = self.controller.stats["mode_transitions"]
        out["unversioned_buckets"] = self.controller.stats[
            "blocks_unversioned"]
        for k, v in self.recovery_counters.items():
            out[k] += v
        return out

    def stop(self) -> None:
        if self._own_controller:
            self.controller.stop()
