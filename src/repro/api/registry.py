"""Backend registry: `make_tm(name, n_threads=..., **kw)`.

One constructor for every substrate, so benchmarks, examples and tests
stop special-casing backends:

    make_tm("multiverse", n_threads=8, params=MultiverseParams(k1=4))
    make_tm("tl2", n_threads=8)
    make_tm("dctl", n_threads=8, irrevocable_after=50)
    make_tm("mvstore", n_threads=4, ring_slots=16)

Every factory returns a `SubstrateBase` — the word-level TMs wrapped in
`WordSubstrate`, the store-level MVStore as an `MVStoreHandle` — so the
product always speaks `txn()/run()/atomic()/stats()/stop()` with the
normalized stats schema.

`forced_mode` pins the mode machinery for the Fig. 8 ablations on the
backends that have one (multiverse, mvstore): "U" jumps the mode counter
to Mode U and pins a sticky bit so the background thread stays there; "Q"
disables the Q->QtoU CAS heuristics (K2/K3 -> inf).  The mode-less
baselines ignore it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

from repro.api.adapters import WordSubstrate
from repro.api.substrate import SubstrateBase

__all__ = ["make_tm", "register_backend", "backend_names"]

_BACKENDS: Dict[str, Callable[..., SubstrateBase]] = {}


def register_backend(name: str, factory: Callable[..., SubstrateBase],
                     overwrite: bool = False) -> None:
    """Register `factory(n_threads, params, forced_mode, **kw)` under
    `name` (case-insensitive).  Later scaling PRs (sharded stores, async
    readers) plug in here instead of growing new entry points."""
    key = name.lower()
    if key in _BACKENDS and not overwrite:
        raise ValueError(f"backend {name!r} already registered")
    _BACKENDS[key] = factory


def backend_names() -> tuple:
    return tuple(sorted(_BACKENDS))


def make_tm(name: str, n_threads: int = 1, *,
            params: Any = None, forced_mode: Optional[str] = None,
            **kw) -> SubstrateBase:
    try:
        factory = _BACKENDS[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {backend_names()}"
        ) from None
    return factory(n_threads, params=params, forced_mode=forced_mode, **kw)


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------


def _make_multiverse(n_threads: int, params=None, forced_mode=None,
                     start_bg: bool = True, array_heap: bool = False,
                     **kw) -> SubstrateBase:
    from repro.configs.paper_stm import MultiverseParams
    from repro.core.stm import Multiverse

    if params is None:
        params = MultiverseParams(**kw)
    elif kw:
        params = dataclasses.replace(params, **kw)
    if forced_mode == "Q":
        # disable the Q->QtoU CAS heuristics: the TM can never leave Q
        params = dataclasses.replace(params, k2=1 << 30, k3=1 << 30)
    tm = Multiverse(n_threads, params, start_bg=start_bg,
                    heap=_make_heap(array_heap))
    if forced_mode == "U":
        # jump the counter to Mode U and pin a synthetic sticky bit so
        # the background thread stays there (Fig. 8 forced-U variant)
        tm.mode_counter.store(2)
        tm.first_obs_mode_u_ts.store(tm.clock.load())
        tm.announce[0].sticky_mode_u = True
    return WordSubstrate(tm, name="multiverse")


def _make_heap(array_heap: bool):
    """`array_heap=True`: numeric words in the engine's int64 buffer
    (`engine.ArrayHeap`) so bulk kernels can touch the whole heap; the
    default ObjectHeap additionally stores arbitrary Python values."""
    if not array_heap:
        return None
    from repro.core.engine import ArrayHeap
    return ArrayHeap()


def _make_baseline(cls, name: str):
    def factory(n_threads: int, params=None, forced_mode=None,
                array_heap: bool = False, **kw) -> SubstrateBase:
        # baselines share the Multiverse lock-table sizing for fairness
        if params is not None and "lock_bits" not in kw:
            kw["lock_bits"] = params.lock_table_bits
        return WordSubstrate(cls(n_threads, heap=_make_heap(array_heap),
                                 **kw), name=name)
    return factory


def _make_mvstore(n_threads: int, params=None, forced_mode=None,
                  **kw) -> SubstrateBase:
    from repro.api.mvhandle import MVStoreHandle
    from repro.configs.paper_stm import MultiverseParams

    if "ring_slots" in kw:
        from repro.configs.base import MVStoreConfig
        kw.setdefault("cfg", MVStoreConfig(ring_slots=kw.pop("ring_slots")))
    if forced_mode == "Q":
        params = dataclasses.replace(params or MultiverseParams(),
                                     k2=1 << 30, k3=1 << 30)
    h = MVStoreHandle(n_threads, params=params, **kw)
    if forced_mode == "U":
        # pin the controller in Mode U via a dedicated sticky reader
        # handle no worker tid ever commits through (so sticky_cleared
        # can never clear it) — the store-level forced-U ablation
        ctl = h.controller
        ctl.mode_counter = 2                      # Q -> QtoU -> U
        ctl.stats["mode_transitions"] += 2
        ctl.first_obs_mode_u_ts = 0
        ctl.reader().ann.sticky_mode_u = True
    return h


def _make_shardstore(n_threads: int, params=None, forced_mode=None,
                     **kw) -> SubstrateBase:
    """The mesh-sharded MVStore (`core/shardstore.ShardStoreHandle`).

    `n_shards` / `span` pick the partitioning; `forced_mode` mirrors the
    mvstore factory (the shards share ONE controller, so the pin applies
    store-wide)."""
    from repro.configs.paper_stm import MultiverseParams
    from repro.core.shardstore import ShardStoreHandle

    if "ring_slots" in kw:
        from repro.configs.base import MVStoreConfig
        kw.setdefault("cfg", MVStoreConfig(ring_slots=kw.pop("ring_slots")))
    if forced_mode == "Q":
        params = dataclasses.replace(params or MultiverseParams(),
                                     k2=1 << 30, k3=1 << 30)
    h = ShardStoreHandle(n_threads, params=params, **kw)
    if forced_mode == "U":
        ctl = h.controller
        ctl.mode_counter = 2                      # Q -> QtoU -> U
        ctl.stats["mode_transitions"] += 2
        ctl.first_obs_mode_u_ts = 0
        ctl.reader().ann.sticky_mode_u = True
    return h


def _register_builtins() -> None:
    from repro.core.baselines import BASELINES

    register_backend("multiverse", _make_multiverse)
    for name, cls in BASELINES.items():
        register_backend(name, _make_baseline(cls, name))
    register_backend("mvstore", _make_mvstore)
    register_backend("shardstore", _make_shardstore)


_register_builtins()
