"""Baseline STMs the paper compares against (SS5/SS6), as ``TMPolicy``s.

  TL2     — commit-time locking, buffered writes, GV-style global clock.
  DCTL    — encounter-time locking, in-place writes, deferred clock
            (incremented by aborts), irrevocable fallback after N aborts.
  NOrec   — single global seqlock, buffered writes, value validation.
  TinySTM — encounter-time locking + snapshot (timestamp) extension.

Each baseline is a policy object over ``repro.core.engine`` — the shared
``TransactionEngine`` owns the heap, clock, lock table, descriptors and
abort/alloc bookkeeping, so what remains here is exactly the algorithmic
difference: the read/write access rules and the commit pipeline.  All
read-set revalidation routes through ``engine.revalidate`` (scalar loop
below ``BULK_MIN`` reads, vectorized bulk gather above it).

None of these keep versions: a long read-only transaction aborts whenever
a concurrent commit advances a lock version past its read clock — the
behavior Multiverse's versioned path removes (paper Figs. 1/6/7).
"""
from __future__ import annotations

import threading
from typing import Any

from repro.core.clock import AtomicInt
from repro.core.engine import (
    PolicyBase,
    TransactionEngine,
    V_EQ,
    V_LE,
    V_LT,
)
from repro.core.engine import bulkread as B
from repro.core.engine import commit as C
from repro.core.engine import validation as V
from repro.reliability import faultpoints as FP


# ---------------------------------------------------------------------------
# TL2
# ---------------------------------------------------------------------------


class TL2Policy(PolicyBase):
    """Deferred (commit-time) locking, buffered writes, GV4-style clock."""

    name = "tl2"
    validate_mode = V_LE
    group_commit = "buffered"     # CommitBatcher: claim+validate+scatter+stamp

    def read(self, eng, d, addr: int) -> Any:
        if addr in d.write_map:
            return d.write_map[addr]
        idx = eng.locks.index(addr)
        st1 = eng.locks.read(idx)
        data = eng.heap[addr]
        st2 = eng.locks.read(idx)
        if st1.locked or st2.locked or st1.version != st2.version or \
                st1.version > d.r_clock:
            eng.abort_txn(d)
        d.read_set.append((idx, st1.version))
        return data

    def read_bulk(self, eng, d, addrs) -> Any:
        # buffered writes make the overlay ambiguous — the rare
        # read-own-writes batch takes the exact scalar loop instead
        if d.write_map:
            return [self.read(eng, d, int(a)) for a in addrs]
        vals, ok = B.bulk_read_lockver(eng, d, addrs, inclusive=True)
        return B.finish_with_scalar(eng, d, addrs, vals, ok, self.read)

    def write(self, eng, d, addr: int, value: Any) -> None:
        d.read_only = False
        d.write_map[addr] = value

    def write_bulk(self, eng, d, addrs, values) -> None:
        d.read_only = False
        d.write_map.update(zip((int(a) for a in addrs), values))

    def commit_update(self, eng, d) -> None:
        locked = C.acquire_write_locks(eng, d)    # aborts on conflict
        try:
            # inside the guard: an injected FaultError here must release
            # the claim like any other mid-commit exception
            if FP.ACTIVE is not None:
                FP.fire("pre_clock_tick", d.tid)
            wv = eng.clock.increment()            # GV4-ish: one fetch-add
            if not eng.revalidate(d):
                eng.abort_txn(d)
            C.write_back(eng, d)
            if FP.ACTIVE is not None:
                FP.fire("pre_release", d.tid)
            C.release_locks(eng, locked, wv)
            locked.clear()
        except BaseException as e:
            # abort or ANY mid-commit exception: commit-time locks are
            # invisible to rollback (TL2 holds none at encounter time),
            # so they must be released here or they leak forever — EXCEPT
            # a simulated crash, which must leave the crash image (held
            # locks, partial heap) intact for recovery to find
            if not FP.is_simulated_crash(e):
                if d.publish_started:
                    # the commit record is written and the buffered data
                    # already scattered (no undo exists to take it back):
                    # the decision stands, so finish publication at wv
                    # before letting the fault propagate
                    C.release_locks(eng, locked, wv)
                    d.stats["commits"] += 1
                    d.active = False
                    self.on_finish(eng, d)
                else:
                    C.release_locks(eng, locked)
            raise


# ---------------------------------------------------------------------------
# DCTL
# ---------------------------------------------------------------------------


class DCTLPolicy(PolicyBase):
    """Encounter-time locking, in-place writes, deferred clock (bumped on
    abort), single-token irrevocable mode after ``irrevocable_after``
    aborts (the paper uses 100)."""

    name = "dctl"
    validate_mode = V_LT
    group_commit = "encounter"    # CommitBatcher: fused validate + release

    def __init__(self, irrevocable_after: int = 100):
        self.irrevocable_after = irrevocable_after
        self._irrevocable_token = threading.Lock()

    def on_begin(self, eng, d) -> None:
        if d.attempts >= self.irrevocable_after and not d.irrevocable:
            self._irrevocable_token.acquire()
            d.irrevocable = True
        d.r_clock = eng.clock.load()

    def read(self, eng, d, addr: int) -> Any:
        idx = eng.locks.index(addr)
        if addr in d.undo or (d.irrevocable and self._lock_for(eng, d, idx)):
            return eng.heap[addr]
        data = eng.heap[addr]
        st = eng.locks.read(idx)
        if not eng.locks.validate(st, d.r_clock, d.tid):
            eng.abort_txn(d)
        d.read_set.append((idx, st.version))
        return data

    def read_bulk(self, eng, d, addrs) -> Any:
        # irrevocable transactions lock even their reads — scalar only
        if d.irrevocable:
            return [self.read(eng, d, int(a)) for a in addrs]
        vals, ok = B.bulk_read_lockver(eng, d, addrs, inclusive=False)
        return B.finish_with_scalar(eng, d, addrs, vals, ok, self.read)

    def _lock_for(self, eng, d, idx: int) -> bool:
        """Irrevocable path: claim locks even for reads; spin, never abort."""
        while True:
            st = eng.locks.read(idx)
            if st.locked and st.tid == d.tid:
                return True
            if not st.locked and eng.locks.try_lock(idx, st, d.tid):
                d.locked_idxs.add(idx)           # remember to release
                return True

    def write(self, eng, d, addr: int, value: Any) -> None:
        d.read_only = False
        idx = eng.locks.index(addr)
        if d.irrevocable:
            self._lock_for(eng, d, idx)
        else:
            st = eng.locks.read(idx)
            if not eng.locks.validate(st, d.r_clock, d.tid):
                # version-blocked but conflict-free word: snapshot-extend
                # past the deferred clock instead of aborting (the abort
                # would replay to exactly this state — commit.py note)
                if st.locked or st.flag or not C.extend_snapshot(eng, d):
                    eng.abort_txn(d)
                st = eng.locks.read(idx)
                if not eng.locks.validate(st, d.r_clock, d.tid):
                    eng.abort_txn(d)
            if not eng.locks.try_lock(idx, st, d.tid):
                eng.abort_txn(d)
            d.locked_idxs.add(idx)
        if addr not in d.undo:
            d.undo[addr] = eng.heap[addr]
        eng.heap[addr] = value

    def write_bulk(self, eng, d, addrs, values) -> None:
        """Encounter-time batched write: validate + claim every lock in
        ONE ``try_lock_bulk`` sweep (version checked under the same
        stripes as the claim — the atomic validate-then-lock), then one
        undo gather and one heap scatter.  A conflicting batch aborts
        with NOTHING acquired or written, where the scalar loop would
        have locked and written a prefix first — the same end state
        (abort, deferred-clock bump) without the partial work to roll
        back.  Irrevocable transactions and sub-``BULK_MIN`` batches
        take the exact scalar loop.
        """
        from repro.core.engine.validation import BULK_MIN
        try_bulk = getattr(eng.locks, "try_lock_bulk", None)
        if d.irrevocable or try_bulk is None or addrs.size < BULK_MIN:
            for a, v in zip(addrs, values):
                self.write(eng, d, int(a), v)
            return
        d.read_only = False
        addrs, values = C.dedup_last_wins(addrs, values)
        idxs = eng.locks.index_bulk(addrs)
        if FP.ACTIVE is not None:
            FP.fire("pre_claim", d.tid)
        new = try_bulk(idxs, d.tid, max_version=d.r_clock)
        if new is None:
            new = C.extend_and_relock(eng, d, idxs)
        if new is None:
            eng.abort_txn(d)
        d.locked_idxs.update(new.tolist())
        if FP.ACTIVE is not None:
            FP.fire("post_claim", d.tid)
        C.merge_undo(eng, d, addrs)
        if FP.ACTIVE is not None:
            FP.fire("pre_scatter", d.tid)
        C.heap_scatter(eng.heap, addrs, values, tid=d.tid)
        if FP.ACTIVE is not None:
            FP.fire("post_scatter", d.tid)

    def rollback(self, eng, d) -> None:
        C.rollback_inplace(eng, d)               # undo + deferred-clock bump

    def commit_update(self, eng, d) -> None:
        if not d.irrevocable and not eng.revalidate(d):
            eng.abort_txn(d)
        if FP.ACTIVE is not None:
            FP.fire("pre_clock_tick", d.tid)
        cv = eng.clock.load()
        # encounter-time commit record: the heap already holds the final
        # values, so past this point recovery rolls FORWARD (release at a
        # fresh tick) rather than restoring the undo log; the durable
        # DECIDE (redo image gathered from the locked heap words) lands
        # at the same instant
        C.wal_log_decide_encounter(eng, d)
        d.publish_started = True
        if FP.ACTIVE is not None:
            try:
                FP.fire("pre_release", d.tid)
            except BaseException as e:
                if not FP.is_simulated_crash(e):
                    # decided: an injected recoverable error cannot abort
                    # any more — finish the release so the outer abort
                    # path (a no-op on an inactive descriptor) cannot
                    # restore the undo log over committed data
                    C.release_locks(eng, d.locked_idxs, cv)
                    d.undo.clear()
                    d.stats["commits"] += 1
                    d.active = False
                    self.on_finish(eng, d)
                raise
        C.release_locks(eng, d.locked_idxs, cv)

    def on_finish(self, eng, d) -> None:
        if d.irrevocable:
            d.irrevocable = False
            self._irrevocable_token.release()
        d.attempts = 0


# ---------------------------------------------------------------------------
# NOrec
# ---------------------------------------------------------------------------


class NOrecPolicy(PolicyBase):
    """No ownership records: one global seqlock + value validation."""

    name = "norec"

    def __init__(self):
        self.seq = AtomicInt(0)

    def on_begin(self, eng, d) -> None:
        while True:
            s = self.seq.load()
            if s % 2 == 0:
                d.r_clock = s
                break

    def _validate_values(self, eng, d) -> int:
        while True:
            s = self.seq.load()
            if s % 2 == 1:
                continue
            if not V.validate_values(eng.heap, d.read_vals):
                eng.abort_txn(d)
            if self.seq.load() == s:
                return s

    def read(self, eng, d, addr: int) -> Any:
        if addr in d.write_map:
            return d.write_map[addr]
        val = eng.heap[addr]
        while self.seq.load() != d.r_clock:
            d.r_clock = self._validate_values(eng, d)
            val = eng.heap[addr]
        d.read_vals.append((addr, val))
        return val

    def read_bulk(self, eng, d, addrs) -> Any:
        """Batched NOrec read: gather under an unchanged seqlock.

        The scalar read's invariant — "value observed while ``seq`` was
        even and equal to ``r_clock``" — holds for the whole batch when
        the seqlock is unchanged across the gather (writers bump it odd
        before touching the heap), so one gather + two seq loads replace
        N validate-and-reread loops.
        """
        if d.write_map:
            return [self.read(eng, d, int(a)) for a in addrs]
        while True:
            if self.seq.load() != d.r_clock:
                d.r_clock = self._validate_values(eng, d)
            vals = B.heap_gather(eng.heap, addrs)
            if self.seq.load() == d.r_clock:
                break
        pairs = zip((int(a) for a in addrs), vals)
        if d.dedup_read_set:
            # traversal dedup, value-log flavor: within one NOrec txn an
            # address's observed value can never legally change (value
            # validation would have aborted), so keeping the first
            # (addr, value) entry is exact
            seen = d.read_set_seen
            rv = d.read_vals
            for p in pairs:
                if p[0] not in seen:
                    seen.add(p[0])
                    rv.append(p)
        else:
            d.read_vals.extend(pairs)
        return vals

    def write(self, eng, d, addr: int, value: Any) -> None:
        d.read_only = False
        d.write_map[addr] = value

    def write_bulk(self, eng, d, addrs, values) -> None:
        d.read_only = False
        d.write_map.update(zip((int(a) for a in addrs), values))

    def commit_update(self, eng, d) -> None:
        while True:
            s = d.r_clock
            if self.seq.cas(s, s + 1):
                break
            d.r_clock = self._validate_values(eng, d)
        if not V.validate_values(eng.heap, d.read_vals):
            self.seq.store(s + 2)
            eng.abort_txn(d)
        C.write_back(eng, d)
        self.seq.store(s + 2)

    def validate(self, eng, d) -> bool:
        return V.validate_values(eng.heap, d.read_vals)


# ---------------------------------------------------------------------------
# TinySTM (encounter-time locking + snapshot extension)
# ---------------------------------------------------------------------------


class TinySTMPolicy(DCTLPolicy):
    """TinySTM-style: DCTL's ETL write path, but the clock advances on every
    commit and readers EXTEND their snapshot instead of aborting when they
    hit a newer-but-consistent version."""

    name = "tinystm"
    validate_mode = V_EQ

    def __init__(self):
        super().__init__(irrevocable_after=1 << 30)  # no irrevocable mode

    def read(self, eng, d, addr: int) -> Any:
        if addr in d.undo:
            return eng.heap[addr]
        idx = eng.locks.index(addr)
        while True:
            st = eng.locks.read(idx)
            if st.locked:
                if st.tid != d.tid:
                    eng.abort_txn(d)
                # lock held by THIS txn (a written address sharing the
                # lock index): the word is stable under our own lock —
                # spinning on it would self-livelock forever.  V_EQ
                # revalidation passes while we still hold it.
                d.read_set.append((idx, st.version))
                return eng.heap[addr]
            data = eng.heap[addr]
            st2 = eng.locks.read(idx)
            if st2.locked or st2.version != st.version:
                continue                      # raced a writer: reread
            if st.version > d.r_clock:
                # snapshot extension: revalidate at the new clock, then
                # loop to re-read the value under the extended snapshot
                now = eng.clock.load()
                if not eng.revalidate(d):
                    eng.abort_txn(d)
                d.r_clock = now
                continue
            d.read_set.append((idx, st.version))
            return data

    def read_bulk(self, eng, d, addrs) -> Any:
        # commit-bumped clock: versions AT r_clock are still consistent;
        # entries needing snapshot extension fall back to the scalar read
        vals, ok = B.bulk_read_lockver(eng, d, addrs, inclusive=True)
        return B.finish_with_scalar(eng, d, addrs, vals, ok, self.read)

    def commit_update(self, eng, d) -> None:
        if not eng.revalidate(d):
            eng.abort_txn(d)
        if FP.ACTIVE is not None:
            FP.fire("pre_clock_tick", d.tid)
        wv = eng.clock.increment()
        C.wal_log_decide_encounter(eng, d)
        d.publish_started = True
        if FP.ACTIVE is not None:
            try:
                FP.fire("pre_release", d.tid)
            except BaseException as e:
                if not FP.is_simulated_crash(e):
                    # decided: roll forward (see DCTL.commit_update)
                    C.release_locks(eng, d.locked_idxs, wv)
                    d.undo.clear()
                    d.stats["commits"] += 1
                    d.active = False
                    self.on_finish(eng, d)
                raise
        C.release_locks(eng, d.locked_idxs, wv)


# ---------------------------------------------------------------------------
# engine-backed classes (historical constructors)
# ---------------------------------------------------------------------------


class TL2(TransactionEngine):
    def __init__(self, n_threads: int, lock_bits: int = 16, heap=None):
        super().__init__(TL2Policy(), n_threads, lock_bits=lock_bits,
                         heap=heap)
        self.name = type(self).__name__


class DCTL(TransactionEngine):
    def __init__(self, n_threads: int, lock_bits: int = 16,
                 irrevocable_after: int = 100, heap=None):
        super().__init__(DCTLPolicy(irrevocable_after), n_threads,
                         lock_bits=lock_bits, heap=heap)
        self.name = type(self).__name__


class NOrec(TransactionEngine):
    def __init__(self, n_threads: int, lock_bits: int = 16, heap=None):
        super().__init__(NOrecPolicy(), n_threads, lock_bits=lock_bits,
                         heap=heap)
        self.name = type(self).__name__

    @property
    def seq(self) -> AtomicInt:
        return self.policy.seq


class TinySTM(TransactionEngine):
    def __init__(self, n_threads: int, lock_bits: int = 16, heap=None):
        super().__init__(TinySTMPolicy(), n_threads, lock_bits=lock_bits,
                         heap=heap)
        self.name = type(self).__name__


BASELINES = {"tl2": TL2, "dctl": DCTL, "norec": NOrec, "tinystm": TinySTM}
POLICIES = {"tl2": TL2Policy, "dctl": DCTLPolicy, "norec": NOrecPolicy,
            "tinystm": TinySTMPolicy}
