"""Baseline STMs the paper compares against (SS5/SS6), on the same harness.

  TL2     — commit-time locking, buffered writes, GV-style global clock.
  DCTL    — encounter-time locking, in-place writes, deferred clock
            (incremented by aborts), irrevocable fallback after N aborts.
  NOrec   — single global seqlock, buffered writes, value validation.
  TinySTM — encounter-time locking + snapshot (timestamp) extension.

All share TMBase's heap and the `run(tm, fn, tid)` retry loop, so every
benchmark data structure runs unmodified on every TM.  None of these keep
versions: a long read-only transaction aborts whenever a concurrent commit
advances a lock version past its read clock — the behavior Multiverse's
versioned path removes (paper Figs. 1/6/7).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

from repro.core.clock import AtomicInt, GlobalClock
from repro.core.locks import LockState, LockTable
from repro.core.stats_schema import base_stats
from repro.core.stm import AbortTx, TMBase


class _Ctx:
    __slots__ = ("tid", "r_clock", "read_set", "write_map", "undo",
                 "attempts", "irrevocable", "stats", "read_vals",
                 "read_only", "active", "alloc_log")

    def __init__(self, tid: int):
        self.tid = tid
        self.attempts = 0
        self.irrevocable = False
        self.active = False
        self.stats = {"commits": 0, "aborts": 0, "versioned_commits": 0,
                      "ro_commits": 0, "mode_cas": 0}
        self.reset()

    def reset(self):
        self.r_clock = 0
        self.read_set: List[tuple] = []
        self.write_map: Dict[int, Any] = {}
        self.undo: Dict[int, Any] = {}
        self.read_vals: List[tuple] = []
        self.read_only = True
        self.alloc_log: List[tuple] = []


class _BaselineTM(TMBase):
    def __init__(self, n_threads: int, lock_bits: int = 16):
        super().__init__(n_threads)
        self.clock = GlobalClock(0)
        self.locks = LockTable(lock_bits)
        self._ctxs = [_Ctx(t) for t in range(n_threads)]

    def ctx(self, tid):
        return self._ctxs[tid]

    def begin(self, tid: int):
        ctx = self._ctxs[tid]
        ctx.reset()
        ctx.active = True
        ctx.r_clock = self.clock.load()
        return _BTx(self, ctx)

    def tx_alloc(self, ctx, n, init=None):
        base = self.alloc(n, init)
        ctx.alloc_log.append((base, n))
        return base

    def stats(self) -> Dict[str, object]:
        """Normalized schema: counters a baseline never touches stay 0
        (no versioning, no modes), so every consumer sees one key set."""
        out = base_stats(backend=self.name, mode="-")
        for c in self._ctxs:
            for k in ("commits", "aborts", "ro_commits"):
                out[k] += c.stats[k]
        return out

    def _abort(self, ctx):
        # free txn-local allocations (nobody else can have seen them: the
        # addresses were only reachable via this txn's unpublished writes)
        for base, n in ctx.alloc_log:
            for i in range(n):
                self._heap[base + i] = None
        ctx.alloc_log.clear()
        ctx.stats["aborts"] += 1
        ctx.attempts += 1
        ctx.active = False
        raise AbortTx()


class _BTx:
    __slots__ = ("_tm", "_ctx")

    def __init__(self, tm, ctx):
        self._tm = tm
        self._ctx = ctx

    def read(self, addr):
        return self._tm.tm_read(self._ctx, addr)

    def write(self, addr, value):
        self._tm.tm_write(self._ctx, addr, value)

    def alloc(self, n, init=None):
        return self._tm.tx_alloc(self._ctx, n, init)

    @property
    def read_count(self):
        return len(self._ctx.read_set) + len(self._ctx.read_vals)


# ---------------------------------------------------------------------------
# TL2
# ---------------------------------------------------------------------------


class TL2(_BaselineTM):
    """Deferred (commit-time) locking, buffered writes, GV4-style clock."""

    def tm_read(self, ctx, addr):
        if addr in ctx.write_map:
            return ctx.write_map[addr]
        idx = self.locks.index(addr)
        st1 = self.locks.read(idx)
        data = self._heap[addr]
        st2 = self.locks.read(idx)
        if st1.locked or st2.locked or st1.version != st2.version or \
                st1.version > ctx.r_clock:
            self._abort(ctx)
        ctx.read_set.append((idx, st1.version))
        return data

    def tm_write(self, ctx, addr, value):
        ctx.read_only = False
        ctx.write_map[addr] = value

    def _try_commit(self, ctx):
        if ctx.read_only:
            ctx.stats["ro_commits"] += 1
            ctx.attempts = 0
            return
        locked: List[int] = []
        try:
            for addr in ctx.write_map:
                idx = self.locks.index(addr)
                st = self.locks.read(idx)
                if not self.locks.try_lock(idx, st, ctx.tid):
                    self._abort(ctx)
                if idx not in locked:
                    locked.append(idx)
            wv = self.clock.increment()          # GV4-ish: one fetch-add
            for idx, seen in ctx.read_set:
                st = self.locks.read(idx)
                if (st.locked and st.tid != ctx.tid) or st.version > \
                        ctx.r_clock:
                    self._abort(ctx)
            for addr, value in ctx.write_map.items():
                self._heap[addr] = value
            for idx in locked:
                self.locks.unlock(idx, wv)
            locked.clear()
            ctx.stats["commits"] += 1
            ctx.attempts = 0
        finally:
            for idx in locked:
                self.locks.unlock(idx)


# ---------------------------------------------------------------------------
# DCTL
# ---------------------------------------------------------------------------


class DCTL(_BaselineTM):
    """Encounter-time locking, in-place writes, deferred clock (bumped on
    abort), single-token irrevocable mode after ``irrevocable_after``
    aborts (the paper uses 100)."""

    def __init__(self, n_threads, lock_bits: int = 16,
                 irrevocable_after: int = 100):
        super().__init__(n_threads, lock_bits)
        self.irrevocable_after = irrevocable_after
        self._irrevocable_token = threading.Lock()

    def begin(self, tid):
        ctx = self._ctxs[tid]
        ctx.reset()
        ctx.active = True
        if ctx.attempts >= self.irrevocable_after and not ctx.irrevocable:
            self._irrevocable_token.acquire()
            ctx.irrevocable = True
        ctx.r_clock = self.clock.load()
        return _BTx(self, ctx)

    def tm_read(self, ctx, addr):
        idx = self.locks.index(addr)
        if addr in ctx.undo or (ctx.irrevocable and self._lock_for(ctx,
                                                                   idx)):
            return self._heap[addr]
        data = self._heap[addr]
        st = self.locks.read(idx)
        if not self.locks.validate(st, ctx.r_clock, ctx.tid):
            self._rollback_abort(ctx)
        ctx.read_set.append((idx, st.version))
        return data

    def _lock_for(self, ctx, idx) -> bool:
        """Irrevocable path: claim locks even for reads; spin, never abort."""
        while True:
            st = self.locks.read(idx)
            if st.locked and st.tid == ctx.tid:
                return True
            if not st.locked and self.locks.try_lock(idx, st, ctx.tid):
                ctx.write_map[idx] = True        # remember to release
                return True

    def tm_write(self, ctx, addr, value):
        ctx.read_only = False
        idx = self.locks.index(addr)
        if ctx.irrevocable:
            self._lock_for(ctx, idx)
        else:
            st = self.locks.read(idx)
            if not self.locks.validate(st, ctx.r_clock, ctx.tid):
                self._rollback_abort(ctx)
            if not self.locks.try_lock(idx, st, ctx.tid):
                self._rollback_abort(ctx)
            ctx.write_map[idx] = True
        if addr not in ctx.undo:
            ctx.undo[addr] = self._heap[addr]
        self._heap[addr] = value

    def _rollback_abort(self, ctx):
        for addr, old in ctx.undo.items():
            self._heap[addr] = old
        nxt = self.clock.increment()             # deferred clock: abort bump
        for idx in ctx.write_map:
            self.locks.unlock(idx, nxt)
        self._abort(ctx)

    def _try_commit(self, ctx):
        if ctx.read_only and not ctx.write_map:
            ctx.stats["ro_commits"] += 1
            self._finish(ctx)
            return
        if not ctx.irrevocable:
            for idx, seen in ctx.read_set:
                st = self.locks.read(idx)
                if not self.locks.validate(st, ctx.r_clock, ctx.tid):
                    self._rollback_abort(ctx)
        cc = self.clock.load()
        for idx in ctx.write_map:
            self.locks.unlock(idx, cc)
        ctx.stats["commits"] += 1
        self._finish(ctx)

    def _finish(self, ctx):
        if ctx.irrevocable:
            ctx.irrevocable = False
            self._irrevocable_token.release()
        ctx.attempts = 0


# ---------------------------------------------------------------------------
# NOrec
# ---------------------------------------------------------------------------


class NOrec(_BaselineTM):
    """No ownership records: one global seqlock + value validation."""

    def __init__(self, n_threads, lock_bits: int = 16):
        super().__init__(n_threads, lock_bits)
        self.seq = AtomicInt(0)

    def begin(self, tid):
        ctx = self._ctxs[tid]
        ctx.reset()
        ctx.active = True
        while True:
            s = self.seq.load()
            if s % 2 == 0:
                ctx.r_clock = s
                break
        return _BTx(self, ctx)

    def _validate_values(self, ctx) -> int:
        while True:
            s = self.seq.load()
            if s % 2 == 1:
                continue
            for addr, val in ctx.read_vals:
                if self._heap[addr] != val:
                    self._abort(ctx)
            if self.seq.load() == s:
                return s

    def tm_read(self, ctx, addr):
        if addr in ctx.write_map:
            return ctx.write_map[addr]
        val = self._heap[addr]
        while self.seq.load() != ctx.r_clock:
            ctx.r_clock = self._validate_values(ctx)
            val = self._heap[addr]
        ctx.read_vals.append((addr, val))
        return val

    def tm_write(self, ctx, addr, value):
        ctx.read_only = False
        ctx.write_map[addr] = value

    def _try_commit(self, ctx):
        if ctx.read_only:
            ctx.stats["ro_commits"] += 1
            ctx.attempts = 0
            return
        while True:
            s = ctx.r_clock
            if self.seq.cas(s, s + 1):
                break
            ctx.r_clock = self._validate_values(ctx)
        for addr, val in ctx.read_vals:
            if self._heap[addr] != val:
                self.seq.store(s + 2)
                self._abort(ctx)
        for addr, value in ctx.write_map.items():
            self._heap[addr] = value
        self.seq.store(s + 2)
        ctx.stats["commits"] += 1
        ctx.attempts = 0


# ---------------------------------------------------------------------------
# TinySTM (encounter-time locking + snapshot extension)
# ---------------------------------------------------------------------------


class TinySTM(DCTL):
    """TinySTM-style: DCTL's ETL write path, but the clock advances on every
    commit and readers EXTEND their snapshot instead of aborting when they
    hit a newer-but-consistent version."""

    def __init__(self, n_threads, lock_bits: int = 16):
        super().__init__(n_threads, lock_bits,
                         irrevocable_after=1 << 30)   # no irrevocable mode

    def tm_read(self, ctx, addr):
        if addr in ctx.undo:
            return self._heap[addr]
        idx = self.locks.index(addr)
        while True:
            st = self.locks.read(idx)
            if st.locked and st.tid != ctx.tid:
                self._rollback_abort(ctx)
            data = self._heap[addr]
            st2 = self.locks.read(idx)
            if st2.locked or st2.version != st.version:
                continue                      # raced a writer: reread
            if st.version > ctx.r_clock:
                # snapshot extension: revalidate at the new clock, then
                # loop to re-read the value under the extended snapshot
                now = self.clock.load()
                for i2, seen in ctx.read_set:
                    st3 = self.locks.read(i2)
                    if (st3.locked and st3.tid != ctx.tid) or \
                            st3.version != seen:
                        self._rollback_abort(ctx)
                ctx.r_clock = now
                continue
            ctx.read_set.append((idx, st.version))
            return data

    def _try_commit(self, ctx):
        if ctx.read_only and not ctx.write_map:
            ctx.stats["ro_commits"] += 1
            ctx.attempts = 0
            return
        for idx, seen in ctx.read_set:
            st = self.locks.read(idx)
            if (st.locked and st.tid != ctx.tid) or st.version != seen:
                self._rollback_abort(ctx)
        cc = self.clock.increment()
        for idx in ctx.write_map:
            self.locks.unlock(idx, cc)
        ctx.stats["commits"] += 1
        ctx.attempts = 0


BASELINES = {"tl2": TL2, "dctl": DCTL, "norec": NOrec, "tinystm": TinySTM}
