"""Multiverse STM — the paper's Algorithms 1-5 as a ``TMPolicy``.

Word-based opaque STM with dynamic multiversioning:
  * unversioned path: DCTL-style (global clock, versioned locks,
    encounter-time locking, in-place writes, commit-time read revalidation,
    clock incremented by aborts);
  * versioned read-only path: version-list traversal with TBD blocking and
    deleted timestamps;
  * four TM modes on a monotone counter (Q, QtoU, U, UtoQ) with the
    Q->QtoU CAS open to workers and all other transitions centralized in
    the background thread, which also unversions VLT buckets in Mode Q
    using the L/P commit-delta heuristic and drives EBR.

Since the engine refactor the begin/read/write/commit scaffolding lives
in ``repro.core.engine`` — this module contains only what makes
Multiverse Multiverse (``MultiversePolicy``), plus the ``Multiverse``
engine subclass that exposes the historical attribute surface
(``tm.vlt``, ``tm.mode_counter``, ``tm.announce``, ...) instrumentation
and benchmarks rely on.  Commit-time read-set revalidation routes through
``engine.revalidate``, which switches to the vectorized bulk validator
(numpy gather on CPU, ``kernels/validate.py`` on TPU) for large read
sets.

The user API is ``repro.api`` (``run``/``@atomic``/``tm.txn()``); the
module-level ``run`` here remains as a deprecation shim.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.configs.paper_stm import MultiverseParams
from repro.core import heuristics as heur
from repro.core import modes as M
from repro.core.bloom import BloomTable
from repro.core.clock import AtomicInt
from repro.core.engine import bulkread as B
from repro.core.engine import commit as C
from repro.core.ebr import EBR, TxRetireBuffer
from repro.core.engine import (
    AbortTx,
    BULK_MIN,
    MaxRetriesExceeded,
    PolicyBase,
    TMBase,
    TransactionEngine,
)
from repro.core.engine.engine import _Tx  # noqa: F401 (historical export)
from repro.core.vlt import DELETED_TS, VLT, VersionList, VListNode
from repro.reliability import faultpoints as FP

__all__ = ["AbortTx", "MaxRetriesExceeded", "Multiverse",
           "MultiversePolicy", "TMBase", "run"]


class MultiversePolicy(PolicyBase):
    name = "multiverse"

    def __init__(self, params: Optional[MultiverseParams] = None,
                 start_bg: bool = True):
        self.params = params or MultiverseParams()
        self._start_bg = start_bg

    # ------------------------------------------------------------------
    # engine wiring
    # ------------------------------------------------------------------
    def setup(self, eng) -> None:
        bits = self.params.lock_table_bits
        self.bloom = BloomTable(bits, self.params.bloom_bits)
        self.vlt = VLT(bits)
        self.mode_counter = AtomicInt(0)         # mode = counter & 3
        self.first_obs_mode_u_ts = AtomicInt(-1)
        self.min_mode_u_reads = heur.MinModeUReadCount()
        self.ebr = EBR(eng.n_threads)
        self.announce = [heur.ThreadAnnouncement()
                         for _ in range(eng.n_threads)]
        self.unversion_heur = heur.UnversionThreshold(self.params)
        self._retire_bufs = [TxRetireBuffer(self.ebr)
                             for _ in range(eng.n_threads)]
        self.stats_unversioned_buckets = 0
        self.stats_mode_transitions = 0
        self.stats_version_gather_hits = 0   # words resolved by the
        #                                      packed-VLT bulk gather
        self._stop = threading.Event()
        self._bg: Optional[threading.Thread] = None
        if self._start_bg:
            self._bg = threading.Thread(target=self._bg_thread,
                                        args=(eng,), daemon=True)
            self._bg.start()

    # ------------------------------------------------------------------
    # transaction lifecycle (Alg. 1)
    # ------------------------------------------------------------------
    def on_begin(self, eng, d) -> None:
        ann = self.announce[d.tid]
        # announce-then-verify: publish (counter, active) BEFORE trusting
        # the counter, else the background thread can advance the mode in
        # the window between our load and our announcement and a local-
        # Mode-Q writer would run unversioned under global Mode U —
        # breaking the invariant Mode-U readers rely on (paper SS3.4 fn.1).
        while True:
            cnt = self.mode_counter.load()
            d.local_mode_counter = cnt
            ann.local_mode_counter = cnt
            d.active = True
            if self.mode_counter.load() == cnt:
                break
            d.active = False
        d.local_mode = M.get_mode(cnt)
        d.r_clock = eng.clock.load()
        if d.versioned and d.initial_versioned_ts is None:
            d.initial_versioned_ts = d.r_clock
        ann.active_versioned = d.versioned
        self.ebr.pin(d.tid)

    def commit_read_only(self, eng, d) -> None:
        ann = self.announce[d.tid]
        if d.versioned:
            delta = eng.clock.load() - (d.initial_versioned_ts or 0)
            ann.commit_ts_delta = delta
            if d.local_mode == M.MODE_U:
                self.min_mode_u_reads.update(d.read_cnt)
            d.stats["versioned_commits"] += 1
        if ann.sticky_mode_u and heur.sticky_cleared(
                self.params, ann, d.read_cnt):
            ann.sticky_mode_u = False

    def commit_update(self, eng, d) -> None:
        # revalidate the read set: scalar loop for small read sets, the
        # vectorized bulk path (one lock-table gather) for large ones
        if not eng.revalidate(d):
            eng.abort_txn(d)
        if FP.ACTIVE is not None:
            FP.fire("pre_clock_tick", d.tid)
        commit_clock = eng.clock.load()
        # commit record: versioned readers can observe cleared-TBD
        # versions the instant _publish_versions runs, and the in-place
        # heap already holds the final values — from here a crash must
        # roll FORWARD (finish publish + release), never back; the
        # durable DECIDE lands at the same instant
        C.wal_log_decide_encounter(eng, d)
        d.publish_started = True
        if d.versioned_write_set:
            self._publish_versions(eng, d, commit_clock)
        if FP.ACTIVE is not None:
            try:
                FP.fire("pre_release", d.tid)
            except BaseException as e:
                if not FP.is_simulated_crash(e):
                    # decided: versions are published, so an injected
                    # recoverable error must complete the commit — an
                    # undo-log rollback here would fork heap vs. VLT
                    C.release_locks(eng, d.locked_idxs, commit_clock)
                    self._retire_bufs[d.tid].commit()
                    d.undo.clear()
                    d.versioned_write_set.clear()
                    d.stats["commits"] += 1
                    d.active = False
                    self.on_finish(eng, d)
                raise
        # release write locks at the commit clock: the DEDUPED index set
        # both write paths maintain (two addresses colliding into one
        # lock word must release it exactly once — a second per-address
        # unlock could stomp a lock another writer claimed in between),
        # one bulk sweep at large write sets (engine/commit.py
        # normalization note)
        C.release_locks(eng, d.locked_idxs, commit_clock)
        self._retire_bufs[d.tid].commit()

    def _publish_versions(self, eng, d, commit_clock: int) -> None:
        """Remove TBD marks (publishing versions at the commit clock) and
        refresh the packed-VLT mirror while the address locks are still
        held (the mirror's writer discipline).  Large versioned write
        sets refresh the mirror in ONE ``publish_bulk`` sweep — per
        unique row a single seqlock bracket around a vectorized slot
        shift — instead of a per-address publish dance."""
        vws = d.versioned_write_set
        for addr, (vlist, node) in vws.items():
            node.timestamp = commit_clock
            node.tbd = False
        if len(vws) >= BULK_MIN and \
                getattr(eng.locks, "index_bulk", None) is not None:
            addrs = np.fromiter(vws.keys(), np.int64, len(vws))
            self.vlt.mirror.publish_bulk(
                eng.locks.index_bulk(addrs), addrs, commit_clock,
                [node.data for (_vl, node) in vws.values()])
        else:
            for addr, (vlist, node) in vws.items():
                self.vlt.mirror.publish(eng.locks.index(addr), addr,
                                        commit_clock, node.data)

    def on_finish(self, eng, d) -> None:
        d.attempts = 0
        d.versioned = False
        d.initial_versioned_ts = None
        self.ebr.unpin(d.tid)

    def rollback(self, eng, d) -> None:
        # roll back versioned writes: deleted timestamp, UNLINK, retire.
        # We hold the address lock, and our node is necessarily still the
        # head (no one else can prepend), so unlinking is safe; without it
        # a reader pinned AFTER the grace period could still walk through
        # the freed node — a real use-after-free caught by the poison-bit
        # assertions (EXPERIMENTS.md SSDeviations).
        buf = self._retire_bufs[d.tid]
        for addr, (vlist, node) in d.versioned_write_set.items():
            node.timestamp = DELETED_TS
            node.tbd = False
            if vlist.head is node:
                vlist.head = node.older
            buf.retire_on_abort(node)
        buf.abort()
        # then the in-place writes: the shared encounter-time rollback —
        # one heap scatter at large undo logs, deduped-index release at
        # the bumped (deferred-clock) abort version
        C.rollback_inplace(eng, d)

    def on_abort(self, eng, d) -> None:
        if d.read_only:
            if heur.should_attempt_mode_cas(
                    self.params, versioned=d.versioned,
                    attempts=d.attempts, read_cnt=d.read_cnt,
                    min_mode_u_reads=self.min_mode_u_reads.get()):
                self._attempt_mode_cas(d)
            if not d.versioned and not d.no_versioning and \
                    heur.should_go_versioned(self.params, d.attempts):
                d.versioned = True
        d.attempts += 1
        self.ebr.unpin(d.tid)

    def on_retries_exhausted(self, eng, tid: int) -> None:
        # a capped operation must leave nothing behind: flush the retire
        # buffer (revoking commit-conditional retires, landing the abort-
        # conditional ones in EBR limbo) and make sure the thread is
        # unpinned so reclamation cannot stall on a dead transaction
        self._retire_bufs[tid].abort()
        self.ebr.unpin(tid)

    def _attempt_mode_cas(self, d) -> None:
        """Any local-Mode-Q txn may CAS Q -> QtoU (SS3.3.1)."""
        cnt = self.mode_counter.load()
        if M.get_mode(cnt) == M.MODE_Q:
            self.announce[d.tid].sticky_mode_u = True
            self.announce[d.tid].small_txn_read_cnt = None
            if self.mode_counter.cas(cnt, cnt + 1):
                d.stats["mode_cas"] += 1
                self.stats_mode_transitions += 1

    # ------------------------------------------------------------------
    # TM accesses (Alg. 3 / Alg. 4)
    # ------------------------------------------------------------------
    def write(self, eng, d, addr: int, value: Any) -> None:
        if d.versioned:
            # Only read-only transactions can be versioned (paper SS3.2.2).
            # A versioned txn that turns out to write must restart on the
            # unversioned path: its versioned reads were of the PAST and
            # cannot anchor writes to the present (mixing them is the
            # SI-writer path of SS3.5, which must be explicitly requested).
            # no_versioning is STICKY for this operation — otherwise the K1
            # heuristic re-promotes on the next abort and the write aborts
            # it again, forever (livelock).
            d.versioned = False
            d.no_versioning = True
            d.initial_versioned_ts = None
            eng.abort_txn(d)
        d.read_only = False
        idx = eng.locks.index(addr)
        st = eng.locks.read_wait_unflagged(idx)
        if not eng.locks.validate(st, d.r_clock, d.tid):
            # version-blocked but conflict-free word: snapshot-extend
            # past the deferred clock instead of aborting (the abort
            # would replay to exactly this state — commit.py note)
            if st.locked or not C.extend_snapshot(eng, d):
                eng.abort_txn(d)
            st = eng.locks.read_wait_unflagged(idx)
            if not eng.locks.validate(st, d.r_clock, d.tid):
                eng.abort_txn(d)
        if not eng.locks.try_lock(idx, st, d.tid):
            eng.abort_txn(d)
        d.locked_idxs.add(idx)
        if addr not in d.undo:
            d.undo[addr] = eng.heap[addr]
        # ORDER MATTERS (paper SS4.1 TEXT, not Alg. 3's line order): the
        # versioned write must complete BEFORE the in-place write.  Mode-U
        # readers of an unversioned address use the lock-freeze protocol,
        # whose safety argument is "a writer holding the lock would have
        # versioned the address [before changing the data]" — with the
        # pseudocode's in-place-first order there is a window where the
        # lock is held, the bloom filter still misses, and the heap already
        # holds the uncommitted value: a reader returns a torn read.  We
        # hit this as a real ~1-in-20s tear (EXPERIMENTS.md SSDeviations).
        if d.local_mode == M.MODE_Q:
            self._try_write_to_vlist(eng, d, addr, idx, value)
        else:
            # Modes QtoU / U / UtoQ: writers must version (Table 1)
            vlist = self._get_vlist(idx, addr)
            if vlist is None:
                ts = self.first_obs_mode_u_ts.load()
                if ts < 0:
                    ts = st.version
                node = VListNode(None, ts, d.undo[addr], False)
                vlist = VersionList(node)
                self.vlt.insert(idx, addr, vlist)
                self.bloom.add(idx, addr)
            self._append_version(d, addr, vlist, value)
        eng.heap[addr] = value                    # in-place (encounter-time)

    def write_bulk(self, eng, d, addrs, values) -> None:
        """Batched encounter-time write for the Mode-Q unversioned case.

        One ``try_lock_bulk`` sweep (validate + claim, atomic under the
        stripes), one undo gather, one heap scatter — the update-heavy
        hot path the paper's SS5 throughput comparison measures.  The
        batch only stays batched when NO claimed bucket holds a version
        list: our locks freeze those buckets (versioning an address
        requires its lock), so bucket-empty checked after the sweep is
        exact, and skipping the per-address version logic is then the
        same decision the scalar Mode-Q write makes on a bloom miss.
        The paper's version-before-in-place ordering (SS4.1) is not in
        play here: lock-freeze readers only exist in Mode U, and the
        mode machinery never overlaps a Mode-U reader with a local-
        Mode-Q writer (QtoU waits for us).  Everything else — versioned
        modes, version-list buckets, flagged/conflicted batches,
        sub-``BULK_MIN`` batches — takes the exact scalar loop.
        """
        if addrs.size == 0:
            return
        if d.versioned:
            self.write(eng, d, int(addrs[0]), values[0])  # restart path
        try_bulk = getattr(eng.locks, "try_lock_bulk", None)
        if d.local_mode != M.MODE_Q or try_bulk is None or \
                addrs.size < BULK_MIN:
            for a, v in zip(addrs, values):
                self.write(eng, d, int(a), v)
            return
        d.read_only = False
        addrs, values = C.dedup_last_wins(addrs, values)
        idxs = eng.locks.index_bulk(addrs)
        if FP.ACTIVE is not None:
            FP.fire("pre_claim", d.tid)
        new = try_bulk(idxs, d.tid, max_version=d.r_clock)
        if new is None:
            # version-blocked but conflict-free batch: snapshot-extend
            # past the deferred clock instead of aborting (the abort
            # would replay to exactly this state — commit.py note)
            new = C.extend_and_relock(eng, d, idxs)
        if new is None:
            # a FLAG means a Mode-Q reader is mid-versioning and the
            # scalar loop's wait-on-flag owns that window; any other
            # conflict (foreign lock, stale version with a stale read
            # set) aborts the scalar write too — skip straight to the
            # abort instead of replaying the batch word by word
            _, _, meta = eng.locks.gather(idxs)
            if bool(((meta & 2) != 0).any()):
                for a, v in zip(addrs, values):
                    self.write(eng, d, int(a), v)
                return
            eng.abort_txn(d)
        if self.vlt.nonempty_count and any(
                self.vlt._buckets[int(i)] is not None
                for i in np.unique(idxs)):
            # a claimed bucket holds version lists: unwind OUR new claims
            # (never locks earlier writes hold) and take the per-address
            # version-append path
            eng.locks.unlock_bulk(new)
            for a, v in zip(addrs, values):
                self.write(eng, d, int(a), v)
            return
        d.locked_idxs.update(new.tolist())
        if FP.ACTIVE is not None:
            FP.fire("post_claim", d.tid)
        C.merge_undo(eng, d, addrs)
        if FP.ACTIVE is not None:
            FP.fire("pre_scatter", d.tid)
        C.heap_scatter(eng.heap, addrs, values, tid=d.tid)
        if FP.ACTIVE is not None:
            FP.fire("post_scatter", d.tid)

    def _get_vlist(self, idx: int, addr: int) -> Optional[VersionList]:
        if not self.bloom.contains(idx, addr):
            return None
        return self.vlt.get(idx, addr)

    def _try_write_to_vlist(self, eng, d, addr, idx, value) -> None:
        """Mode Q: add a version iff the address is already versioned."""
        vlist = self._get_vlist(idx, addr)
        if vlist is None:
            return
        self._append_version(d, addr, vlist, value)

    def _append_version(self, d, addr, vlist, value) -> None:
        head = vlist.head
        if head is not None and head.tbd and addr in d.versioned_write_set:
            head.data = value                     # our own TBD: update it
            return
        node = VListNode(head, d.r_clock, value, True)
        vlist.head = node
        d.versioned_write_set[addr] = (vlist, node)
        if head is not None:
            # previous version retired iff we commit (eventualFree)
            self._retire_bufs[d.tid].retire_on_commit(head)

    def read_bulk(self, eng, d, addrs) -> Any:
        """Batched read on BOTH of the paper's read paths.

        Unversioned: the shared lock-version batch (one heap gather
        bracketed by two lock-word gathers, V_LT predicate); failures
        re-read scalar, which spins/aborts exactly like a scalar loop.

        Versioned: the same batch WITHOUT read-set tracking — an element
        that is unlocked, unflagged and stable at ``version < r_clock``
        holds precisely its value as of the reader's snapshot, no version
        list needed — then the recently-written minority (version at or
        past the snapshot, locked, or mid-versioning) resolves through
        ONE gather of the packed VLT mirror (`PackedVLT.select`: the
        newest committed version strictly below the snapshot, vectorized
        — `kernels/version_select.py` on TPU, the numpy twin on CPU),
        and only what the mirror cannot represent (colliding buckets,
        torn rows, versions deeper than the mirror) walks the version
        lists through the mode's scalar read.  This is what makes the
        paper's long-running read an array operation end to end: the
        stable majority moves in the heap gather, the written minority
        in the mirror gather, and the scalar walk handles a residue that
        is empty in the common case.
        """
        if not d.versioned:
            vals, ok = B.bulk_read_lockver(eng, d, addrs, inclusive=False)
            return B.finish_with_scalar(eng, d, addrs, vals, ok, self.read)
        vals, ok = B.bulk_read_lockver(eng, d, addrs, inclusive=False,
                                       track=False)
        vals, ok = self._bulk_versioned_gather(eng, d, addrs, vals, ok)
        scalar = (self._mode_u_versioned_read if d.local_mode == M.MODE_U
                  else self._mode_q_versioned_read)
        return B.finish_with_scalar(eng, d, addrs, vals, ok, scalar)

    def _bulk_versioned_gather(self, eng, d, addrs, vals, ok):
        """Vectorized version-list resolution for a failed batch minority.

        Elements the lock-version predicate rejected are exactly the
        recently-written ones a versioned reader serves from version
        lists (paper SS4.2); `PackedVLT.select` answers them in one
        mirror gather.  SOUNDNESS needs a lock gate in front of the row
        gather: a commit that could still land BELOW this snapshot (its
        commit clock was loaded before we began — the deferred clock can
        advance in between) holds its address locks for its entire
        version-publish window, so requiring the lock word to be free
        BEFORE reading the row excludes every such in-flight commit —
        serving the mirror there could mix pre- and post-commit state
        across a multi-address commit (the scalar traverse instead waits
        on the TBD mark).  A writer who takes the lock AFTER the gate
        commits at/above our snapshot and is skipped by the strict
        `ts < r_clock` acceptance anyway, and an accepted row is a
        seqlock-stable snapshot of the address's newest committed
        versions, so acceptance equals the scalar traverse's result.
        Unresolved elements keep `ok=False` and take the scalar walk.
        """
        if bool(ok.all()):
            return vals, ok
        bad = np.nonzero(~ok)[0]
        sub = addrs[bad]
        idxs = eng.locks.index_bulk(sub)
        # the lock gate: gathered BEFORE the mirror rows (GIL program
        # order), unlocked AND unflagged required
        _, _, meta = eng.locks.gather(idxs)
        free = (meta & 3) == 0
        mvals, mok = self.vlt.mirror.select(idxs, sub, d.r_clock)
        mok &= free
        hit = bad[mok]
        if hit.size == 0:
            return vals, ok
        self.stats_version_gather_hits += int(hit.size)
        if isinstance(vals, np.ndarray):
            if not vals.flags.writeable:     # kernel-path gathers are
                vals = vals.copy()           # read-only jax views
            vals[hit] = mvals[mok]
        else:
            for i, v in zip(hit.tolist(), mvals[mok].tolist()):
                vals[i] = v
        ok[hit] = True
        return vals, ok

    def read(self, eng, d, addr: int) -> Any:
        if d.versioned and d.local_mode in (M.MODE_Q, M.MODE_QTOU,
                                            M.MODE_UTOQ):
            return self._mode_q_versioned_read(eng, d, addr)
        if d.versioned and d.local_mode == M.MODE_U:
            return self._mode_u_versioned_read(eng, d, addr)
        # unversioned read
        idx = eng.locks.index(addr)
        if addr in d.undo:
            return eng.heap[addr]
        data = eng.heap[addr]
        st = eng.locks.read_wait_unflagged(idx)
        if not eng.locks.validate(st, d.r_clock, d.tid):
            eng.abort_txn(d)
        d.read_set.append((idx, st.version))
        return data

    # -- versioned reads ---------------------------------------------------
    def _traverse(self, eng, d, vlist: VersionList) -> Any:
        """Alg. 2 traverse: block on suitable TBD heads, skip deleted.

        Acceptance is STRICTLY ts < rClock (the paper writes <=; with the
        deferred clock several commits share one timestamp, so a reader at
        rclock c could otherwise see half of an in-flight commit whose
        commitClock also lands on c — mirroring validateLock's strict <
        restores opacity; DESIGN.md SS6)."""
        node = vlist.head
        while node is not None and node.tbd and node.timestamp < d.r_clock:
            node = vlist.head                     # reread head (spin)
        while node is not None and (node.timestamp >= d.r_clock
                                    or node.timestamp == DELETED_TS
                                    or node.tbd):
            assert not node.freed, "use-after-free: version node"
            node = node.older
        if node is None:
            eng.abort_txn(d)
        assert not node.freed, "use-after-free: version node"
        return node.data

    def _mode_q_versioned_read(self, eng, d, addr: int) -> Any:
        idx = eng.locks.index(addr)
        if not self.bloom.try_add(idx, addr):
            vlist = self.vlt.get(idx, addr)       # bloom hit (may be false+)
            if vlist is not None:
                return self._traverse(eng, d, vlist)
        return self._version_then_read(eng, d, addr, idx)

    def _version_then_read(self, eng, d, addr: int, idx: int) -> Any:
        """Mode-Q reader versions an unversioned address (SS4.1)."""
        st = eng.locks.lock_and_flag(idx, d.tid)
        try:
            # recheck: someone may have versioned it while we waited
            vlist = self.vlt.get(idx, addr)
            if vlist is None:
                data = eng.heap[addr]
                ts = self.first_obs_mode_u_ts.load()
                if ts < 0:
                    ts = st.version
                self.vlt.insert(idx, addr,
                                VersionList(VListNode(None, ts, data,
                                                      False)))
                self.bloom.add(idx, addr)
        finally:
            eng.locks.unlock(idx)
        if st.version >= d.r_clock:
            # the value we versioned was written at/after our snapshot
            eng.abort_txn(d)
        vlist = self.vlt.get(idx, addr)
        if vlist is not None:
            return self._traverse(eng, d, vlist)
        return eng.heap[addr]

    def _mode_u_versioned_read(self, eng, d, addr: int) -> Any:
        """SS4.2: unversioned addresses cannot have been written since the
        TM entered Mode U — read them with the lock-freeze protocol."""
        idx = eng.locks.index(addr)
        if self.bloom.contains(idx, addr):
            vlist = self.vlt.get(idx, addr)
            if vlist is not None:
                return self._traverse(eng, d, vlist)
        last_ver, last_val = -1, None
        while True:
            st = eng.locks.read(idx)
            if st.locked:
                # stable-value check by EQUALITY, not identity: ArrayHeap
                # returns a fresh int per read, so `is` would only ever
                # match CPython's small-int cache and the early return
                # would silently stop firing for values > 256
                cur = eng.heap[addr]
                if st.version == last_ver and cur == last_val:
                    return cur
                last_ver, last_val = st.version, cur
                # recheck versioned-ness: a writer holding the lock would
                # have versioned the address before changing it
                if self.bloom.contains(idx, addr):
                    vlist = self.vlt.get(idx, addr)
                    if vlist is not None:
                        return self._traverse(eng, d, vlist)
                continue
            data = eng.heap[addr]
            st2 = eng.locks.read(idx)
            if st2.version != st.version or st2.locked:
                if self.bloom.contains(idx, addr):
                    vlist = self.vlt.get(idx, addr)
                    if vlist is not None:
                        return self._traverse(eng, d, vlist)
                eng.abort_txn(d)
            return data

    # ------------------------------------------------------------------
    # background thread (Alg. 5)
    # ------------------------------------------------------------------
    def _wait_for_workers(self, eng, mode_counter: int) -> None:
        while not self._stop.is_set():
            found = False
            for t, ann in enumerate(self.announce):
                if ann.local_mode_counter < mode_counter and \
                        eng.ctx(t).active:
                    found = True
                    break
            if not found:
                return
            time.sleep(0.0005)

    def _any_sticky(self) -> bool:
        return any(a.sticky_mode_u for a in self.announce)

    def _transition(self, cur: int) -> int:
        new = cur + 1
        self.mode_counter.store(new)
        self.stats_mode_transitions += 1
        return new

    def _bg_thread(self, eng) -> None:
        poll = self.params.unversion_poll_ms / 1000.0
        while not self._stop.is_set():
            cnt = self.mode_counter.load()
            mode = M.get_mode(cnt)
            if mode == M.MODE_QTOU:
                self._wait_for_workers(eng, cnt)
                cnt = self._transition(cnt)          # -> U
                self.first_obs_mode_u_ts.store(eng.clock.load())
                # remain in U while sticky readers want it
                while self._any_sticky() and not self._stop.is_set():
                    time.sleep(poll)
                cnt = self._transition(cnt)          # -> UtoQ
                self._wait_for_workers(eng, cnt)
                self.first_obs_mode_u_ts.store(-1)
                cnt = self._transition(cnt)          # -> Q
            elif mode == M.MODE_Q:
                self._unversion_pass(eng)
                self.ebr.advance_and_reclaim()
                time.sleep(poll)
            else:  # recover if constructed mid-cycle
                time.sleep(poll)

    def _unversion_pass(self, eng) -> None:
        """SS4.4: unversion buckets whose newest version is older than the
        L/P-averaged commit-delta threshold."""
        deltas = [a.commit_ts_delta for a in self.announce
                  if a.commit_ts_delta is not None]
        self.unversion_heur.observe_round(deltas)
        thresh = self.unversion_heur.threshold()
        if thresh is None:
            return
        now = eng.clock.load()
        for bucket in self.vlt.nonempty_buckets():
            newest = self.vlt.bucket_newest_ts(bucket)
            if newest is None or now - newest < thresh:
                continue
            # claim the bucket's lock, detach, retire everything, reset bloom
            st = eng.locks.lock_and_flag(bucket, tid=-2)
            try:
                head = self.vlt.take_bucket(bucket)
                node = head
                while node is not None:
                    v = node.vlist.head
                    while v is not None:
                        self.ebr.retire(v)
                        v = v.older
                    self.ebr.retire(node)
                    node = node.next
                self.bloom.reset(bucket)
                self.stats_unversioned_buckets += 1
            finally:
                eng.locks.unlock(bucket)

    # ------------------------------------------------------------------
    # reporting / teardown
    # ------------------------------------------------------------------
    def mode_name(self, eng) -> str:
        return M.mode_name(self.mode_counter.load())

    def extra_stats(self, eng, out: dict) -> None:
        out["mode_transitions"] = self.stats_mode_transitions
        out["unversioned_buckets"] = self.stats_unversioned_buckets
        out["ebr_freed"] = self.ebr.freed_count
        # raw-engine stats only (the normalized substrate schema drops
        # them): words a versioned bulk read resolved via PackedVLT.select,
        # and how many of those a non-primary mirror way served (bucket
        # collisions the multi-way row layout kept vectorizable)
        out["version_gather_hits"] = self.stats_version_gather_hits
        out["mirror_way2_hits"] = sum(self.vlt.mirror.way_hits[1:])

    def stop(self, eng) -> None:
        self._stop.set()
        if self._bg is not None:
            self._bg.join(timeout=2.0)


class Multiverse(TransactionEngine):
    """The paper's TM: ``MultiversePolicy`` on the shared engine.

    Historical attribute surface (``tm.vlt``, ``tm.mode_counter``, ...)
    is preserved as properties over the policy so instrumentation,
    forced-mode ablations and the memory benchmarks keep working.
    """

    def __init__(self, n_threads: int,
                 params: Optional[MultiverseParams] = None,
                 start_bg: bool = True, heap=None):
        p = params or MultiverseParams()
        super().__init__(MultiversePolicy(p, start_bg=start_bg), n_threads,
                         lock_bits=p.lock_table_bits, heap=heap)
        self.name = "Multiverse"

    # -- instrumentation surface (policy state) ---------------------------
    @property
    def params(self) -> MultiverseParams:
        return self.policy.params

    @property
    def vlt(self) -> VLT:
        return self.policy.vlt

    @property
    def bloom(self) -> BloomTable:
        return self.policy.bloom

    @property
    def mode_counter(self) -> AtomicInt:
        return self.policy.mode_counter

    @property
    def first_obs_mode_u_ts(self) -> AtomicInt:
        return self.policy.first_obs_mode_u_ts

    @property
    def min_mode_u_reads(self):
        return self.policy.min_mode_u_reads

    @property
    def announce(self):
        return self.policy.announce

    @property
    def ebr(self) -> EBR:
        return self.policy.ebr

    @property
    def unversion_heur(self):
        return self.policy.unversion_heur

    @property
    def stats_mode_transitions(self) -> int:
        return self.policy.stats_mode_transitions

    @property
    def stats_unversioned_buckets(self) -> int:
        return self.policy.stats_unversioned_buckets


def run(tm, fn: Callable, tid: int = 0, max_retries: int = 0) -> Any:
    """DEPRECATED shim — the retry loop now lives in `repro.api.run`.

    Kept so existing call sites keep working; new code should use

        from repro.api import run, atomic, make_tm

    which accepts both raw TMs and `make_tm(...)` substrates and owns the
    retry/backoff/max_retries policy for every backend.
    """
    import warnings

    warnings.warn(
        "repro.core.stm.run() is deprecated; use repro.api.run() (or "
        "@repro.api.atomic / tm.txn()) instead",
        DeprecationWarning, stacklevel=2)
    from repro.api import run as api_run

    return api_run(tm, fn, tid=tid, max_retries=max_retries)
