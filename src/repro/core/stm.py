"""Multiverse STM — faithful implementation of the paper's Algorithms 1-5.

Word-based opaque STM with dynamic multiversioning:
  * unversioned path: DCTL-style (global clock, versioned locks,
    encounter-time locking, in-place writes, commit-time read revalidation,
    clock incremented by aborts);
  * versioned read-only path: version-list traversal with TBD blocking and
    deleted timestamps;
  * four TM modes on a monotone counter (Q, QtoU, U, UtoQ) with the
    Q->QtoU CAS open to workers and all other transitions centralized in
    the background thread, which also unversions VLT buckets in Mode Q
    using the L/P commit-delta heuristic and drives EBR.

The user API is `run(tm, fn)` where fn(tx) performs tx.read/tx.write —
aborts raise AbortTx and retry at begin, the setjmp/longjmp analogue.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.configs.paper_stm import MultiverseParams
from repro.core import heuristics as heur
from repro.core import modes as M
from repro.core import stats_schema
from repro.core.bloom import BloomTable
from repro.core.clock import AtomicInt, GlobalClock
from repro.core.ebr import EBR, TxRetireBuffer
from repro.core.locks import LockState, LockTable
from repro.core.vlt import DELETED_TS, VLT, VersionList, VListNode


class AbortTx(Exception):
    """Transaction abort (longjmp back to beginTxn)."""


class MaxRetriesExceeded(Exception):
    """A transaction hit the retry cap (baselines quit here; paper SS5)."""


class TMBase:
    """Shared heap + allocation interface (structures build on this)."""

    def __init__(self, n_threads: int):
        self.n_threads = n_threads
        self._heap: List[Any] = []
        self._heap_lock = threading.Lock()
        self.name = type(self).__name__

    # heap ---------------------------------------------------------------
    def alloc(self, n: int, init: Any = None) -> int:
        with self._heap_lock:
            base = len(self._heap)
            self._heap.extend([init] * n)
            return base

    def peek(self, addr: int) -> Any:
        """Non-transactional read (test/debug only)."""
        return self._heap[addr]

    def stop(self) -> None:  # pragma: no cover - overridden
        pass


class _TxCtx:
    """Per-thread transaction context (paper Alg. 1 thread locals)."""

    __slots__ = (
        "tid", "r_clock", "attempts", "read_only", "read_cnt", "versioned",
        "local_mode_counter", "local_mode", "read_set", "write_set",
        "versioned_write_set", "retires", "initial_versioned_ts", "active",
        "stats", "alloc_log", "no_versioning")

    def __init__(self, tid: int):
        self.tid = tid
        self.attempts = 0
        self.versioned = False
        self.no_versioning = False
        self.active = False
        self.stats = {"commits": 0, "aborts": 0, "versioned_commits": 0,
                      "mode_cas": 0, "ro_commits": 0}
        self.reset()
        self.initial_versioned_ts: Optional[int] = None

    def reset(self):
        self.r_clock = 0
        self.read_only = True
        self.read_cnt = 0
        self.local_mode_counter = 0
        self.local_mode = M.MODE_Q
        self.read_set: List[tuple] = []          # (idx, version_seen)
        self.write_set: Dict[int, Any] = {}      # addr -> old value
        # addr -> (vlist, node): the vlist lets rollback UNLINK the node
        self.versioned_write_set: Dict[int, tuple] = {}
        self.alloc_log: List[tuple] = []


class Multiverse(TMBase):
    def __init__(self, n_threads: int,
                 params: Optional[MultiverseParams] = None,
                 start_bg: bool = True):
        super().__init__(n_threads)
        self.params = params or MultiverseParams()
        bits = self.params.lock_table_bits
        self.clock = GlobalClock(0)
        self.locks = LockTable(bits)
        self.bloom = BloomTable(bits, self.params.bloom_bits)
        self.vlt = VLT(bits)
        self.mode_counter = AtomicInt(0)         # mode = counter & 3
        self.first_obs_mode_u_ts = AtomicInt(-1)
        self.min_mode_u_reads = heur.MinModeUReadCount()
        self.ebr = EBR(n_threads)
        self.announce = [heur.ThreadAnnouncement()
                         for _ in range(n_threads)]
        self.unversion_heur = heur.UnversionThreshold(self.params)
        self._ctxs = [_TxCtx(t) for t in range(n_threads)]
        self._retire_bufs = [TxRetireBuffer(self.ebr)
                             for _ in range(n_threads)]
        self.stats_unversioned_buckets = 0
        self.stats_mode_transitions = 0
        self._stop = threading.Event()
        self._bg: Optional[threading.Thread] = None
        if start_bg:
            self._bg = threading.Thread(target=self._bg_thread, daemon=True)
            self._bg.start()

    # ------------------------------------------------------------------
    # transaction lifecycle (Alg. 1)
    # ------------------------------------------------------------------
    def ctx(self, tid: int) -> _TxCtx:
        return self._ctxs[tid]

    def begin(self, tid: int) -> "_Tx":
        ctx = self._ctxs[tid]
        ctx.reset()
        ann = self.announce[tid]
        # announce-then-verify: publish (counter, active) BEFORE trusting
        # the counter, else the background thread can advance the mode in
        # the window between our load and our announcement and a local-
        # Mode-Q writer would run unversioned under global Mode U —
        # breaking the invariant Mode-U readers rely on (paper SS3.4 fn.1).
        while True:
            cnt = self.mode_counter.load()
            ctx.local_mode_counter = cnt
            ann.local_mode_counter = cnt
            ctx.active = True
            if self.mode_counter.load() == cnt:
                break
            ctx.active = False
        ctx.local_mode = M.get_mode(cnt)
        ctx.r_clock = self.clock.load()
        if ctx.versioned and ctx.initial_versioned_ts is None:
            ctx.initial_versioned_ts = ctx.r_clock
        ann.active_versioned = ctx.versioned
        self.ebr.pin(tid)
        return _Tx(self, ctx)

    def _try_commit(self, ctx: _TxCtx) -> None:
        ann = self.announce[ctx.tid]
        if ctx.read_only:
            if ctx.versioned:
                delta = self.clock.load() - (ctx.initial_versioned_ts or 0)
                ann.commit_ts_delta = delta
                if ctx.local_mode == M.MODE_U:
                    self.min_mode_u_reads.update(ctx.read_cnt)
                ctx.stats["versioned_commits"] += 1
            if ann.sticky_mode_u and heur.sticky_cleared(
                    self.params, ann, ctx.read_cnt):
                ann.sticky_mode_u = False
            ctx.stats["ro_commits"] += 1
            self._finish(ctx)
            return
        # update transaction: revalidate the read set
        for idx, seen_version in ctx.read_set:
            st = self.locks.read(idx)
            if not self.locks.validate(st, ctx.r_clock, ctx.tid):
                self._abort(ctx)
                raise AbortTx()
        commit_clock = self.clock.load()
        # remove TBD marks (publish versions at the commit clock)
        for addr, (vlist, node) in ctx.versioned_write_set.items():
            node.timestamp = commit_clock
            node.tbd = False
        # release write locks at the commit clock
        for addr in ctx.write_set:
            self.locks.unlock(self.locks.index(addr), commit_clock)
        self._retire_bufs[ctx.tid].commit()
        ctx.stats["commits"] += 1
        self._finish(ctx)

    def _finish(self, ctx: _TxCtx) -> None:
        ctx.active = False
        ctx.attempts = 0
        ctx.versioned = False
        ctx.initial_versioned_ts = None
        self.ebr.unpin(ctx.tid)

    def _abort(self, ctx: _TxCtx) -> None:
        # roll back in-place writes
        for addr, old in ctx.write_set.items():
            self._heap[addr] = old
        # roll back versioned writes: deleted timestamp, UNLINK, retire.
        # We hold the address lock, and our node is necessarily still the
        # head (no one else can prepend), so unlinking is safe; without it
        # a reader pinned AFTER the grace period could still walk through
        # the freed node — a real use-after-free caught by the poison-bit
        # assertions (EXPERIMENTS.md SSDeviations).
        buf = self._retire_bufs[ctx.tid]
        for addr, (vlist, node) in ctx.versioned_write_set.items():
            node.timestamp = DELETED_TS
            node.tbd = False
            if vlist.head is node:
                vlist.head = node.older
            buf.retire_on_abort(node)
        buf.abort()
        # free txn-local allocations
        for base, n in ctx.alloc_log:
            for i in range(n):
                self._heap[base + i] = None
        nxt = self.clock.increment()
        for addr in ctx.write_set:
            self.locks.unlock(self.locks.index(addr), nxt)
        ctx.stats["aborts"] += 1
        ann = self.announce[ctx.tid]
        if ctx.read_only:
            if heur.should_attempt_mode_cas(
                    self.params, versioned=ctx.versioned,
                    attempts=ctx.attempts, read_cnt=ctx.read_cnt,
                    min_mode_u_reads=self.min_mode_u_reads.get()):
                self._attempt_mode_cas(ctx)
            if not ctx.versioned and not ctx.no_versioning and \
                    heur.should_go_versioned(self.params, ctx.attempts):
                ctx.versioned = True
        ctx.attempts += 1
        ctx.active = False
        self.ebr.unpin(ctx.tid)

    def _attempt_mode_cas(self, ctx: _TxCtx) -> None:
        """Any local-Mode-Q txn may CAS Q -> QtoU (SS3.3.1)."""
        cnt = self.mode_counter.load()
        if M.get_mode(cnt) == M.MODE_Q:
            self.announce[ctx.tid].sticky_mode_u = True
            self.announce[ctx.tid].small_txn_read_cnt = None
            if self.mode_counter.cas(cnt, cnt + 1):
                ctx.stats["mode_cas"] += 1
                self.stats_mode_transitions += 1

    # ------------------------------------------------------------------
    # TM accesses (Alg. 3 / Alg. 4)
    # ------------------------------------------------------------------
    def tm_write(self, ctx: _TxCtx, addr: int, value: Any) -> None:
        if ctx.versioned:
            # Only read-only transactions can be versioned (paper SS3.2.2).
            # A versioned txn that turns out to write must restart on the
            # unversioned path: its versioned reads were of the PAST and
            # cannot anchor writes to the present (mixing them is the
            # SI-writer path of SS3.5, which must be explicitly requested).
            # no_versioning is STICKY for this operation — otherwise the K1
            # heuristic re-promotes on the next abort and the write aborts
            # it again, forever (livelock).
            ctx.versioned = False
            ctx.no_versioning = True
            ctx.initial_versioned_ts = None
            self._abort(ctx)
            raise AbortTx()
        ctx.read_only = False
        idx = self.locks.index(addr)
        st = self.locks.read_wait_unflagged(idx)
        if not self.locks.validate(st, ctx.r_clock, ctx.tid):
            self._abort(ctx)
            raise AbortTx()
        if not self.locks.try_lock(idx, st, ctx.tid):
            self._abort(ctx)
            raise AbortTx()
        if addr not in ctx.write_set:
            ctx.write_set[addr] = self._heap[addr]
        # ORDER MATTERS (paper SS4.1 TEXT, not Alg. 3's line order): the
        # versioned write must complete BEFORE the in-place write.  Mode-U
        # readers of an unversioned address use the lock-freeze protocol,
        # whose safety argument is "a writer holding the lock would have
        # versioned the address [before changing the data]" — with the
        # pseudocode's in-place-first order there is a window where the
        # lock is held, the bloom filter still misses, and the heap already
        # holds the uncommitted value: a reader returns a torn read.  We
        # hit this as a real ~1-in-20s tear (EXPERIMENTS.md SSDeviations).
        if ctx.local_mode == M.MODE_Q:
            self._try_write_to_vlist(ctx, addr, idx, value)
        else:
            # Modes QtoU / U / UtoQ: writers must version (Table 1)
            vlist = self._get_vlist(idx, addr)
            if vlist is None:
                ts = self.first_obs_mode_u_ts.load()
                if ts < 0:
                    ts = st.version
                node = VListNode(None, ts, ctx.write_set[addr], False)
                vlist = VersionList(node)
                self.vlt.insert(idx, addr, vlist)
                self.bloom.add(idx, addr)
            self._append_version(ctx, addr, vlist, value)
        self._heap[addr] = value                  # in-place (encounter-time)

    def _get_vlist(self, idx: int, addr: int) -> Optional[VersionList]:
        if not self.bloom.contains(idx, addr):
            return None
        return self.vlt.get(idx, addr)

    def _try_write_to_vlist(self, ctx, addr, idx, value) -> None:
        """Mode Q: add a version iff the address is already versioned."""
        vlist = self._get_vlist(idx, addr)
        if vlist is None:
            return
        self._append_version(ctx, addr, vlist, value)

    def _append_version(self, ctx, addr, vlist, value) -> None:
        head = vlist.head
        if head is not None and head.tbd and addr in ctx.versioned_write_set:
            head.data = value                     # our own TBD: update it
            return
        node = VListNode(head, ctx.r_clock, value, True)
        vlist.head = node
        ctx.versioned_write_set[addr] = (vlist, node)
        if head is not None:
            # previous version retired iff we commit (eventualFree)
            self._retire_bufs[ctx.tid].retire_on_commit(head)

    def tm_read(self, ctx: _TxCtx, addr: int) -> Any:
        ctx.read_cnt += 1
        if ctx.versioned and ctx.local_mode in (M.MODE_Q, M.MODE_QTOU,
                                                M.MODE_UTOQ):
            return self._mode_q_versioned_read(ctx, addr)
        if ctx.versioned and ctx.local_mode == M.MODE_U:
            return self._mode_u_versioned_read(ctx, addr)
        # unversioned read
        idx = self.locks.index(addr)
        if addr in ctx.write_set:
            return self._heap[addr]
        data = self._heap[addr]
        st = self.locks.read_wait_unflagged(idx)
        if not self.locks.validate(st, ctx.r_clock, ctx.tid):
            self._abort(ctx)
            raise AbortTx()
        ctx.read_set.append((idx, st.version))
        return data

    # -- versioned reads ---------------------------------------------------
    def _traverse(self, ctx, vlist: VersionList) -> Any:
        """Alg. 2 traverse: block on suitable TBD heads, skip deleted.

        Acceptance is STRICTLY ts < rClock (the paper writes <=; with the
        deferred clock several commits share one timestamp, so a reader at
        rclock c could otherwise see half of an in-flight commit whose
        commitClock also lands on c — mirroring validateLock's strict <
        restores opacity; DESIGN.md SS6)."""
        node = vlist.head
        while node is not None and node.tbd and node.timestamp < ctx.r_clock:
            node = vlist.head                     # reread head (spin)
        while node is not None and (node.timestamp >= ctx.r_clock
                                    or node.timestamp == DELETED_TS
                                    or node.tbd):
            assert not node.freed, "use-after-free: version node"
            node = node.older
        if node is None:
            self._abort(ctx)
            raise AbortTx()
        assert not node.freed, "use-after-free: version node"
        return node.data

    def _mode_q_versioned_read(self, ctx, addr: int) -> Any:
        idx = self.locks.index(addr)
        if not self.bloom.try_add(idx, addr):
            vlist = self.vlt.get(idx, addr)       # bloom hit (may be false+)
            if vlist is not None:
                return self._traverse(ctx, vlist)
        return self._version_then_read(ctx, addr, idx)

    def _version_then_read(self, ctx, addr: int, idx: int) -> Any:
        """Mode-Q reader versions an unversioned address (SS4.1)."""
        st = self.locks.lock_and_flag(idx, ctx.tid)
        try:
            # recheck: someone may have versioned it while we waited
            vlist = self.vlt.get(idx, addr)
            if vlist is None:
                data = self._heap[addr]
                ts = self.first_obs_mode_u_ts.load()
                if ts < 0:
                    ts = st.version
                self.vlt.insert(idx, addr,
                                VersionList(VListNode(None, ts, data,
                                                      False)))
                self.bloom.add(idx, addr)
            else:
                data = None
        finally:
            self.locks.unlock(idx)
        if st.version >= ctx.r_clock:
            # the value we versioned was written at/after our snapshot
            self._abort(ctx)
            raise AbortTx()
        vlist = self.vlt.get(idx, addr)
        if vlist is not None:
            return self._traverse(ctx, vlist)
        return self._heap[addr]

    def _mode_u_versioned_read(self, ctx, addr: int) -> Any:
        """SS4.2: unversioned addresses cannot have been written since the
        TM entered Mode U — read them with the lock-freeze protocol."""
        idx = self.locks.index(addr)
        if self.bloom.contains(idx, addr):
            vlist = self.vlt.get(idx, addr)
            if vlist is not None:
                return self._traverse(ctx, vlist)
        last_ver, last_val = -1, None
        while True:
            st = self.locks.read(idx)
            if st.locked:
                if st.version == last_ver and self._heap[addr] is last_val:
                    return last_val
                last_ver, last_val = st.version, self._heap[addr]
                # recheck versioned-ness: a writer holding the lock would
                # have versioned the address before changing it
                if self.bloom.contains(idx, addr):
                    vlist = self.vlt.get(idx, addr)
                    if vlist is not None:
                        return self._traverse(ctx, vlist)
                continue
            data = self._heap[addr]
            st2 = self.locks.read(idx)
            if st2.version != st.version or st2.locked:
                if self.bloom.contains(idx, addr):
                    vlist = self.vlt.get(idx, addr)
                    if vlist is not None:
                        return self._traverse(ctx, vlist)
                self._abort(ctx)
                raise AbortTx()
            return data

    # ------------------------------------------------------------------
    # allocation inside transactions
    # ------------------------------------------------------------------
    def tx_alloc(self, ctx, n: int, init: Any = None) -> int:
        base = self.alloc(n, init)
        ctx.alloc_log.append((base, n))
        return base

    # ------------------------------------------------------------------
    # background thread (Alg. 5)
    # ------------------------------------------------------------------
    def _wait_for_workers(self, mode_counter: int) -> None:
        while not self._stop.is_set():
            found = False
            for ann in self.announce:
                if ann.local_mode_counter < mode_counter and \
                        self._ctxs[self.announce.index(ann)].active:
                    found = True
                    break
            if not found:
                return
            time.sleep(0.0005)

    def _any_sticky(self) -> bool:
        return any(a.sticky_mode_u for a in self.announce)

    def _transition(self, cur: int) -> int:
        new = cur + 1
        self.mode_counter.store(new)
        self.stats_mode_transitions += 1
        return new

    def _bg_thread(self) -> None:
        poll = self.params.unversion_poll_ms / 1000.0
        while not self._stop.is_set():
            cnt = self.mode_counter.load()
            mode = M.get_mode(cnt)
            if mode == M.MODE_QTOU:
                self._wait_for_workers(cnt)
                cnt = self._transition(cnt)          # -> U
                self.first_obs_mode_u_ts.store(self.clock.load())
                # remain in U while sticky readers want it
                while self._any_sticky() and not self._stop.is_set():
                    time.sleep(poll)
                cnt = self._transition(cnt)          # -> UtoQ
                self._wait_for_workers(cnt)
                self.first_obs_mode_u_ts.store(-1)
                cnt = self._transition(cnt)          # -> Q
            elif mode == M.MODE_Q:
                self._unversion_pass()
                self.ebr.advance_and_reclaim()
                time.sleep(poll)
            else:  # recover if constructed mid-cycle
                time.sleep(poll)

    def _unversion_pass(self) -> None:
        """SS4.4: unversion buckets whose newest version is older than the
        L/P-averaged commit-delta threshold."""
        deltas = [a.commit_ts_delta for a in self.announce
                  if a.commit_ts_delta is not None]
        self.unversion_heur.observe_round(deltas)
        thresh = self.unversion_heur.threshold()
        if thresh is None:
            return
        now = self.clock.load()
        for bucket in self.vlt.nonempty_buckets():
            newest = self.vlt.bucket_newest_ts(bucket)
            if newest is None or now - newest < thresh:
                continue
            # claim the bucket's lock, detach, retire everything, reset bloom
            st = self.locks.lock_and_flag(bucket, tid=-2)
            try:
                head = self.vlt.take_bucket(bucket)
                node = head
                while node is not None:
                    v = node.vlist.head
                    while v is not None:
                        self.ebr.retire(v)
                        v = v.older
                    self.ebr.retire(node)
                    node = node.next
                self.bloom.reset(bucket)
                self.stats_unversioned_buckets += 1
            finally:
                self.locks.unlock(bucket)

    def stop(self) -> None:
        self._stop.set()
        if self._bg is not None:
            self._bg.join(timeout=2.0)

    # aggregate stats ----------------------------------------------------
    def stats(self) -> Dict[str, object]:
        out = stats_schema.base_stats(
            backend=self.name, mode=M.mode_name(self.mode_counter.load()))
        for c in self._ctxs:
            for k in ("commits", "aborts", "versioned_commits",
                      "ro_commits", "mode_cas"):
                out[k] += c.stats[k]
        out["mode_transitions"] = self.stats_mode_transitions
        out["unversioned_buckets"] = self.stats_unversioned_buckets
        out["ebr_freed"] = self.ebr.freed_count
        return out


class _Tx:
    """Handle passed to user transaction bodies."""

    __slots__ = ("_tm", "_ctx")

    def __init__(self, tm: Multiverse, ctx: _TxCtx):
        self._tm = tm
        self._ctx = ctx

    def read(self, addr: int) -> Any:
        return self._tm.tm_read(self._ctx, addr)

    def write(self, addr: int, value: Any) -> None:
        self._tm.tm_write(self._ctx, addr, value)

    def alloc(self, n: int, init: Any = None) -> int:
        return self._tm.tx_alloc(self._ctx, n, init)

    @property
    def read_count(self) -> int:
        return self._ctx.read_cnt


def run(tm, fn: Callable, tid: int = 0, max_retries: int = 0) -> Any:
    """DEPRECATED shim — the retry loop now lives in `repro.api.run`.

    Kept so existing call sites keep working; new code should use

        from repro.api import run, atomic, make_tm

    which accepts both raw TMs and `make_tm(...)` substrates and owns the
    retry/backoff/max_retries policy for every backend.
    """
    import warnings

    warnings.warn(
        "repro.core.stm.run() is deprecated; use repro.api.run() (or "
        "@repro.api.atomic / tm.txn()) instead",
        DeprecationWarning, stacklevel=2)
    from repro.api import run as api_run

    return api_run(tm, fn, tid=tid, max_retries=max_retries)
