"""MVStore: the paper's dynamic multiversioning at parameter-store level.

Layer-B adaptation (DESIGN.md SS2): parameter blocks are the transactional
addresses, the optimizer commit is the update transaction, snapshot readers
(eval / checkpoint / serve-from-trainer) are the long-running read-only
transactions, and the global clock is a replicated step counter.

Version lists become bounded HBM rings of R slots per versioned block (the
TPU adaptation of the paper's unbounded lists; overflow surfaces as reader
abort/retry, exactly like a paper conflict).  Which blocks are versioned is
STATIC per compiled step — a compiled step function is a transaction whose
local mode was fixed at begin (trace) time; the host-side controller
(mvcontroller.py) changes the global mode and swaps step variants at step
boundaries, which is the paper's local-mode-lags-global-mode-by-one rule.

Commit semantics per mode (paper Table 1):
  - local Mode Q, unversioned block: in-place write, no versioning work.
  - local Mode Q, versioned block:   in-place write + ring append (paper:
    "keeping both the version list and the unversioned location up to
    date"), published atomically at the step boundary (TBD analogue).
  - local Mode U (and QtoU/UtoQ):    every written block must be versioned
    -> ring append for all blocks.

Snapshot reads resolve each block to the newest version with
ts <= read_clock (versioned blocks), or to the live value with a
lock-validation check clock <= read_clock (unversioned blocks, the Mode-Q
reader path that aborts when the writer advanced the clock).
"""
from __future__ import annotations

import functools
from typing import Any, FrozenSet, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import MVStoreConfig

NO_TS = jnp.int32(-1)          # empty ring slot


class MVStoreState(NamedTuple):
    """live: the in-place values ('addresses').  ring/ring_ts exist only for
    versioned blocks (dict keyed by block path -> [R, ...] / [R]).

    ``block_clocks`` is the per-block level of the two-level clock scheme:
    the LAST-WRITER stamp of every block (dict path -> int32 scalar, in
    the same units as ``clock``).  Commits to disjoint blocks advance
    their own stamps, so conflict detection (``blocks_conflict``) only
    fires when footprints overlap — the global ``clock`` stays the total
    order that ring timestamps and snapshot pins are expressed in.
    ``None`` means a pre-sharding state: every check falls back to the
    global clock (the old single-clock semantics)."""
    live: Any
    ring: dict
    ring_ts: dict
    clock: jnp.ndarray          # int32 global clock
    block_clocks: Any = None    # dict path -> int32 last-writer stamp


VersionedSet = Union[str, FrozenSet[str]]  # 'all' | 'none' | explicit paths


def block_paths(params) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return [jax.tree_util.keystr(p) for p, _ in flat]


def _is_versioned(path: str, versioned: VersionedSet) -> bool:
    if versioned == "all":
        return True
    if versioned == "none" or not versioned:
        return False
    return path in versioned


def resolve_versioned(params, versioned: VersionedSet) -> FrozenSet[str]:
    return frozenset(p for p in block_paths(params)
                     if _is_versioned(p, versioned))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def mv_init(params, cfg: MVStoreConfig,
            versioned: VersionedSet = "none") -> MVStoreState:
    """Build store state.  Versioned blocks get an R-slot ring seeded with
    the current value at the current clock (paper SS3.1.1: the initial
    version takes the last consistent value and the earliest safe ts)."""
    R = cfg.ring_slots
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    ring, ring_ts = {}, {}
    block_clocks = {}
    for p, leaf in flat:
        path = jax.tree_util.keystr(p)
        block_clocks[path] = jnp.zeros((), jnp.int32)
        if _is_versioned(path, versioned):
            buf = jnp.zeros((R,) + leaf.shape, leaf.dtype)
            ring[path] = buf.at[0].set(leaf)
            ring_ts[path] = jnp.full((R,), NO_TS).at[0].set(0)
    return MVStoreState(live=params, ring=ring, ring_ts=ring_ts,
                        clock=jnp.zeros((), jnp.int32),
                        block_clocks=block_clocks)


# ---------------------------------------------------------------------------
# commit (the update-transaction write path)
# ---------------------------------------------------------------------------


def mv_commit(state: MVStoreState, new_params, *, local_mode: str,
              cfg: MVStoreConfig) -> MVStoreState:
    """Publish an optimizer step.  Rings rotate: the new value lands in slot
    ``clock' % R`` — a bounded version list ordered by timestamp."""
    from repro.reliability import faultpoints as FP
    if FP.ACTIVE is not None:
        FP.fire("pre_scatter")
    new_clock = state.clock + 1
    ring, ring_ts = state.ring, state.ring_ts
    must_version = local_mode in ("U", "QtoU", "UtoQ")
    if must_version:
        # every written block must already be in the versioned set: the
        # controller guarantees this before handing out a Mode-U step.
        missing = [p for p in block_paths(new_params) if p not in ring]
        if missing:
            raise ValueError(
                f"Mode {local_mode} commit with unversioned blocks "
                f"{missing[:3]}... — controller must version first")
    if ring:
        R = cfg.ring_slots
        slot = (new_clock % R).astype(jnp.int32)
        flat, _ = jax.tree_util.tree_flatten_with_path(new_params)
        new_ring, new_ts = {}, {}
        for p, leaf in flat:
            path = jax.tree_util.keystr(p)
            if path in ring:
                new_ring[path] = jax.lax.dynamic_update_index_in_dim(
                    ring[path], leaf.astype(ring[path].dtype), slot, 0)
                new_ts[path] = jax.lax.dynamic_update_index_in_dim(
                    ring_ts[path], new_clock.astype(jnp.int32), slot, 0)
        ring, ring_ts = new_ring, new_ts
    # a whole-store publish stamps every block it carries
    stamp = new_clock.astype(jnp.int32)
    block_clocks = dict(state.block_clocks or {})
    for path in block_paths(new_params):
        block_clocks[path] = stamp
    return MVStoreState(live=new_params, ring=ring, ring_ts=ring_ts,
                        clock=new_clock, block_clocks=block_clocks)


def mv_commit_fused(state: MVStoreState, key: str, addrs, values, *,
                    local_mode: str, cfg: MVStoreConfig) -> MVStoreState:
    """Sparse single-block publish: ``mv_commit`` where the new value is
    the live block with ``values`` scattered at ``addrs``, fused into
    ONE device-resident call.

    This is the `MVStoreHandle.commit` hot path: instead of
    scatter-then-rotate (a ``scatter_row`` launch, then ``mv_commit``'s
    ring ``dynamic_update_index_in_dim`` — with the live row crossing
    host between them), the whole publish — scatter into the live row
    AND the PackedVLT ring-row refresh — rides one ``ops.commit_fused``
    call under the caller's held commit lock (the seqlock bracket).
    The live and ring buffers are DONATED: the caller must alias the
    previous state for still-pinned snapshot readers before calling
    (``MVStoreHandle._install`` publishes the replacement wholesale).
    Mode/versioning semantics are exactly ``mv_commit``'s; only the
    single-block sparse-update spelling differs.
    """
    import numpy as np

    from repro.kernels import ops
    from repro.reliability import faultpoints as FP

    # fired BEFORE the donating call: past this point the old buffers
    # are gone and the only copy of the store is the return value, which
    # the caller must park somewhere recovery can find
    # (MVStoreHandle._inflight)
    if FP.ACTIVE is not None:
        FP.fire("pre_scatter")

    new_clock = state.clock + 1
    live = state.live[key]
    flat, _ = jax.tree_util.tree_flatten_with_path({key: live})
    path = jax.tree_util.keystr(flat[0][0])
    must_version = local_mode in ("U", "QtoU", "UtoQ")
    if must_version and path not in state.ring:
        raise ValueError(
            f"Mode {local_mode} commit with unversioned blocks "
            f"[{path!r}]... — controller must version first")
    a = np.asarray(addrs, np.int64)
    if a.size:
        lo, hi = int(a.min()), int(a.max())
        if lo < 0 or hi >= int(live.shape[0]):
            raise IndexError(lo if lo < 0 else hi)
    empty = np.zeros((0,), np.int64)
    ring = state.ring.get(path)
    kw = {}
    if ring is not None:
        kw = dict(ring=ring, ring_ts=state.ring_ts[path],
                  ring_slot=int(new_clock % cfg.ring_slots))
    out = ops.commit_fused(
        live, a, np.asarray(values), np.zeros(a.shape[0], np.int64),
        empty, empty, empty, empty, empty,
        np.zeros(1, np.int64), np.zeros(1, np.int64),
        int(new_clock), 1, **kw)
    # fired AFTER the donating call, before the caller installs the
    # result: a crash here is UNRECOVERABLE in-process (the old buffers
    # are deleted, the new state not yet parked) — exactly the window
    # only the durable WAL can cover (reliability/wal.recover_from_wal)
    if FP.ACTIVE is not None:
        FP.fire("mid_scatter")
    new_live = dict(state.live)
    new_live[key] = out[0]
    # sparse publish touches ONE block: only its stamp advances
    block_clocks = dict(state.block_clocks or {})
    block_clocks[path] = new_clock.astype(jnp.int32)
    if ring is not None:
        ring_d, ts_d = dict(state.ring), dict(state.ring_ts)
        ring_d[path], ts_d[path] = out[3], out[4]
        return MVStoreState(live=new_live, ring=ring_d, ring_ts=ts_d,
                            clock=new_clock, block_clocks=block_clocks)
    return MVStoreState(live=new_live, ring=state.ring,
                        ring_ts=state.ring_ts, clock=new_clock,
                        block_clocks=block_clocks)


# ---------------------------------------------------------------------------
# snapshot read (the versioned read-only transaction)
# ---------------------------------------------------------------------------


def _select_version(buf, ts, read_clock, impl: str):
    """Newest slot with NO_TS < ts <= read_clock.  Returns (value, ok)."""
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.snapshot_select(buf, ts, read_clock)
    valid = jnp.logical_and(ts != NO_TS, ts <= read_clock)
    masked = jnp.where(valid, ts, NO_TS)
    idx = jnp.argmax(masked)
    ok = jnp.any(valid)
    return jax.lax.dynamic_index_in_dim(buf, idx, 0, keepdims=False), ok


def mv_snapshot(state: MVStoreState, read_clock, *,
                assume_versioned: bool = False,
                impl: str = "xla") -> Tuple[Any, jnp.ndarray]:
    """Resolve a consistent view at ``read_clock``.

    ``assume_versioned``: the local-Mode-U reader path — every relevant
    block is versioned by the writers' invariant, so unversioned blocks are
    read live *without* validation (they cannot have been written since
    Mode U began; paper SS4.2).  Mode-Q readers validate unversioned blocks
    against the clock and abort (ok=False) when the writer has advanced.
    Returns (params_view, ok scalar bool).
    """
    ok = jnp.asarray(True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(state.live)
    out = []
    for p, leaf in flat:
        path = jax.tree_util.keystr(p)
        if path in state.ring:
            val, vok = _select_version(state.ring[path],
                                       state.ring_ts[path], read_clock,
                                       impl)
            ok = jnp.logical_and(ok, vok)
            out.append(val.astype(leaf.dtype))
        else:
            if not assume_versioned:
                # per-block validation: only a write to THIS block since
                # read_clock invalidates the view (two-level clock rule)
                bc = state.block_clocks
                stamp = (state.clock if bc is None or path not in bc
                         else bc[path])
                ok = jnp.logical_and(ok, stamp <= read_clock)
            out.append(leaf)
    view = jax.tree_util.tree_unflatten(
        treedef, out)
    return view, ok


# ---------------------------------------------------------------------------
# per-block clock queries (host-side conflict detection)
# ---------------------------------------------------------------------------


def block_clock(state: MVStoreState, path: str) -> int:
    """Last-writer stamp of ``path`` as a host int.  States predating
    per-block stamps (``block_clocks is None``) fall back to the global
    clock — the conservative old semantics."""
    bc = state.block_clocks
    if bc is None or path not in bc:
        return int(state.clock)
    return int(bc[path])


def blocks_conflict(state: MVStoreState, paths, read_clock: int) -> bool:
    """True iff any block in ``paths`` was committed after ``read_clock``.

    The per-block spelling of the old global ``clock != read_clock``
    commit check: a transaction whose write footprint is disjoint from
    every commit since its begin pin validates cleanly even though the
    GLOBAL clock advanced — disjoint-block updaters never conflict."""
    return any(block_clock(state, p) > read_clock for p in paths)


# ---------------------------------------------------------------------------
# host-side maintenance (controller helpers)
# ---------------------------------------------------------------------------


def version_blocks(state: MVStoreState, paths, cfg: MVStoreConfig,
                   first_obs_mode_u_ts: Optional[int] = None
                   ) -> MVStoreState:
    """Version additional blocks (reader-triggered in Mode Q; writer-forced
    in Mode U).  The initial version takes the live value; its timestamp is
    the earliest safe one — firstObsModeUTs when valid, else the current
    clock (the 'lock version'), per paper SS4.2."""
    ring = dict(state.ring)
    ring_ts = dict(state.ring_ts)
    R = cfg.ring_slots
    ts0 = (jnp.int32(first_obs_mode_u_ts)
           if first_obs_mode_u_ts is not None else state.clock)
    flat, _ = jax.tree_util.tree_flatten_with_path(state.live)
    for p, leaf in flat:
        path = jax.tree_util.keystr(p)
        if path in paths and path not in ring:
            buf = jnp.zeros((R,) + leaf.shape, leaf.dtype)
            ring[path] = buf.at[0].set(leaf)
            ring_ts[path] = jnp.full((R,), NO_TS).at[0].set(ts0)
    return state._replace(ring=ring, ring_ts=ring_ts)


def unversion_blocks(state: MVStoreState, paths) -> MVStoreState:
    """Drop rings (the background thread's unversioning; EBR analogue is
    host GC — a ring is only dropped when no live reader pins it, enforced
    by the controller's epoch refcounts)."""
    ring = {k: v for k, v in state.ring.items() if k not in paths}
    ring_ts = {k: v for k, v in state.ring_ts.items() if k not in paths}
    return state._replace(ring=ring, ring_ts=ring_ts)


def versioned_paths(state: MVStoreState) -> FrozenSet[str]:
    return frozenset(state.ring)


def ring_bytes(state: MVStoreState) -> int:
    return int(sum(v.size * v.dtype.itemsize for v in state.ring.values()))
