"""Heuristics K1/K2/K3/S/L/P + shared announcements (paper SS4.2-SS4.4).

Shared between the Layer-A STM (core/stm.py) and the Layer-B MVStore
controller (core/mvcontroller.py): both adapt versioning with exactly these
rules, at word vs parameter-block granularity.
"""
from __future__ import annotations

import threading
from typing import List, Optional

from repro.configs.paper_stm import MultiverseParams


class ThreadAnnouncement:
    """Per-thread shared slots the background thread inspects (Alg. 1/5)."""

    __slots__ = ("local_mode_counter", "sticky_mode_u", "commit_ts_delta",
                 "active_versioned", "small_txn_read_cnt",
                 "consec_small_txns")

    def __init__(self):
        self.local_mode_counter = 0
        self.sticky_mode_u = False
        self.commit_ts_delta: Optional[int] = None
        self.active_versioned = False
        self.small_txn_read_cnt: Optional[int] = None
        self.consec_small_txns = 0


class MinModeUReadCount:
    """Global minimum reads of committed Mode-U versioned txns (SS4.2)."""

    def __init__(self):
        self._v: Optional[int] = None
        self._lock = threading.Lock()

    def update(self, read_cnt: int) -> None:
        with self._lock:
            if self._v is None or read_cnt < self._v:
                self._v = read_cnt

    def get(self) -> Optional[int]:
        return self._v

    def reset(self) -> None:
        with self._lock:
            self._v = None


def should_go_versioned(params: MultiverseParams, attempts: int) -> bool:
    """K1: an unversioned read-only txn switches to the versioned path
    after K1 failed attempts (SS4.1)."""
    return attempts >= params.k1


def should_attempt_mode_cas(params: MultiverseParams, *, versioned: bool,
                            attempts: int, read_cnt: int,
                            min_mode_u_reads: Optional[int]) -> bool:
    """K2/K3: when a read-only txn should CAS the TM from Q to QtoU
    (SS4.3).  Versioned txns always try after K3 attempts; any read-only
    txn tries after K2 attempts iff its read count reaches the minimum
    Mode-U read count observed so far."""
    if versioned and attempts >= params.k3:
        return True
    if attempts >= params.k2:
        if min_mode_u_reads is None:
            return versioned  # no Mode-U history yet: only versioned txns
        return read_cnt >= min_mode_u_reads
    return False


def sticky_cleared(params: MultiverseParams, ann: ThreadAnnouncement,
                   read_cnt: int) -> bool:
    """S: the sticky Mode-U bit clears after S consecutive 'small'
    transactions; small = readCnt <= (1/S) * size of the first txn
    committed after the last CAS attempt (SS4.3)."""
    if ann.small_txn_read_cnt is None:
        ann.small_txn_read_cnt = max(1, read_cnt // max(params.s, 1))
        ann.consec_small_txns = 0
        return False
    if read_cnt <= ann.small_txn_read_cnt:
        ann.consec_small_txns += 1
    else:
        ann.consec_small_txns = 0
    if ann.consec_small_txns >= params.s:
        ann.small_txn_read_cnt = None
        ann.consec_small_txns = 0
        return True
    return False


class UnversionThreshold:
    """L/P: the background thread averages commit-timestamp deltas into a
    list of length L, sorts descending, and averages the first P fraction;
    buckets older than that delta get unversioned (SS4.4)."""

    def __init__(self, params: MultiverseParams):
        self.params = params
        self._deltas: List[float] = []

    def observe_round(self, deltas: List[int]) -> None:
        if deltas:
            self._deltas.append(sum(deltas) / len(deltas))
            if len(self._deltas) > self.params.l:
                self._deltas.pop(0)

    def threshold(self) -> Optional[float]:
        if len(self._deltas) < self.params.l:
            return None
        s = sorted(self._deltas, reverse=True)
        n = max(1, int(len(s) * self.params.p))
        return sum(s[:n]) / n
