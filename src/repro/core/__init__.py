"""The paper's contribution, two layers (DESIGN.md SS2):

Layer A — faithful word-based Multiverse STM (stm.py + friends) with the
TL2/DCTL/NOrec/TinySTM baselines it is evaluated against.

Layer B — MVStore (mvstore.py): the same dynamic-multiversioning policy at
parameter-block granularity for TPU-pod training/serving, driven by
mvcontroller.py.
"""
from repro.core.mvstore import (  # noqa: F401
    MVStoreState,
    mv_commit,
    mv_init,
    mv_snapshot,
    ring_bytes,
    unversion_blocks,
    version_blocks,
    versioned_paths,
)
