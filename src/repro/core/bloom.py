"""Per-bucket bloom filters (paper SS3.1.2).

One filter per lock/VLT bucket, stored in a parallel table of identical
size.  Membership answers "is this address versioned?" without walking the
VLT bucket.  Filters only reset in bulk (unversioning a bucket resets its
filter — items cannot be removed, paper SS3.1.3).
"""
from __future__ import annotations

import threading
from typing import List

from repro.core.locks import _GOLDEN, _MASK64


class BloomTable:
    def __init__(self, buckets_bits: int, bits_per_filter: int = 64):
        self.size = 1 << buckets_bits
        self.nbits = bits_per_filter
        self._filters: List[int] = [0] * self.size
        self._lock = threading.Lock()

    def _hashes(self, addr: int):
        h1 = (addr * _GOLDEN) & _MASK64
        h2 = ((addr ^ 0xDEADBEEF) * 0xC2B2AE3D27D4EB4F) & _MASK64
        return (1 << (h1 % self.nbits)) | (1 << (h2 % self.nbits))

    def contains(self, bucket: int, addr: int) -> bool:
        m = self._hashes(addr)
        return (self._filters[bucket] & m) == m

    def add(self, bucket: int, addr: int) -> None:
        m = self._hashes(addr)
        with self._lock:
            self._filters[bucket] |= m

    def try_add(self, bucket: int, addr: int) -> bool:
        """Paper Alg. 4 bloomFltr.tryAdd: returns False when the address was
        (apparently) already present, True when this call inserted it."""
        m = self._hashes(addr)
        with self._lock:
            if (self._filters[bucket] & m) == m:
                return False
            self._filters[bucket] |= m
            return True

    def reset(self, bucket: int) -> None:
        with self._lock:
            self._filters[bucket] = 0

    def fill_ratio(self, bucket: int) -> float:
        return bin(self._filters[bucket]).count("1") / self.nbits
