"""Epoch-based reclamation tied to transaction commit/abort (paper SS4.5).

The paper's key memory-management points, reproduced here:
  * retires issued during a transaction are buffered and REVOCABLE — an
    aborted update revokes the retire of the previous version it displaced,
    and instead retires the version it had added;
  * a retired node is only freed when every thread has passed the retire
    epoch (so a non-revalidating reader can never dereference freed memory
    — the TL2/DCTL segfault race of SS4.5);
  * freeing sets a poison bit so tests can PROVE absence of use-after-free.
"""
from __future__ import annotations

import threading
from typing import List


class EBR:
    GRACE = 2

    def __init__(self, n_threads: int):
        self.global_epoch = 0
        self._lock = threading.Lock()
        self._thread_epochs = [-1] * n_threads   # -1 = quiescent
        self._limbo: List[tuple] = []            # (epoch, node)
        self.freed_count = 0

    def pin(self, tid: int) -> None:
        self._thread_epochs[tid] = self.global_epoch

    def unpin(self, tid: int) -> None:
        self._thread_epochs[tid] = -1

    def retire(self, node) -> None:
        with self._lock:
            self._limbo.append((self.global_epoch, node))

    def retire_all(self, nodes) -> None:
        with self._lock:
            e = self.global_epoch
            self._limbo.extend((e, n) for n in nodes)

    def advance_and_reclaim(self) -> int:
        """Background-thread duty: bump the epoch and free safe nodes."""
        with self._lock:
            self.global_epoch += 1
            min_pinned = min((e for e in self._thread_epochs if e >= 0),
                             default=self.global_epoch)
            keep, freed = [], 0
            for e, node in self._limbo:
                if e + self.GRACE <= min_pinned:
                    node.freed = True           # poison: tests assert on it
                    freed += 1
                else:
                    keep.append((e, node))
            self._limbo = keep
            self.freed_count += freed
            return freed

    @property
    def limbo_size(self) -> int:
        return len(self._limbo)


class TxRetireBuffer:
    """Per-transaction revocable retires (paper SS4.5)."""

    def __init__(self, ebr: EBR):
        self._ebr = ebr
        self._pending = []        # retired iff the txn commits
        self._on_abort = []       # retired iff the txn aborts

    def retire_on_commit(self, node) -> None:
        self._pending.append(node)

    def retire_on_abort(self, node) -> None:
        self._on_abort.append(node)

    def commit(self) -> None:
        self._ebr.retire_all(self._pending)
        self._pending.clear()
        self._on_abort.clear()

    def abort(self) -> None:
        """Revoke pending retires; retire the aborted txn's own additions."""
        self._pending.clear()
        self._ebr.retire_all(self._on_abort)
        self._on_abort.clear()
