"""Normalized TM statistics schema shared by every backend.

Every substrate — Multiverse, the four baselines, and the Layer-B
MVStoreHandle — reports the SAME key set from ``stats()``.  Counters a
backend does not implement stay 0 (a TL2 instance never versions, so its
``versioned_commits`` is structurally zero, not missing).  This is what
lets benchmarks/run.py and the conformance tests treat backends uniformly
instead of special-casing key sets per TM.

Keys:
  commits              update-transaction commits
  aborts               aborts (all causes)
  ro_commits           read-only commits
  versioned_commits    read-only commits that used the versioned path
  mode_cas             successful Q->QtoU CASes by worker transactions
  mode_transitions     total mode-counter advances
  unversioned_buckets  buckets (word level) / blocks (store level) reclaimed
  ebr_freed            version nodes freed by epoch-based reclamation
  rolled_forward       crashed commits recovery redid (decided records)
  rolled_back          crashed attempts recovery dropped (undecided)
  locks_swept          orphaned lock words the owner scan released
  torn_rows_repaired   torn PackedVLT mirror rows reset by recovery
  wal_records_replayed durable WAL records replayed on restart
  mode                 current global mode name ("Q"/"QtoU"/"U"/"UtoQ"),
                       or "-" for backends with no mode machinery
  backend              backend class/registry name

The five recovery counters are ``reliability.recovery.RecoveryReport``
projected through ``as_stats()`` — every ``recover_*`` accumulates them
into the target's ``recovery_counters`` so they surface here instead of
as ad-hoc report fields.
"""
from __future__ import annotations

from typing import Dict, Optional

RECOVERY_STAT_KEYS = (
    "rolled_forward",
    "rolled_back",
    "locks_swept",
    "torn_rows_repaired",
    "wal_records_replayed",
)

STATS_COUNTER_KEYS = (
    "commits",
    "aborts",
    "ro_commits",
    "versioned_commits",
    "mode_cas",
    "mode_transitions",
    "unversioned_buckets",
    "ebr_freed",
) + RECOVERY_STAT_KEYS

STATS_KEYS = STATS_COUNTER_KEYS + ("mode", "backend")


def base_stats(backend: str = "", mode: str = "-") -> Dict[str, object]:
    """A zeroed stats dict in the shared schema."""
    out: Dict[str, object] = {k: 0 for k in STATS_COUNTER_KEYS}
    out["mode"] = mode
    out["backend"] = backend
    return out


def normalize_stats(raw: Optional[Dict], backend: str = "",
                    mode: Optional[str] = None) -> Dict[str, object]:
    """Project an arbitrary stats dict onto the shared schema.

    Unknown keys are dropped, missing counters default to 0; ``mode`` and
    ``backend`` fall back to the raw dict's values when not given.
    """
    raw = raw or {}
    out = base_stats(backend=backend or str(raw.get("backend", "")),
                     mode=mode or str(raw.get("mode", "-")))
    for k in STATS_COUNTER_KEYS:
        if k in raw:
            out[k] = int(raw[k])
    return out
