"""Version List Table (paper SS3.1, Fig. 2) + its packed bulk mirror.

Each bucket is a linked list of VLT nodes; a node holds (1) the head of a
version list, (2) the address it tracks, (3) the next bucket node.  Version
lists are linked lists of VListNode(older, timestamp, data, tbd), newest
first.  The address's lock (same index) protects all VLT mutations.

DELETED_TS marks versions rolled back by an aborted writer so concurrent
traversals are never permanently blocked on a TBD mark (paper SS4.1).

The bucket lists are what writers MUTATE; what bulk readers need is a
gather-friendly view of what they would FIND.  ``PackedVLT`` is that
view: an int64 mirror, indexed like the lock table, of each bucket's
newest ``depth`` COMMITTED ``(timestamp, data)`` pairs, maintained under
the same address lock that protects the list mutations and bracketed by
a per-row seqlock for lock-free readers.  A versioned bulk read
(``engine/bulkread.py`` Mode-U/Q hybrid path, paper SS4.2) resolves its
recently-written minority through ONE ``PackedVLT.select`` gather —
numpy twin ``np_version_select`` on CPU, the
``kernels/version_select.py`` Pallas kernel on TPU — instead of walking
version lists node by node in Python.  Rows the mirror cannot represent
(hash-colliding addresses sharing a bucket, non-integer payloads,
versions deeper than ``depth``) simply fail ``select`` and fall back to
the exact scalar traversal, so the mirror is an optimization of the
common case, never a semantic change.
"""
from __future__ import annotations

import threading
from typing import List, Optional, Tuple

import numpy as np

DELETED_TS = -2

#: empty mirror slot: never strictly below any snapshot clock, so the
#: selection predicate rejects it with no special-casing (rebased to the
#: int32-saturated positive sentinel on the kernel path)
EMPTY_TS = 1 << 62


class VListNode:
    __slots__ = ("older", "timestamp", "data", "tbd", "freed")

    def __init__(self, older, timestamp, data, tbd):
        self.older = older
        self.timestamp = timestamp
        self.data = data
        self.tbd = tbd
        self.freed = False          # EBR poison bit (use-after-free checks)


class VersionList:
    __slots__ = ("head",)

    def __init__(self, head: Optional[VListNode] = None):
        self.head = head


class VLTNode:
    __slots__ = ("vlist", "addr", "next", "freed")

    def __init__(self, vlist: VersionList, addr: int,
                 nxt: Optional["VLTNode"]):
        self.vlist = vlist
        self.addr = addr
        self.next = nxt
        self.freed = False


def np_version_select(ts: np.ndarray, data: np.ndarray,
                      r_clock: int) -> Tuple[np.ndarray, np.ndarray]:
    """Newest committed version strictly below ``r_clock``, per row.

    ``ts``/``data`` are [N, depth] newest-first mirror rows; returns
    ``(values [N], ok [N] bool)`` with ``values`` meaningful only where
    ``ok``.  Strict ``<`` mirrors the scalar traverse's acceptance (the
    deferred clock shares timestamps across commits; DESIGN.md SS6).
    The same contract is implemented by ``kernels/version_select.py`` —
    the kernel test pins the two element-for-element.
    """
    valid = ts < r_clock
    ok = valid.any(axis=1)
    first = np.argmax(valid, axis=1)
    vals = data[np.arange(ts.shape[0]), first]
    return vals, ok


def _packable(data) -> bool:
    """Only plain int64-range integers ride in the packed mirror."""
    return type(data) in (int, np.int64, np.int32) and \
        -(1 << 62) < int(data) < (1 << 62)


class PackedVLT:
    """Gather-friendly mirror of each bucket's newest committed versions.

    Arrays indexed by lock-table index: ``seq`` (per-row seqlock),
    ``addr`` ([size, ways] — WHICH addresses each row tracks, or a
    sentinel per way), and the newest-first ``ts``/``data`` version
    slots ([size, ways, depth]).  A bucket collision no longer poisons
    the row: the second address hashing into a bucket claims the second
    WAY and both stay vectorizable (``way_hits[w]`` counts reads each
    way served); only when every way is taken does a further colliding
    address go unmirrored — it simply never matches ``select`` and
    falls back to the scalar walk.  WRITERS mutate a row only while
    holding the row's address lock, bumping ``seq`` odd before and even
    after, so the scalar path's lock discipline also serializes mirror
    updates.  READERS hold nothing: ``select`` brackets its gathers
    with two ``seq`` gathers and accepts only rows that were stable and
    even across the window — a torn row just falls back to the scalar
    version-list walk.

    TBD (uncommitted) versions are never mirrored, so callers MUST gate
    acceptance on the address lock being free, gathered BEFORE the row
    (``MultiversePolicy._bulk_versioned_gather``): a commit whose clock
    was loaded before the reader began — and which can therefore still
    publish BELOW the reader's snapshot — holds its address locks for
    its entire publish window, and serving the mirror mid-window could
    mix pre- and post-commit values across a multi-address commit.
    With the gate, a writer locking after the gather commits at/above
    the snapshot and is skipped by strict ``ts < r_clock`` regardless —
    the same versions the scalar traverse waits on and then skips.
    """

    NO_ADDR = -1       # way empty (tracks no versioned address)
    UNPACKABLE = -2    # way poisoned (non-int payload reached a tracked
    #                    address): never matches select -> scalar fallback

    def __init__(self, size: int, depth: int = 4, ways: int = 2):
        self.size = size
        self.depth = depth
        self.ways = ways
        self._seq = np.zeros(size, np.int64)
        self._addr = np.full((size, ways), self.NO_ADDR, np.int64)
        self._ts = np.full((size, ways, depth), EMPTY_TS, np.int64)
        self._data = np.zeros((size, ways, depth), np.int64)
        #: reads served per way (way_hits[1:] are the collision wins the
        #: multi-way layout buys — exposed as stats_mirror_way2_hits)
        self.way_hits = [0] * ways

    def _way_of(self, bucket: int, addr: int) -> Optional[int]:
        w = np.nonzero(self._addr[bucket] == addr)[0]
        return int(w[0]) if w.size else None

    # -- writer side (caller holds the address lock for ``bucket``) ------
    def seed(self, bucket: int, addr: int, head: VListNode) -> None:
        """A version list was inserted for ``addr`` in ``bucket``: claim
        the first free way.  Unrepresentable heads (TBD, deleted,
        non-int payloads) and way overflow claim NOTHING — an unmirrored
        address never matches ``select``, which is already the safe
        fail-closed answer."""
        if head is None or head.tbd or head.timestamp == DELETED_TS \
                or not _packable(head.data):
            return
        free = np.nonzero(self._addr[bucket] == self.NO_ADDR)[0]
        if not free.size:
            return                     # all ways busy: not mirrored
        w = int(free[0])
        self._seq[bucket] += 1
        self._addr[bucket, w] = addr
        self._ts[bucket, w, 0] = head.timestamp
        self._ts[bucket, w, 1:] = EMPTY_TS
        self._data[bucket, w, 0] = int(head.data)
        self._seq[bucket] += 1

    def publish(self, bucket: int, addr: int, ts: int, data) -> None:
        """A commit published a NEW newest version for ``addr``."""
        w = self._way_of(bucket, addr)
        if w is None:
            return                     # unmirrored/poisoned: no-op
        self._seq[bucket] += 1
        if _packable(data):
            self._ts[bucket, w, 1:] = self._ts[bucket, w, :-1]
            self._data[bucket, w, 1:] = self._data[bucket, w, :-1]
            self._ts[bucket, w, 0] = ts
            self._data[bucket, w, 0] = int(data)
        else:
            # the newest version is unrepresentable; serving older slots
            # would time-travel, so the way must fall back until cleared
            self._addr[bucket, w] = self.UNPACKABLE
        self._seq[bucket] += 1

    def publish_bulk(self, buckets: np.ndarray, addrs: np.ndarray,
                     ts: int, datas) -> None:
        """One batched mirror refresh for a whole commit's version set
        (caller holds every address lock; ``MultiversePolicy``'s batched
        ``commit_update``).  Per UNIQUE bucket a single seqlock bracket
        — NOT one per entry: two ways of one bucket bumped separately
        would pass through an even mid-update ``seq`` and a reader could
        accept a half-refreshed row.  The slot shift itself is one
        vectorized assignment over all matched (bucket, way) pairs;
        unpackable payloads take the scalar ``publish`` (which poisons
        their way) after the sweep.
        """
        b = np.asarray(buckets, np.int64)
        a = np.asarray(addrs, np.int64)
        packable = np.fromiter((_packable(x) for x in datas), bool, a.size)
        vals = np.fromiter((int(x) if ok else 0
                            for x, ok in zip(datas, packable)),
                           np.int64, a.size)
        match = self._addr[b] == a[:, None]            # [M, ways]
        way = np.argmax(match, axis=1)
        tracked = match.any(axis=1)
        hit = tracked & packable
        if hit.any():
            hb, hw = b[hit], way[hit]                  # distinct pairs:
            # a way tracks ONE address and addrs are dict-keyed unique
            uniq = np.unique(hb)
            self._seq[uniq] += 1
            self._ts[hb, hw, 1:] = self._ts[hb, hw, :-1]
            self._data[hb, hw, 1:] = self._data[hb, hw, :-1]
            self._ts[hb, hw, 0] = ts
            self._data[hb, hw, 0] = vals[hit]
            self._seq[uniq] += 1
        for i in np.nonzero(tracked & ~packable)[0]:
            self.publish(int(b[i]), int(a[i]), ts, datas[int(i)])

    def clear(self, bucket: int) -> None:
        """The bucket was unversioned (paper SS4.4): forget everything."""
        self._seq[bucket] += 1
        self._addr[bucket] = self.NO_ADDR
        self._ts[bucket] = EMPTY_TS
        self._seq[bucket] += 1

    # -- reader side (lock-free) -----------------------------------------
    def select(self, idxs: np.ndarray, addrs: np.ndarray,
               r_clock: int) -> Tuple[np.ndarray, np.ndarray]:
        """Batched version resolution: ``(values int64[N], ok bool[N])``.

        ``values[i]`` is the newest committed version of ``addrs[i]``
        strictly below ``r_clock`` wherever ``ok[i]``; everywhere else
        the caller re-reads through the scalar traverse.  One seqlock-
        bracketed gather of the mirror rows, a vectorized way match,
        then one vectorized select over the matched ways (numpy twin on
        CPU, the Pallas kernel when KERNEL_INTERPRET=0).
        """
        s1 = self._seq[idxs]
        rows_addr = self._addr[idxs]                   # [N, ways]
        ts = self._ts[idxs]                            # [N, ways, depth]
        data = self._data[idxs]
        s2 = self._seq[idxs]
        stable = (s1 == s2) & ((s1 & 1) == 0)
        match = rows_addr == addrs[:, None]
        way = np.argmax(match, axis=1)                 # first (only) match
        rows = np.arange(idxs.shape[0])
        ts_w, data_w = ts[rows, way], data[rows, way]  # [N, depth]
        from repro.kernels import ops
        if not ops.INTERPRET:
            vals, found = ops.version_select(ts_w, data_w, r_clock)
        else:
            vals, found = np_version_select(ts_w, data_w, r_clock)
        ok = stable & match.any(axis=1) & found
        for w in range(1, self.ways):
            n = int((ok & (way == w)).sum())
            if n:
                self.way_hits[w] += n
        return vals, ok


class VLT:
    def __init__(self, buckets_bits: int, mirror_depth: int = 4):
        self.size = 1 << buckets_bits
        self._buckets: List[Optional[VLTNode]] = [None] * self.size
        self.mirror = PackedVLT(self.size, depth=mirror_depth)
        #: live count of nonempty buckets, guarded by ``_count_lock``:
        #: ``+=`` on an attribute is a preemptible load/add/store, and
        #: two inserts under DIFFERENT bucket locks could lose an
        #: increment — after which the count could read 0 with a bucket
        #: still populated, and the batched Mode-Q write path would skip
        #: version publication (a silent snapshot violation).  Reads are
        #: single attribute loads and need no lock.  The gate itself is
        #: sound: 0 proves every lock-frozen bucket in a write batch is
        #: empty without walking them (an insert needs the bucket's
        #: address lock, so a batch's own buckets cannot gain version
        #: lists while the batch holds their locks).
        self.nonempty_count = 0
        self._count_lock = threading.Lock()

    def get(self, bucket: int, addr: int) -> Optional[VersionList]:
        """tryGetVList: walk the bucket list (caller saw a bloom hit)."""
        node = self._buckets[bucket]
        while node is not None:
            assert not node.freed, "use-after-free: VLT node"
            if node.addr == addr:
                return node.vlist
            node = node.next
        return None

    def insert(self, bucket: int, addr: int, vlist: VersionList) -> None:
        """Prepend (caller holds the address lock)."""
        if self._buckets[bucket] is None:
            with self._count_lock:
                self.nonempty_count += 1
        self._buckets[bucket] = VLTNode(vlist, addr, self._buckets[bucket])
        self.mirror.seed(bucket, addr, vlist.head)

    def take_bucket(self, bucket: int) -> Optional[VLTNode]:
        """Detach the whole bucket (unversioning; caller holds the lock)."""
        head = self._buckets[bucket]
        if head is not None:
            with self._count_lock:
                self.nonempty_count -= 1
        self._buckets[bucket] = None
        self.mirror.clear(bucket)
        return head

    def bucket_newest_ts(self, bucket: int) -> Optional[int]:
        """Most recent (non-TBD) timestamp in the bucket, for the
        unversioning heuristic (paper SS4.4)."""
        newest = None
        node = self._buckets[bucket]
        while node is not None:
            v = node.vlist.head
            while v is not None and (v.tbd or v.timestamp == DELETED_TS):
                v = v.older
            if v is not None and (newest is None or v.timestamp > newest):
                newest = v.timestamp
            node = node.next
        return newest

    def nonempty_buckets(self):
        return [i for i in range(self.size) if self._buckets[i] is not None]
