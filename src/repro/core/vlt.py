"""Version List Table (paper SS3.1, Fig. 2).

Each bucket is a linked list of VLT nodes; a node holds (1) the head of a
version list, (2) the address it tracks, (3) the next bucket node.  Version
lists are linked lists of VListNode(older, timestamp, data, tbd), newest
first.  The address's lock (same index) protects all VLT mutations.

DELETED_TS marks versions rolled back by an aborted writer so concurrent
traversals are never permanently blocked on a TBD mark (paper SS4.1).
"""
from __future__ import annotations

import threading
from typing import List, Optional

DELETED_TS = -2


class VListNode:
    __slots__ = ("older", "timestamp", "data", "tbd", "freed")

    def __init__(self, older, timestamp, data, tbd):
        self.older = older
        self.timestamp = timestamp
        self.data = data
        self.tbd = tbd
        self.freed = False          # EBR poison bit (use-after-free checks)


class VersionList:
    __slots__ = ("head",)

    def __init__(self, head: Optional[VListNode] = None):
        self.head = head


class VLTNode:
    __slots__ = ("vlist", "addr", "next", "freed")

    def __init__(self, vlist: VersionList, addr: int,
                 nxt: Optional["VLTNode"]):
        self.vlist = vlist
        self.addr = addr
        self.next = nxt
        self.freed = False


class VLT:
    def __init__(self, buckets_bits: int):
        self.size = 1 << buckets_bits
        self._buckets: List[Optional[VLTNode]] = [None] * self.size

    def get(self, bucket: int, addr: int) -> Optional[VersionList]:
        """tryGetVList: walk the bucket list (caller saw a bloom hit)."""
        node = self._buckets[bucket]
        while node is not None:
            assert not node.freed, "use-after-free: VLT node"
            if node.addr == addr:
                return node.vlist
            node = node.next
        return None

    def insert(self, bucket: int, addr: int, vlist: VersionList) -> None:
        """Prepend (caller holds the address lock)."""
        self._buckets[bucket] = VLTNode(vlist, addr, self._buckets[bucket])

    def take_bucket(self, bucket: int) -> Optional[VLTNode]:
        """Detach the whole bucket (unversioning; caller holds the lock)."""
        head = self._buckets[bucket]
        self._buckets[bucket] = None
        return head

    def bucket_newest_ts(self, bucket: int) -> Optional[int]:
        """Most recent (non-TBD) timestamp in the bucket, for the
        unversioning heuristic (paper SS4.4)."""
        newest = None
        node = self._buckets[bucket]
        while node is not None:
            v = node.vlist.head
            while v is not None and (v.tbd or v.timestamp == DELETED_TS):
                v = v.older
            if v is not None and (newest is None or v.timestamp > newest):
                newest = v.timestamp
            node = node.next
        return newest

    def nonempty_buckets(self):
        return [i for i in range(self.size) if self._buckets[i] is not None]
