"""TM modes (paper SS3.3): a monotonically increasing counter whose value
mod 4 is the mode, so transitions are single atomic increments and the
cyclic order Q -> QtoU -> U -> UtoQ -> Q is structural."""
from __future__ import annotations

MODE_Q = 0
MODE_QTOU = 1
MODE_U = 2
MODE_UTOQ = 3

MODE_NAMES = {MODE_Q: "Q", MODE_QTOU: "QtoU", MODE_U: "U",
              MODE_UTOQ: "UtoQ"}


def get_mode(counter: int) -> int:
    return counter & 3


def mode_name(counter: int) -> str:
    return MODE_NAMES[get_mode(counter)]


def writers_must_version(mode: int) -> bool:
    """Paper Table 1: writers version in every mode except Q."""
    return mode != MODE_Q


def readers_assume_versioned(mode: int) -> bool:
    """Paper Table 1: only local-Mode-U readers may assume all relevant
    addresses are versioned."""
    return mode == MODE_U


def unversioning_enabled(mode: int) -> bool:
    return mode == MODE_Q
