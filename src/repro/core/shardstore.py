"""ShardStoreHandle — the MVStore partitioned across a jax mesh of shards.

The tentpole of the two-level clock scheme (``mvstore.MVStoreState.
block_clocks`` is the fine level; this module is the coarse level):
``n_shards`` independent ``MVStoreHandle``s, each owning one slice of
the address space, one shard-local clock and its own bounded rings —
plus ONE coarse epoch clock for cross-shard ordering.  Commits to
disjoint shards tick independently and never conflict; that is the
paper's footprints-only-conflict-when-they-overlap promise lifted from
blocks to devices.

Address routing: the global space is striped in spans of ``span``
words — global address ``a`` lives in span ``k = a // span``, which
shard ``k % n_shards`` stores at local address
``(k // n_shards) * span + a % span``.  At ``n_shards == 1`` the map is
the identity, so the sharded store is BIT-IDENTICAL to a solo
``MVStoreHandle`` on the same seeds (the conformance suite pins this).
When the host exposes multiple jax devices (or a mesh is passed), each
shard's buffers are ``device_put`` onto its own device slice via the
``launch/mesh.py`` + ``launch/sharding.py`` machinery — one shard = one
device slice; on a single-device host placement is a no-op and the
partitioning still buys clock independence.

Transaction lifecycle (the two-level clock protocol):

  * ``begin`` pins a VECTOR of shard clocks — one sub-context per
    shard — under an epoch seqlock bracket: the pin loop re-reads the
    epoch sequence (odd = a cross-shard publish is mid-flight) and
    retries until it pinned a stable, even cut.  Single-shard commits
    never bump the sequence, so the common case costs two atomic loads.
  * reads/writes route to the owning shard and validate against that
    shard's pin (``read_bulk`` batches per shard through
    ``engine/bulkread.shard_partition`` and reassembles in order).
  * commit with a SINGLE-shard footprint (reads and writes on one
    shard — the common case) delegates to that shard's solo commit: no
    coordination, no epoch traffic, exactly today's pipeline.
  * commit SPANNING shards runs a two-phase epoch-stamped publish:
    acquire every involved shard's commit lock in ascending shard order
    (``engine/commit.acquire_ascending`` — the ``Striped.for_indices``
    discipline lifted to whole commit locks), validate EVERY touched
    shard against its pin under the locks (atomic
    validate-all-then-publish-all: a read-shard/write-shard split can
    never produce a non-serializable cut), park an
    ``EpochRecord`` (``reliability/recovery.py`` — ``publish_started``
    generalized to the epoch), bump the epoch seqlock odd, publish
    shard-locally through each shard's exact solo publish path, then
    even the seqlock.  A crash mid-epoch leaves the record parked and
    the sequence odd; ``recover_shardstore`` rolls the whole epoch
    forward or back atomically — never a torn cut.
"""
from __future__ import annotations

import threading
import time
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.api.mvhandle import MVStoreHandle, _MVCtx
from repro.api.substrate import SubstrateBase, Txn
from repro.core import modes as M
from repro.core.clock import AtomicInt
from repro.core.engine import AbortTx
from repro.core.engine.bulkread import as_addr_array, shard_partition
from repro.core.engine.commit import acquire_ascending
from repro.core.stats_schema import RECOVERY_STAT_KEYS, base_stats
from repro.reliability import faultpoints as FP
from repro.reliability.recovery import EpochRecord

__all__ = ["ShardStoreHandle", "shard_devices"]

_COUNTER_KEYS = ("commits", "aborts", "ro_commits", "versioned_commits")


def shard_devices(n_shards: int, mesh=None) -> List[Any]:
    """One device per shard: round-robin over the mesh's device slices.

    With an explicit mesh (``launch.mesh.make_mesh``/``make_host_mesh``)
    shards stripe over ``mesh.devices``; without one, over
    ``jax.devices()`` — and a single-device host gets ``[None] * n``
    (placement is a no-op there, the sharding still buys per-shard
    clocks)."""
    try:
        import jax
        if mesh is not None:
            from repro.launch.sharding import shard_device_slices
            return shard_device_slices(mesh, n_shards)
        devs = jax.devices()
    except Exception:                      # pragma: no cover - no backend
        return [None] * n_shards
    if len(devs) <= 1:
        return [None] * n_shards
    return [devs[s % len(devs)] for s in range(n_shards)]


class _ShardCtx:
    """Store-level transaction context: one sub-context per shard plus
    the pinned vector of shard clocks (the epoch-consistent cut)."""

    __slots__ = ("tid", "subs", "pins", "active")

    def __init__(self, tid: int, subs: List[_MVCtx]):
        self.tid = tid
        self.subs = subs
        self.pins = tuple(c.read_clock for c in subs)
        self.active = True

    @property
    def read_only(self) -> bool:
        return all(c.read_only for c in self.subs)


class ShardStoreHandle(SubstrateBase):
    name = "shardstore"

    def __init__(self, n_threads: int = 1, *, n_shards: int = 2,
                 span: int = 64, cfg=None, params=None, controller=None,
                 versioned: str = "none", start_bg: bool = True,
                 mesh=None):
        from repro.configs.base import MVStoreConfig
        from repro.configs.paper_stm import MultiverseParams
        from repro.core.mvcontroller import MVController

        assert n_shards >= 1 and span >= 1
        self.n_threads = n_threads
        self.n_shards = n_shards
        self._span = span
        self.cfg = cfg or MVStoreConfig(ring_slots=8)
        self.params = params or MultiverseParams()
        self.controller = controller or MVController(
            params=self.params, mvcfg=self.cfg, start_bg=start_bg)
        self._own_controller = controller is None
        # one solo handle per shard, all sharing ONE controller: the
        # mode cycle is global (the paper's single global mode), the
        # clocks are per shard
        self._shards = [
            MVStoreHandle(n_threads, cfg=self.cfg, params=self.params,
                          controller=self.controller, versioned=versioned)
            for _ in range(n_shards)]
        self._devices = shard_devices(n_shards, mesh)
        # the coarse level of the two-level clock: an epoch counter
        # (ticks once per cross-shard publish) and its seqlock (odd =
        # publish in flight; begin() pins only on even-and-stable)
        self._epoch = AtomicInt(0)
        self._epoch_seq = AtomicInt(0)
        self._epoch_inflight: Optional[EpochRecord] = None
        self._alloc_lock = threading.Lock()
        self._top = 0
        self._counters = [{k: 0 for k in _COUNTER_KEYS}
                          for _ in range(n_threads)]
        self._cross_commits = 0
        # durable commit log (reliability/wal.attach_wal sets this AND
        # each member shard's ``wal``/``wal_shard``): single-shard
        # commits journal through the member's solo publish; cross-shard
        # epochs journal here as one prepare-group + one group DECIDE
        self.wal = None
        self.recovery_counters = {k: 0 for k in RECOVERY_STAT_KEYS}

    # -- address routing --------------------------------------------------
    def _route(self, a: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Global addresses -> (shard ids, shard-local addresses)."""
        g, n = self._span, self.n_shards
        k = a // g
        return (k % n).astype(np.int64), (k // n) * g + (a % g)

    def _route1(self, addr: int) -> Tuple[int, int]:
        g, n = self._span, self.n_shards
        k = addr // g
        return int(k % n), int((k // n) * g + (addr % g))

    def _local_top(self, s: int, top: int) -> int:
        """Shard ``s``'s heap size when the global heap has ``top`` words
        (spans round-robin, so local heaps stay contiguous prefixes)."""
        g, n = self._span, self.n_shards
        full, rem = divmod(top, g)
        local = (full // n + (1 if (full % n) > s else 0)) * g
        if full % n == s:
            local += rem
        return local

    # -- Substrate protocol ----------------------------------------------
    def begin_operation(self, tid: int) -> None:
        for sh in self._shards:
            sh.begin_operation(tid)

    def begin(self, tid: int = 0) -> Txn:
        while True:
            s0 = self._epoch_seq.load()
            if s0 & 1:
                # a cross-shard publish is mid-flight: pinning now could
                # capture half an epoch — wait the bracket out
                time.sleep(0)
                continue
            subs = [sh.begin(tid)._ctx for sh in self._shards]
            if self._epoch_seq.load() == s0:
                break
            for c in subs:          # raced the bracket: discard the pins
                c.active = False
        return Txn(self, _ShardCtx(tid, subs), tid)

    def read(self, ctx: _ShardCtx, addr: int) -> Any:
        s, local = self._route1(addr)
        try:
            return self._shards[s].read(ctx.subs[s], local)
        except AbortTx:
            self._fail(ctx)
            raise

    def read_bulk(self, ctx: _ShardCtx, addrs) -> Any:
        a = as_addr_array(addrs)
        try:
            if a.size == 0:
                return self._shards[0].read_bulk(ctx.subs[0], a)
            sid, local = self._route(a)
            if bool((sid == sid[0]).all()):     # one shard: one gather
                s = int(sid[0])
                return self._shards[s].read_bulk(ctx.subs[s], local)
            out: list = [None] * a.size
            for s, pos in shard_partition(sid, self.n_shards):
                vals = self._shards[s].read_bulk(ctx.subs[s], local[pos])
                vlist = (vals.tolist() if hasattr(vals, "tolist")
                         else list(vals))
                for p, v in zip(pos.tolist(), vlist):
                    out[p] = v
            return out
        except AbortTx:
            self._fail(ctx)
            raise

    def write(self, ctx: _ShardCtx, addr: int, value: Any) -> None:
        s, local = self._route1(addr)
        try:
            self._shards[s].write(ctx.subs[s], local, value)
        except AbortTx:
            self._fail(ctx)
            raise

    def write_bulk(self, ctx: _ShardCtx, addrs, values) -> None:
        a = as_addr_array(addrs)
        if a.size == 0:
            return
        sid, local = self._route(a)
        try:
            if bool((sid == sid[0]).all()):
                s = int(sid[0])
                self._shards[s].write_bulk(ctx.subs[s], local, values)
                return
            vlist = (values.tolist() if hasattr(values, "tolist")
                     else list(values))
            for s, pos in shard_partition(sid, self.n_shards):
                self._shards[s].write_bulk(
                    ctx.subs[s], local[pos],
                    [vlist[p] for p in pos.tolist()])
        except AbortTx:
            self._fail(ctx)
            raise

    def txn_alloc(self, ctx: _ShardCtx, n: int, init: Any = None) -> int:
        return self.alloc(n, init)

    def read_count(self, ctx: _ShardCtx) -> int:
        return sum(c.read_cnt for c in ctx.subs)

    def validate(self, ctx: _ShardCtx) -> bool:
        return all(sh.validate(c)
                   for sh, c in zip(self._shards, ctx.subs))

    # -- commit -----------------------------------------------------------
    def _touched(self, ctx: _ShardCtx) -> List[int]:
        return [s for s, c in enumerate(ctx.subs)
                if c.read_cnt or c.write_buf]

    def commit(self, txn: Txn) -> None:
        ctx = txn._ctx
        c = self._counters[ctx.tid]
        subs = ctx.subs
        write_shards = [s for s, sc in enumerate(subs) if sc.write_buf]
        touched = self._touched(ctx)
        if not write_shards:
            # read-only: each touched shard commits locally (feeding the
            # K1/K2/K3 heuristics); pins are immutable, no coordination
            for s in touched:
                self._shards[s].commit(Txn(self._shards[s], subs[s],
                                           ctx.tid))
            if any(subs[s].versioned for s in touched):
                c["versioned_commits"] += 1
            c["ro_commits"] += 1
            self._deactivate(ctx)
            return
        if len(touched) == 1:
            # the common case the ISSUE names: a single-shard footprint
            # commits with NO cross-shard coordination — the solo
            # pipeline verbatim (shard==1 bit-identity rides this path)
            s = touched[0]
            try:
                self._shards[s].commit(Txn(self._shards[s], subs[s],
                                           ctx.tid))
            except AbortTx:
                self._fail(ctx)
                raise
        else:
            self._commit_cross(ctx, touched, write_shards)
            self._cross_commits += 1
        c["commits"] += 1
        self._deactivate(ctx)

    def _commit_cross(self, ctx: _ShardCtx, touched: List[int],
                      write_shards: List[int]) -> None:
        """Two-phase epoch-stamped publish across shards.

        Phase 1 (validate): under EVERY touched shard's commit lock
        (ascending order — deadlock-free), check each shard's per-block
        stamps against this transaction's pin.  Phase 2 (publish): park
        the ``EpochRecord``, bump the epoch seqlock odd, drive each
        write shard's solo publish, even the seqlock.  Crash anywhere in
        phase 2 leaves the record for ``recover_shardstore``; the odd
        sequence keeps new pins out until recovery resolves the epoch.
        """
        subs = ctx.subs
        shards = self._shards
        if FP.ACTIVE is not None:
            FP.fire("pre_claim", ctx.tid)
        with acquire_ascending([shards[s]._commit_lock for s in touched]):
            if (self._epoch_inflight is not None
                    or any(shards[s]._check_conflict(subs[s])
                           for s in touched)):
                # fail closed on an unrecovered epoch, abort on conflict
                self._abort_cross(ctx, touched)
            if FP.ACTIVE is not None:
                FP.fire("post_claim", ctx.tid)
            rec = EpochRecord(
                epoch=self._epoch.increment(),
                write_shards=tuple(write_shards),
                pins={s: int(shards[s]._state.clock)
                      for s in write_shards},
                ctxs={s: subs[s] for s in write_shards},
                tid=ctx.tid)
            if self.wal is not None:
                # the epoch's durable twin: one PREPARE per write shard
                # (each carrying that shard's redo image + pinned clock)
                # under ONE group DECIDE — a restart replays the epoch
                # all-or-nothing across shards (wal.recover_from_wal)
                recs = []
                for s in write_shards:
                    wb = subs[s].write_buf
                    idx = sorted(wb)
                    recs.append((ctx.tid, idx, [wb[i] for i in idx],
                                 (rec.pins[s] + 1,), rec.epoch, s))
                rec.wal_lsns = tuple(self.wal.append_prepare_group(recs))
            self._epoch_inflight = rec
            self._epoch_seq.increment()        # odd: begin() waits
            try:
                if FP.ACTIVE is not None:
                    FP.fire("pre_clock_tick", ctx.tid)
                if self.wal is not None:
                    self.wal.append_decide_group(rec.wal_lsns)
                rec.publish_started = True     # the epoch commit record
                for s in write_shards:
                    # members must not re-journal solo records — the
                    # EPOCH is the durable unit
                    shards[s]._publish_locked(subs[s], wal_log=False)
                    rec.published.append(s)
                if FP.ACTIVE is not None:
                    FP.fire("pre_release", ctx.tid)
                self._epoch_inflight = None
                if self.wal is not None:
                    for lsn in rec.wal_lsns:
                        self.wal.append_complete(lsn)
            finally:
                if self._epoch_inflight is None:
                    self._epoch_seq.increment()    # even: bracket closed
                # else: crashed mid-epoch — the record stays parked and
                # the sequence odd until recover_shardstore resolves it

    # -- abort bookkeeping -------------------------------------------------
    def _deactivate(self, ctx: _ShardCtx) -> None:
        for c in ctx.subs:
            c.active = False
        ctx.active = False

    def _fail(self, ctx: _ShardCtx) -> None:
        """A shard-level abort surfaced: the shard already did its own
        accounting/heuristics; record ONE logical abort and retire every
        sub-context."""
        self._counters[ctx.tid]["aborts"] += 1
        self._deactivate(ctx)

    def _abort_cross(self, ctx: _ShardCtx, touched: List[int]) -> None:
        for s in touched:
            try:
                self._shards[s]._abort_ctx(ctx.subs[s])
            except AbortTx:
                pass
        self._fail(ctx)
        raise AbortTx()

    def abort(self, txn: Txn) -> None:
        ctx = txn._ctx
        if not getattr(ctx, "active", False):
            return
        for s in self._touched(ctx):
            if ctx.subs[s].active:
                try:
                    self._shards[s]._abort_ctx(ctx.subs[s])
                except AbortTx:
                    pass
        self._fail(ctx)

    # -- heap --------------------------------------------------------------
    def alloc(self, n: int, init: Any = None) -> int:
        with self._alloc_lock:
            base = self._top
            new_top = base + n
            for s, sh in enumerate(self._shards):
                need = self._local_top(s, new_top)
                have = self._local_top(s, base)
                if need > have:
                    got = sh.alloc(need - have, init)
                    assert got == have, (s, got, have)
                    self._place(s)
            self._top = new_top
        return base

    def _place(self, s: int) -> None:
        """Pin shard ``s``'s buffers onto its device slice (one shard =
        one device slice); no-op on a single-device host."""
        dev = self._devices[s]
        if dev is None:
            return
        import jax
        sh = self._shards[s]
        with sh._commit_lock:
            sh._install(jax.device_put(sh._state, dev))

    def peek(self, addr: int) -> Any:
        s, local = self._route1(addr)
        return self._shards[s].peek(local)

    def snapshot_bulk(self, addrs, read_clock=None):
        """``(values, ok)`` at a pinned cut.

        ``read_clock`` is ``None`` (now), one int (the same clock on
        every shard), or a per-shard vector — the pin a transaction's
        ``ctx.pins`` carries, so a recovery check can replay any epoch's
        cut."""
        a = as_addr_array(addrs)
        sid, local = self._route(a)
        out = np.zeros(a.size, np.int64)
        for s, pos in shard_partition(sid, self.n_shards):
            rc = (read_clock if read_clock is None
                  or isinstance(read_clock, (int, np.integer))
                  else read_clock[s])
            vals, ok = self._shards[s].snapshot_bulk(local[pos], rc)
            if not ok:
                return None, False
            out[pos] = np.asarray(vals)
        return out, True

    # -- accessors ---------------------------------------------------------
    @property
    def clocks(self) -> Tuple[int, ...]:
        """The per-shard clock vector (the fine level)."""
        return tuple(sh.clock for sh in self._shards)

    @property
    def clock(self) -> int:
        """Total commits across shards — one monotone scalar for callers
        that want a single progress clock."""
        return sum(self.clocks)

    @property
    def epoch(self) -> int:
        """The coarse epoch clock (ticks once per cross-shard publish)."""
        return self._epoch.load()

    # -- stats / lifecycle -------------------------------------------------
    def stats(self) -> dict:
        out = base_stats(backend=self.name,
                         mode=M.mode_name(self.controller.mode_counter))
        for c in self._counters:
            for k in _COUNTER_KEYS:
                out[k] += c[k]
        out["mode_cas"] = sum(h.stats["mode_cas"]
                              for sh in self._shards
                              for h in sh._readers)
        out["mode_transitions"] = self.controller.stats["mode_transitions"]
        out["unversioned_buckets"] = self.controller.stats[
            "blocks_unversioned"]
        out["n_shards"] = self.n_shards
        out["cross_shard_commits"] = self._cross_commits
        out["epoch"] = self.epoch
        for k, v in self.recovery_counters.items():
            out[k] += v
        return out

    def stop(self) -> None:
        if self._own_controller:
            self.controller.stop()
