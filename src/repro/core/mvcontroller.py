"""MVStore mode controller — the paper's background thread at pod scale.

Drives the Q -> QtoU -> U -> UtoQ -> Q cycle over the MVStore using the
same heuristics as the word-level STM (core/heuristics.py):

  * snapshot readers announce aborts/read-counts; K1 flips a reader to the
    versioned path, K2/K3 let it CAS the global mode Q -> QtoU;
  * the controller advances all other transitions only after every
    participant's announced local mode counter has caught up (the paper's
    local-mode-lags-by-one invariant).  A *participant* is the trainer
    (the single logical writer) or a snapshot reader;
  * in Mode Q it runs unversioning rounds with the L/P commit-delta
    threshold, dropping rings whose newest version is stale;
  * JAX buffer immutability plays the role of EBR: a ring dropped at a
    step boundary cannot invalidate arrays an in-flight reader already
    holds (DESIGN.md SS6 note 3), so reclamation is structurally safe —
    the controller still tracks reader pins to mirror the paper's
    accounting and to bound ring growth.

State-mutating effects (version/unversion blocks, ring writes) are applied
by the TRAINER at step boundaries via `trainer_tick` — compiled steps have
a fixed local mode, so swapping variants at boundaries is exactly a
transaction picking up its local mode at begin.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, FrozenSet, List, Optional, Set

from repro.configs.base import MVStoreConfig
from repro.configs.paper_stm import MultiverseParams
from repro.core import heuristics as heur
from repro.core import modes as M
from repro.core import mvstore


class ReaderHandle:
    """Per-reader announcement + heuristic state."""

    def __init__(self, rid: int, controller: "MVController"):
        self.rid = rid
        self.ctl = controller
        self.ann = heur.ThreadAnnouncement()
        self.attempts = 0
        self.versioned = False
        self.local_mode_counter = 0
        self.initial_versioned_ts: Optional[int] = None
        self.stats = {"commits": 0, "aborts": 0, "versioned_commits": 0,
                      "mode_cas": 0}

    # -- reader lifecycle -----------------------------------------------
    def begin(self, read_clock: int) -> Dict:
        self.local_mode_counter = self.ctl.mode_counter
        self.ann.local_mode_counter = self.local_mode_counter
        self.ann.active_versioned = self.versioned
        if self.versioned and self.initial_versioned_ts is None:
            self.initial_versioned_ts = read_clock
        return {"mode": M.get_mode(self.local_mode_counter),
                "versioned": self.versioned,
                "read_clock": read_clock}

    def on_abort(self, read_cnt: int, wanted_blocks=()) -> None:
        """A snapshot read came back not-ok (writer advanced the clock or
        ring overflow) — the paper's reader abort path."""
        self.stats["aborts"] += 1
        p = self.ctl.params
        if heur.should_attempt_mode_cas(
                p, versioned=self.versioned, attempts=self.attempts,
                read_cnt=read_cnt,
                min_mode_u_reads=self.ctl.min_mode_u_reads.get()):
            self.ann.sticky_mode_u = True
            self.ann.small_txn_read_cnt = None
            self.ctl.try_cas_q_to_qtou(self)
        if not self.versioned and heur.should_go_versioned(p,
                                                           self.attempts):
            self.versioned = True
        if self.versioned and wanted_blocks:
            # Mode-Q versioned reader versions the blocks it needs
            self.ctl.request_versioning(wanted_blocks)
        self.attempts += 1

    def on_commit(self, read_cnt: int, commit_clock: int) -> None:
        self.stats["commits"] += 1
        if self.versioned:
            self.stats["versioned_commits"] += 1
            self.ann.commit_ts_delta = commit_clock - (
                self.initial_versioned_ts or 0)
            if M.get_mode(self.local_mode_counter) == M.MODE_U:
                self.ctl.min_mode_u_reads.update(read_cnt)
        if self.ann.sticky_mode_u and heur.sticky_cleared(
                self.ctl.params, self.ann, read_cnt):
            self.ann.sticky_mode_u = False
        self.attempts = 0
        self.versioned = False
        self.initial_versioned_ts = None


class MVController:
    def __init__(self, params: Optional[MultiverseParams] = None,
                 mvcfg: Optional[MVStoreConfig] = None,
                 poll_s: float = 0.002, start_bg: bool = True):
        self.params = params or MultiverseParams()
        self.mvcfg = mvcfg or MVStoreConfig()
        self.mode_counter = 0
        self._mode_lock = threading.Lock()
        self.min_mode_u_reads = heur.MinModeUReadCount()
        self.unversion_heur = heur.UnversionThreshold(self.params)
        self.first_obs_mode_u_ts: Optional[int] = None
        self._readers: List[ReaderHandle] = []
        self._trainer_mode_counter = 0
        self._trainer_clock = 0
        self._pending_version: Set[str] = set()
        self._pending_unversion: Set[str] = set()
        self._poll = poll_s
        self._stop = threading.Event()
        self.stats = {"mode_transitions": 0, "unversion_rounds": 0,
                      "blocks_unversioned": 0}
        self._bg = None
        if start_bg:
            self._bg = threading.Thread(target=self._bg_loop, daemon=True)
            self._bg.start()

    # -- registration -----------------------------------------------------
    def reader(self) -> ReaderHandle:
        h = ReaderHandle(len(self._readers), self)
        self._readers.append(h)
        return h

    # -- mode machinery -----------------------------------------------------
    @property
    def mode(self) -> int:
        return M.get_mode(self.mode_counter)

    def try_cas_q_to_qtou(self, reader: ReaderHandle) -> bool:
        with self._mode_lock:
            if M.get_mode(self.mode_counter) == M.MODE_Q:
                self.mode_counter += 1
                self.stats["mode_transitions"] += 1
                reader.stats["mode_cas"] += 1
                return True
        return False

    def _advance(self) -> None:
        with self._mode_lock:
            self.mode_counter += 1
            self.stats["mode_transitions"] += 1

    def request_versioning(self, paths) -> None:
        self._pending_version.update(paths)

    # -- trainer integration ------------------------------------------------
    def trainer_tick(self, state: mvstore.MVStoreState
                     ) -> (mvstore.MVStoreState):
        """Called by the trainer BETWEEN steps: adopt the global mode and
        apply pending (un)versioning.  Returns the updated store state;
        the trainer then selects the compiled variant for
        `current_local_mode()` and the store's versioned set."""
        cnt = self.mode_counter
        mode = M.get_mode(cnt)
        self._trainer_clock = int(state.clock)
        if M.writers_must_version(mode):
            missing = [p for p in mvstore.block_paths(state.live)
                       if p not in state.ring]
            if missing:
                state = mvstore.version_blocks(
                    state, set(missing), self.mvcfg,
                    first_obs_mode_u_ts=self.first_obs_mode_u_ts)
        if self._pending_version:
            want = self._pending_version
            self._pending_version = set()
            state = mvstore.version_blocks(
                state, want, self.mvcfg,
                first_obs_mode_u_ts=self.first_obs_mode_u_ts)
        if self._pending_unversion and M.unversioning_enabled(mode):
            pending = self._pending_unversion
            self._pending_unversion = set()
            drop = apply_stale_unversioning(state, pending)
            if drop:
                state = mvstore.unversion_blocks(state, drop)
                self.stats["blocks_unversioned"] += len(drop)
        self._trainer_mode_counter = cnt
        return state

    def current_local_mode(self) -> str:
        return M.MODE_NAMES[M.get_mode(self._trainer_mode_counter)]

    # -- background thread ----------------------------------------------------
    def _participants_caught_up(self, cnt: int) -> bool:
        if self._trainer_mode_counter < cnt:
            return False
        return all(r.ann.local_mode_counter >= cnt or
                   not r.ann.active_versioned
                   for r in self._readers)

    def _any_sticky(self) -> bool:
        return any(r.ann.sticky_mode_u for r in self._readers)

    def step_once(self) -> None:
        """One controller decision round — the body of the poll loop.

        Public so tests (and recovery drills) can drive the mode state
        machine SYNCHRONOUSLY with ``start_bg=False`` instead of
        sleeping until a background poller happens to observe the same
        announcements — the decision depends only on the announcement
        state, never on wall-clock timing."""
        cnt = self.mode_counter
        mode = M.get_mode(cnt)
        if mode == M.MODE_QTOU:
            if self._participants_caught_up(cnt):
                self._advance()                       # -> U
                self.first_obs_mode_u_ts = self._trainer_clock
        elif mode == M.MODE_U:
            if not self._any_sticky():
                self._advance()                       # -> UtoQ
        elif mode == M.MODE_UTOQ:
            if self._participants_caught_up(cnt):
                self.first_obs_mode_u_ts = None
                self._advance()                       # -> Q
        else:  # Mode Q: unversioning rounds (paper SS4.4)
            self._unversion_round()

    def _bg_loop(self) -> None:
        while not self._stop.is_set():
            self.step_once()
            time.sleep(self._poll)

    def _unversion_round(self) -> None:
        deltas = [r.ann.commit_ts_delta for r in self._readers
                  if r.ann.commit_ts_delta is not None]
        self.unversion_heur.observe_round(deltas)
        thresh = self.unversion_heur.threshold()
        if thresh is None:
            return
        self.stats["unversion_rounds"] += 1
        # the trainer applies the drop at the next step boundary; the
        # 'newest ts' of every ring equals the commit clock of its last
        # write, which the trainer knows — send the threshold along
        self._pending_unversion.add(f"__stale_older_than:{thresh}")

    def stop(self) -> None:
        self._stop.set()
        if self._bg is not None:
            self._bg.join(timeout=2.0)


def apply_stale_unversioning(state: mvstore.MVStoreState,
                             pending: Set[str]) -> FrozenSet[str]:
    """Resolve '__stale_older_than:<t>' markers against ring timestamps."""
    drop: Set[str] = set()
    thresh = None
    for p in pending:
        if p.startswith("__stale_older_than:"):
            thresh = float(p.split(":", 1)[1])
        else:
            drop.add(p)
    if thresh is not None:
        import numpy as np
        clock = int(state.clock)
        for path, ts in state.ring_ts.items():
            newest = int(np.max(np.asarray(ts)))
            if clock - newest >= thresh:
                drop.add(path)
    return frozenset(drop)
