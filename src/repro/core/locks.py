"""Versioned lock table (paper SS3, Alg. 2).

A lock word is the tuple (locked, version, tid, flag):
  locked  — held by an updater (encounter-time locking)
  version — commit clock of the last writer to any address in this bucket
  tid     — current holder (lets a transaction revalidate its own locks)
  flag    — 'versioning in progress': readers/writers must wait, the holder
            is only installing a version list, not changing data

The lock table, bloom-filter table and VLT are identically sized and share
one address->index map, so an address's lock also protects its version list
(paper SS3.1).  CAS is emulated with striped host locks (clock.Striped).
"""
from __future__ import annotations

import threading
from typing import NamedTuple, Optional

from repro.core.clock import Striped


class LockState(NamedTuple):
    locked: bool
    version: int
    tid: int
    flag: bool


UNLOCKED = LockState(False, 0, -1, False)

# Fibonacci hashing; all three tables use this same map (paper SS3.1).
_GOLDEN = 0x9E3779B97F4A7C15
_MASK64 = (1 << 64) - 1


def addr_index(addr: int, bits: int) -> int:
    return ((addr * _GOLDEN) & _MASK64) >> (64 - bits)


class LockTable:
    def __init__(self, bits: int):
        self.bits = bits
        self.size = 1 << bits
        self._words = [UNLOCKED] * self.size
        self._stripes = Striped(1024)

    def index(self, addr: int) -> int:
        return addr_index(addr, self.bits)

    # -- raw word ops -----------------------------------------------------
    def read(self, idx: int) -> LockState:
        return self._words[idx]

    def read_wait_unflagged(self, idx: int) -> LockState:
        """Reread the lock until flag is false (paper Alg. 3 line 2)."""
        while True:
            st = self._words[idx]
            if not st.flag:
                return st

    def cas(self, idx: int, expect: LockState, new: LockState) -> bool:
        with self._stripes.for_index(idx):
            if self._words[idx] != expect:
                return False
            self._words[idx] = new
            return True

    def store(self, idx: int, new: LockState) -> None:
        with self._stripes.for_index(idx):
            self._words[idx] = new

    # -- paper operations ---------------------------------------------------
    def validate(self, st: LockState, r_clock: int, tid: int) -> bool:
        """validateLock (Alg. 2): own locks pass; held locks conflict;
        versions must predate the read clock."""
        if st.tid == tid and st.locked:
            return True
        if st.locked or st.flag:
            return False
        return st.version < r_clock

    def try_lock(self, idx: int, st: LockState, tid: int) -> bool:
        """Claim for writing (encounter-time)."""
        if st.locked:
            return st.tid == tid
        return self.cas(idx, st, LockState(True, st.version, tid, False))

    def lock_and_flag(self, idx: int, tid: int) -> LockState:
        """Spin until the lock is claimed with the versioning flag set
        (paper Alg. 4 versionThenRead); returns the pre-claim state."""
        while True:
            st = self._words[idx]
            if not st.locked and not st.flag:
                if self.cas(idx, st, LockState(True, st.version, tid, True)):
                    return st

    def unlock(self, idx: int, version: Optional[int] = None) -> None:
        """Release, optionally publishing a new version."""
        with self._stripes.for_index(idx):
            st = self._words[idx]
            v = version if version is not None else st.version
            self._words[idx] = LockState(False, v, -1, False)
