"""Transaction control-flow exceptions (canonical home).

Historically these lived in ``repro.core.stm``; that module re-exports
them so old imports keep working, but the engine layer — and anything
below ``repro.api`` — should import from here.
"""
from __future__ import annotations


class AbortTx(Exception):
    """Transaction abort (longjmp back to beginTxn)."""


class MaxRetriesExceeded(Exception):
    """A transaction hit the retry cap (baselines quit here; paper SS5)."""
