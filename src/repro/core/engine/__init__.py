"""repro.core.engine — the shared transaction-engine layer.

One ``TransactionEngine`` (heap + clock + lock table + descriptors +
commit/abort orchestration) drives every word-level backend; the
algorithms themselves are ``TMPolicy`` objects (``core/baselines.py``,
``core/stm.py``).  Layered as:

    descriptor.py   TxnDescriptor — unified per-thread txn context
    validation.py   commit-time revalidation (scalar + bulk/vectorized)
    bulkread.py     batched reads (Txn.read_bulk): gather + vectorized
                    stability predicate, scalar fallback per element
    traverse.py     frontier-at-a-time traversal (traverse_bulk /
                    chase_bulk): pointer chases as per-level batches
    commit.py       lock-acquire / write-back / version-publish steps
    policy.py       TMPolicy protocol + PolicyBase defaults
    arrayheap.py    ObjectHeap / ArrayHeap / packed ArrayLockTable
    engine.py       TransactionEngine + the _Tx user handle

See API.md ("The engine layer") for the worked add-a-backend example.
"""
from repro.core.engine.arrayheap import (  # noqa: F401
    ArrayHeap,
    ArrayLockTable,
    ObjectHeap,
)
from repro.core.engine.bulkread import (  # noqa: F401
    as_addr_array,
    bulk_read_lockver,
    heap_gather,
)
from repro.core.engine.descriptor import (  # noqa: F401
    COUNTER_KEYS,
    TxnDescriptor,
)
from repro.core.engine.engine import (  # noqa: F401
    TMBase,
    TransactionEngine,
    _Tx,
)
from repro.core.engine.errors import (  # noqa: F401
    AbortTx,
    MaxRetriesExceeded,
)
from repro.core.engine.policy import PolicyBase, TMPolicy  # noqa: F401
from repro.core.engine.traverse import (  # noqa: F401
    chase_bulk,
    traverse_bulk,
)
from repro.core.engine.validation import (  # noqa: F401
    BULK_MIN,
    V_EQ,
    V_LE,
    V_LT,
)

__all__ = [
    "ArrayHeap", "ArrayLockTable", "BULK_MIN", "COUNTER_KEYS",
    "MaxRetriesExceeded", "AbortTx", "ObjectHeap", "PolicyBase", "TMBase",
    "TMPolicy", "TransactionEngine", "TxnDescriptor", "V_EQ", "V_LE",
    "V_LT", "as_addr_array", "bulk_read_lockver", "chase_bulk",
    "heap_gather", "traverse_bulk",
]
