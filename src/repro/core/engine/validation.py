"""Commit-time read-set revalidation strategies (the paper's hot path).

Every lock-version backend revalidates its read set at commit with one of
three predicates over the current lock word vs what the transaction saw:

  * ``V_LT``  (Multiverse/DCTL, deferred clock): own locks pass; foreign
    locks/flags conflict; otherwise ``version < r_clock`` (Alg. 2
    validateLock);
  * ``V_LE``  (TL2): locked-by-other conflicts; ``version <= r_clock``;
  * ``V_EQ``  (TinySTM): locked-by-other conflicts; ``version == seen``.

``revalidate`` is the single entry point: it runs the word-at-a-time
scalar loop for small read sets and switches to the BULK path — one
consistent ``gather`` of the packed lock words, then a vectorized
predicate — once the read set is large enough to amortize it.  The bulk
predicate itself has two implementations sharing one contract:

  * ``np_validate``   — numpy, the CPU fast path and interpret-mode oracle;
  * ``kernels/validate.py`` — the Pallas kernel (one launch per read set),
    used when ``KERNEL_INTERPRET=0`` (real TPU); in interpret mode the
    per-tile Python interpreter would cost more than it saves, so the
    numpy path serves as the documented CPU fallback.

NOrec validates VALUES, not versions: ``validate_values`` re-reads each
``(addr, value)`` pair against the heap.
"""
from __future__ import annotations

import os
from typing import List, Optional

import numpy as np

V_LT = 0      # version <  r_clock   (Multiverse / DCTL encounter-time)
V_LE = 1      # version <= r_clock   (TL2 commit-time)
V_EQ = 2      # version == seen      (TinySTM exact snapshot)

#: read-set size at which the bulk path engages (env-tunable for benches)
BULK_MIN = int(os.environ.get("REPRO_BULK_VALIDATE_MIN", "256"))


def check_entry(st, seen: int, r_clock: int, tid: int, mode: int) -> bool:
    """One lock word against one read-set entry (the scalar predicate)."""
    if mode == V_LT:
        if st.locked:
            return st.tid == tid
        return not st.flag and st.version < r_clock
    if st.locked and st.tid != tid:
        return False
    return st.version <= r_clock if mode == V_LE else st.version == seen


def revalidate_scalar(locks, read_set: List[tuple], r_clock: int, tid: int,
                      mode: int) -> bool:
    """The word-at-a-time loop (exact historical behavior)."""
    for idx, seen in read_set:
        if not check_entry(locks.read(idx), seen, r_clock, tid, mode):
            return False
    return True


def np_validate(ver, own, meta, seen, r_clock: int, tid: int,
                mode: int) -> bool:
    """Vectorized predicate over gathered lock fields (numpy reference).

    ``meta`` bit0 = locked, bit1 = flag; ``own`` is the holder tid.  The
    same contract is implemented by the Pallas kernel — the kernel test
    asserts element-for-element agreement with this function.
    """
    locked = (meta & 1) != 0
    flagged = (meta & 2) != 0
    mine = locked & (own == tid)
    if mode == V_LT:
        ok = mine | (~locked & ~flagged & (ver < r_clock))
    elif mode == V_LE:
        ok = (~locked | mine) & (ver <= r_clock)
    else:
        ok = (~locked | mine) & (ver == seen)
    return bool(ok.all())


def revalidate_bulk(locks, read_set: List[tuple], r_clock: int, tid: int,
                    mode: int) -> Optional[bool]:
    """Bulk revalidation; ``None`` when the lock table cannot gather."""
    gather = getattr(locks, "gather", None)
    if gather is None:
        return None
    idxs = np.fromiter((e[0] for e in read_set), np.int64, len(read_set))
    seen = np.fromiter((e[1] for e in read_set), np.int64, len(read_set))
    ver, own, meta = gather(idxs)
    from repro.kernels import ops
    if not ops.INTERPRET:
        return bool(ops.validate_readset(ver, own, meta, seen, r_clock,
                                         tid, mode))
    return np_validate(ver, own, meta, seen, r_clock, tid, mode)


def revalidate(locks, read_set: List[tuple], r_clock: int, tid: int,
               mode: int, bulk_min: int = BULK_MIN) -> bool:
    """Scalar below ``bulk_min`` entries, bulk at/above it."""
    if len(read_set) >= bulk_min:
        ok = revalidate_bulk(locks, read_set, r_clock, tid, mode)
        if ok is not None:
            return ok
    return revalidate_scalar(locks, read_set, r_clock, tid, mode)


def validate_values(heap, read_vals: List[tuple]) -> bool:
    """NOrec value validation: every read value must still be in place."""
    for addr, val in read_vals:
        if heap[addr] != val:
            return False
    return True
