"""Group commit: publish batches of conflict-disjoint transactions at
one clock tick through the fused commit path.

After PR 5 a single commit is one batched pipeline; this module batches
ACROSS transactions.  The paper's serialization argument (and the
multi-version conflict notion it builds on) says transactions whose
conflict sets are disjoint serialize freely — so N ready commits whose
footprints do not overlap can share one atomicity bracket, one clock
tick and one publish sweep instead of N of each:

  * ``CommitBatcher.add`` collects ready transactions (engine ``_Tx``
    handles, substrate ``Txn`` wrappers or raw descriptors);
  * ``commit_all`` partitions them into conflict-disjoint groups via
    vectorized lock-index intersection (``partition_disjoint``).  The
    conflict rule is ``write_i ∩ (read_j ∪ write_j) = ∅`` for i != j —
    write-write AND write-read overlaps separate transactions; read-read
    overlap is harmless.  Write-set-only disjointness would be UNSOUND:
    two members each reading what the other writes have no serial order
    at a shared commit version;
  * each multi-member group publishes through the fused commit math
    (``kernels/commit_fused``): gather + verdict + claim under ONE
    hoisted stripe window (``ArrayLockTable.striped`` — the batched
    spelling of ``try_lock_bulk``'s CAS bracket), ONE
    ``clock.increment()``, one heap scatter for every surviving
    member's writes, one release sweep stamping the shared version.
    On CPU the in-file numpy twin (``np_commit_decide``) is the
    production verdict and the scatter goes through the in-place heap
    (the ``heap_scatter`` contract); with ``KERNEL_INTERPRET=0`` the
    whole publish is one ``ops.commit_fused`` megakernel launch over
    the device-resident row;
  * anything it cannot prove safe — colliding footprints, encounter
    descriptors holding locks mid-undo, irrevocable or versioned
    transactions, policies that never opted in — falls back to TODAY'S
    solo pipeline (``eng._try_commit``), so grouping is an optimization
    of the ready-batch case, never a semantic change
    (``tests/test_groupcommit.py`` pins group == solo results).

Ordering proof sketch for the buffered (TL2) group: the stripe window
makes verdict + claim atomic, which is at least as strong as solo TL2's
acquire-then-revalidate (both observe a state where every write lock is
held and every read entry validated at the member's own ``r_clock``).
``wv`` is fetched AFTER the claim — a reader beginning after the
increment sees either our locks or our released version ``wv <= its
r_clock`` with the new values, never a torn mix (the same GV4 argument
as the solo pipeline, hoisted over the group).  Failed members are
never claimed and never scattered: they abort individually with the
heap and their group-mates untouched.

Policies opt in via ``group_commit``: ``"buffered"`` (TL2 — full
claim + validate + scatter + stamp) or ``"encounter"`` (DCTL — locks
already held, so the group is one fused validation plus one release
sweep at the deferred clock's current value, the exact solo release).
"""
from __future__ import annotations

from itertools import chain
from typing import Any, List, Optional

import numpy as np

from repro.core.engine import commit as C
from repro.core.engine.errors import AbortTx
from repro.kernels.commit_fused import np_commit_decide, pack_segments
from repro.reliability import faultpoints as FP

__all__ = ["CommitBatcher", "ShardedCommitBatcher", "partition_disjoint"]


def partition_disjoint(write_sets: List[np.ndarray],
                       read_sets: List[np.ndarray]) -> List[List[int]]:
    """Partition into conflict-disjoint groups via one vectorized sweep.

    ``write_sets[i]`` / ``read_sets[i]`` are transaction ``i``'s lock
    indices (any order, within-transaction duplicates allowed — a hash
    collision within one transaction is one lock word claimed once).
    Conflict rule: ``write_i ∩ (read_j ∪ write_j) != ∅`` for ``i != j``
    — cross-transaction collisions on a lock word count even when the
    heap addresses differ, because colliding addresses share the word.

    Fast path (the expected batch): lock indices are table slots, so a
    dense ``bincount`` over the concatenated write indices finds any
    duplicate in O(batch + table) with no sort at all — zero duplicates
    means no write-write conflict is possible, and a dense owner map
    resolves the read probe with one fancy gather.  A batch with ANY
    repeated write index (cross-owner = a real conflict; within one
    transaction = a hash collision claiming one word once) or with
    indices too sparse for a dense table falls to one argsort sweep,
    and only a genuinely conflicted batch takes the quadratic first-fit
    fallback.  Singleton groups are committed solo by the batcher, so
    overlapping transactions degrade to exactly today's pipeline.
    """
    n = len(write_sets)
    if n == 0:
        return []
    sizes = np.fromiter((a.size for a in write_sets), np.int64, n)
    all_w = np.concatenate(write_sets)
    w_own = np.repeat(np.arange(n), sizes)
    conflict = None
    hi = int(all_w.max(initial=-1)) + 1
    if 0 <= hi <= (1 << 18) and int(all_w.min(initial=0)) >= 0:
        counts = np.bincount(all_w, minlength=hi)
        # dup check via a gather back through the batch — O(batch), not
        # a full-table scan
        if not (counts[all_w] > 1).any():
            conflict = False
            nz = [i for i, r in enumerate(read_sets) if r.size]
            if nz and all_w.size:
                # every written index is unique, so a dense last-writer
                # map IS the owner map
                own_map = np.empty(hi, np.int64)
                own_map[all_w] = w_own
                all_r = np.concatenate([read_sets[i] for i in nz])
                r_own = np.repeat(
                    np.asarray(nz, np.int64),
                    np.fromiter((read_sets[i].size for i in nz),
                                np.int64, len(nz)))
                inb = (all_r >= 0) & (all_r < hi)
                pos = np.where(inb, all_r, 0)
                hit = inb & (counts[pos] > 0)
                conflict = bool((hit & (own_map[pos] != r_own)).any())
    if conflict is None:
        # sparse or duplicated indices: one sort sweep.  Any equal-value
        # run spanning two owners yields SOME adjacent cross-owner pair
        # regardless of sort stability.
        order = np.argsort(all_w)
        sw, so = all_w[order], w_own[order]
        dup = sw[1:] == sw[:-1]
        conflict = bool((dup & (so[1:] != so[:-1])).any())
        if not conflict:
            nz = [i for i, r in enumerate(read_sets) if r.size]
            if nz and sw.size:
                all_r = np.concatenate([read_sets[i] for i in nz])
                r_own = np.repeat(
                    np.asarray(nz, np.int64),
                    np.fromiter((read_sets[i].size for i in nz),
                                np.int64, len(nz)))
                # no write-write conflict => each written value has one
                # owner, so any slot of its equal run identifies it
                pos = np.clip(np.searchsorted(sw, all_r), 0, sw.size - 1)
                hit = sw[pos] == all_r
                conflict = bool((hit & (so[pos] != r_own)).any())
    if not conflict:
        return [list(range(n))]

    # slow path: first-fit greedy over unique sets (conflicted batch)
    groups: List[dict] = []
    for i in range(n):
        w = np.unique(write_sets[i])
        rw = np.union1d(w, read_sets[i])
        placed = False
        for g in groups:
            if np.intersect1d(w, g["rw"], assume_unique=True).size:
                continue
            if np.intersect1d(rw, g["w"], assume_unique=True).size:
                continue
            g["members"].append(i)
            g["w"] = np.union1d(g["w"], w)
            g["rw"] = np.union1d(g["rw"], rw)
            placed = True
            break
        if not placed:
            groups.append({"members": [i], "w": w, "rw": rw})
    return [g["members"] for g in groups]


_EMPTY = np.zeros((0,), np.int64)


def _read_arrays(d):
    rs = d.read_set
    if not rs:
        return _EMPTY, _EMPTY
    idx = np.fromiter((p[0] for p in rs), np.int64, len(rs))
    seen = np.fromiter((p[1] for p in rs), np.int64, len(rs))
    return idx, seen


class CommitBatcher:
    """Collects ready transactions and commits them in disjoint groups.

    ``add`` accepts whatever the caller holds — an engine ``_Tx``, a
    substrate ``Txn`` or a raw descriptor; ``commit_all`` returns one
    bool per added transaction (add order): True committed, False
    aborted (the descriptor is rolled back; the caller owns the retry).
    ``stats`` counts how the batch split: ``grouped`` members published
    through fused group windows, ``solo`` through the fallback
    pipeline, ``groups`` fused windows executed, ``failed`` aborts.
    """

    def __init__(self, eng: Any):
        self.eng = getattr(eng, "raw", eng)   # unwrap WordSubstrate
        self._pending: List[Any] = []
        self.stats = {"grouped": 0, "solo": 0, "groups": 0, "failed": 0}

    def __len__(self) -> int:
        return len(self._pending)

    def add(self, tx: Any) -> None:
        self._pending.append(getattr(tx, "_ctx", tx))

    # -- eligibility ----------------------------------------------------
    def _groupable(self, d) -> Optional[str]:
        kind = getattr(self.eng.policy, "group_commit", None)
        if kind is None or not d.active or d.read_only:
            return None
        if getattr(d, "irrevocable", False) or d.versioned_write_set:
            return None
        if kind == "buffered":
            # pure buffered: no in-place state, no held locks — and a
            # lock table with the bulk window primitives (the scalar
            # table commits solo)
            if d.write_map and not d.undo and not d.locked_idxs \
                    and getattr(self.eng.locks, "striped", None) is not None:
                return kind
            return None
        if kind == "encounter":
            # in-place writes, locks already held; write_map would mean a
            # policy this module does not know — fall back
            if d.locked_idxs and not d.write_map \
                    and getattr(self.eng.locks, "gather", None) is not None:
                return kind
        return None

    # -- the entry point ------------------------------------------------
    def commit_all(self) -> List[bool]:
        eng = self.eng
        descs, self._pending = self._pending, []
        results: List[Optional[bool]] = [None] * len(descs)

        kind = None
        cand: List[int] = []
        for i, d in enumerate(descs):
            k = self._groupable(d)
            if k is not None and (kind is None or k == kind):
                kind = k
                cand.append(i)

        # extract each candidate's footprint ONCE — partition and the
        # group window share the same arrays (a second per-txn pass
        # would hand back most of the batching win).  Lock indices hash
        # in ONE index_bulk call over the whole batch and split back
        # into per-transaction views.
        groups: List[List[int]] = []
        preps: List[tuple] = []
        l_pack = None
        if len(cand) >= 2:
            arrayish = isinstance(getattr(eng.heap, "_buf", None),
                                  np.ndarray)
            if kind == "buffered":
                wms = [descs[i].write_map for i in cand]
                sizes = np.fromiter((len(wm) for wm in wms),
                                    np.int64, len(wms))
                total = int(sizes.sum())
                offs = [0] * (len(wms) + 1)
                for k, wm in enumerate(wms):
                    offs[k + 1] = offs[k] + len(wm)
                # hand-rolled view slicing: np.split routes through
                # array_split/swapaxes and costs real time at this size
                cut = lambda a: [a[offs[k]:offs[k + 1]]          # noqa: E731
                                 for k in range(len(wms))]
                # ONE fromiter over the chained dicts, split into
                # per-transaction views — per-dict fromiter calls cost
                # about twice as much at typical write-set sizes
                all_addr = np.fromiter(
                    chain.from_iterable(wms), np.int64, total)
                w_addrs = cut(all_addr)
                if arrayish:
                    # int64 heap: values as one array now, so the
                    # publish sweep is one concatenate + one fancy
                    # scatter (object heaps keep the list form)
                    w_valss = cut(np.fromiter(
                        chain.from_iterable(wm.values() for wm in wms),
                        np.int64, total))
                else:
                    w_valss = [list(wm.values()) for wm in wms]
                all_l = eng.locks.index_bulk(all_addr)
                l_sets = cut(all_l)
            else:
                w_addrs = w_valss = None
                all_l = sizes = None
                l_sets = [C.held_write_indices(eng, descs[i])
                          for i in cand]
            for k, i in enumerate(cand):
                d = descs[i]
                r_idx, r_seen = _read_arrays(d)
                preps.append((d,
                              w_addrs[k] if w_addrs is not None else None,
                              w_valss[k] if w_valss is not None else None,
                              l_sets[k], r_idx, r_seen))
            groups = partition_disjoint(
                [p[3] for p in preps], [p[4] for p in preps])
            if (all_l is not None and len(groups) == 1
                    and len(groups[0]) == len(preps)):
                # the whole batch formed one group: its flat lock batch
                # is exactly the one we already hashed — skip the repack
                l_pack = (all_l,
                          np.repeat(np.arange(len(preps), dtype=np.int64),
                                    sizes))

        solo = set(range(len(descs)))
        for members in groups:
            if len(members) < 2:
                continue                       # singleton: solo fallback
            gp = [preps[m] for m in members]
            ok = (self._commit_group_buffered(gp, l_pack)
                  if kind == "buffered"
                  else self._commit_group_encounter(gp))
            self.stats["grouped"] += len(gp)
            self.stats["groups"] += 1
            for m, okd in zip(members, ok):
                results[cand[m]] = bool(okd)
                solo.discard(cand[m])

        for i in sorted(solo):
            d = descs[i]
            self.stats["solo"] += 1
            try:
                eng._try_commit(d)
                results[i] = True
            except AbortTx:
                results[i] = False
        out = [bool(r) for r in results]
        self.stats["failed"] += sum(1 for r in out if not r)
        return out

    # -- buffered (TL2-style) group window ------------------------------
    def _commit_group_buffered(self, gp, l_pack=None) -> np.ndarray:
        eng = self.eng
        locks = eng.locks
        mode = eng.policy.validate_mode
        group = [p[0] for p in gp]
        w_addrs = [p[1] for p in gp]
        w_vals = [p[2] for p in gp]
        if l_pack is not None:
            l_flat, l_seg = l_pack
        else:
            l_flat, l_seg, _ = pack_segments([p[3] for p in gp])
        r_flat, r_seg, _ = pack_segments([p[4] for p in gp])
        tids = np.fromiter((d.tid for d in group), np.int64, len(group))

        from repro.core.engine.arrayheap import (_TID_BIAS, _TID_MASK,
                                                 _UNLOCKED_WORD,
                                                 _VER_SHIFT)

        # durable group commit: ONE buffered append carries every
        # member's PREPARE frame, landed BEFORE the claim window (the
        # append-before-claim invariant); the single fsync'd group
        # DECIDE below covers the whole batch
        wal = eng.wal
        if wal is not None:
            lsns = wal.append_prepare_group(
                [(int(d.tid), a, v, (eng.clock.load(),), -1, -1)
                 for d, a, v in zip(group, w_addrs, w_vals)])
            for d, lsn in zip(group, lsns):
                d.wal_lsn = lsn

        # ONE hoisted CAS window for verdict + claim + tick + publish +
        # release: the group analogue of try_lock_bulk's
        # gather/check/scatter under held stripes.  Solo TL2 pays two
        # stripe sweeps (acquire, release-at-wv); the group window pays
        # ONE and holds it through the heap scatter instead.  That is a
        # concurrency trade, not a correctness one — the claim words
        # already serialize every conflicting commit for the same span,
        # so the longer hold only delays transactions that merely share
        # a stripe, and buys back a full for_indices + acquire sweep.
        with locks.striped(l_flat):
            l_words = locks.words_at(l_flat)
            r_seen = None
            if r_flat.size == 0 and not (l_words & 3).any():
                # fast verdict: no reads to validate and every write
                # word free + unflagged means claimable for ANY owner —
                # algebraically the same answer np_commit_decide gives
                # (claimable = ~((locked|flagged) & ~own) with
                # locked = flagged = False), minus the field unpack
                ok = np.ones(len(group), bool)
                all_ok = any_ok = True
            else:
                def fields(words):
                    ver = words >> _VER_SHIFT
                    own = (((words >> 2) & _TID_MASK)
                           - _TID_BIAS).astype(np.int32)
                    meta = (((words >> 1) & 1)
                            | ((words & 1) << 1)).astype(np.int32)
                    return ver, own, meta

                r_seen = (np.concatenate([p[5] for p in gp]) if gp
                          else np.zeros((0,), np.int64))
                rcs = np.fromiter((d.r_clock for d in group),
                                  np.int64, len(group))
                r_words = locks.words_at(r_flat)
                lv, lo, lm = fields(l_words)
                rv, ro, rm = fields(r_words)
                ok = np_commit_decide(lv, lo, lm, l_seg, rv, ro, rm,
                                      r_seen, r_seg, tids, rcs,
                                      len(group), mode)
                all_ok = bool(ok.all())
                any_ok = all_ok or bool(ok[l_seg].any())
            if any_ok:
                if FP.ACTIVE is not None:
                    FP.fire("pre_claim", int(tids[0]))
                if all_ok:
                    claim = l_flat
                    locks.store_words(
                        claim, locks.claim_words(l_words, tids[l_seg]))
                else:
                    sel = ok[l_seg]
                    claim = l_flat[sel]
                    locks.store_words(
                        claim,
                        locks.claim_words(l_words[sel], tids[l_seg[sel]]))
                if FP.ACTIVE is not None:
                    FP.fire("post_claim", int(tids[0]))
                    FP.fire("pre_clock_tick", int(tids[0]))
            # ONE tick for the whole group — fetched AFTER the claim,
            # the same GV4 ordering the solo pipeline pins (module
            # docstring)
            wv = eng.clock.increment()
            if any_ok:
                if FP.ACTIVE is not None:
                    FP.fire("pre_scatter", int(tids[0]))
                # group commit record: every surviving member is decided
                # and about to publish — a crash from here rolls them
                # all FORWARD (recovery.recover_engine); ONE fsync'd
                # group DECIDE makes the whole batch durable first
                if wal is not None:
                    wal.append_decide_group(
                        [d.wal_lsn for d, okd in zip(group, ok)
                         if okd and d.wal_lsn is not None])
                for d, okd in zip(group, ok):
                    if okd:
                        d.publish_started = True
                self._publish(group, ok, all_ok, w_addrs, w_vals,
                              l_flat, l_seg, r_flat, r_seg, r_seen,
                              tids, None, wv, mode)
                if FP.ACTIVE is not None:
                    FP.fire("post_scatter", int(tids[0]))
                    FP.fire("pre_release", int(tids[0]))
                # release-at-wv is a raw scatter: the stripes are still
                # held and every claimed word is ours
                locks.store_words(
                    claim,
                    np.int64((wv << _VER_SHIFT) | _UNLOCKED_WORD))
        if wal is not None:
            for d, okd in zip(group, ok):
                if okd and d.wal_lsn is not None:
                    wal.append_complete(d.wal_lsn)
                d.wal_lsn = None    # losers: abandoned prepare = rollback
        self._bookkeep(group, ok)
        return ok

    def _publish(self, group, ok, all_ok, w_addrs, w_vals, l_flat, l_seg,
                 r_flat, r_seg, r_seen, tids, rcs, wv, mode) -> None:
        """Scatter every surviving member's writes in one sweep.

        CPU production: one in-place ``heap_scatter`` (the heap IS the
        numpy buffer — ``engine/commit.heap_scatter``'s contract).
        ``KERNEL_INTERPRET=0``: the full ``ops.commit_fused`` megakernel
        over the device row — validate + claim-check + scatter + stamp
        in one launch (the claim words read as locked-by-owner, so the
        in-kernel verdict reproduces ``ok`` exactly), then only the
        touched addresses copy back into the host mirror.
        """
        eng = self.eng
        from repro.kernels import ops
        sel_addrs = (w_addrs if all_ok
                     else [a for a, okd in zip(w_addrs, ok) if okd])
        addrs = (np.concatenate(sel_addrs) if sel_addrs
                 else np.zeros((0,), np.int64))
        if not addrs.size:
            return
        if not ops.INTERPRET and getattr(eng.heap, "jnp", None) is not None:
            w_flat, w_seg, _ = pack_segments(w_addrs)
            vals = np.concatenate(
                [np.asarray(v, np.int64) for v in w_vals])
            locks = eng.locks
            if r_seen is None:          # fast-verdict window: no reads
                r_seen = np.zeros((0,), np.int64)
            if rcs is None:
                rcs = np.fromiter((d.r_clock for d in group),
                                  np.int64, len(group))
            new_row, k_ok, _ = ops.commit_fused(
                eng.heap.jnp(), w_flat, vals, w_seg,
                locks.words_at(l_flat), l_seg,
                locks.words_at(r_flat), r_seen, r_seg,
                tids, rcs, wv, len(group), mode=mode)
            eng.heap.scatter(addrs, np.asarray(new_row)[addrs])
            return
        sel_vals = (w_vals if all_ok
                    else [v for v, okd in zip(w_vals, ok) if okd])
        if isinstance(sel_vals[0], np.ndarray):
            vals = np.concatenate(sel_vals)
        else:
            vals = []
            for vs in sel_vals:
                vals.extend(vs)
        C.heap_scatter(eng.heap, addrs, vals, tid=int(tids[0]))

    # -- encounter (DCTL-style) group window ----------------------------
    def _commit_group_encounter(self, gp) -> np.ndarray:
        """Locks are already held, writes already in place: the group is
        one fused read-set validation plus one release sweep at the
        deferred clock's CURRENT value — exactly the solo release
        (``DCTLPolicy.commit_update``), batched.  Failed members roll
        back individually (undo restore + deferred-clock bump) with
        their disjoint group-mates' words untouched."""
        eng = self.eng
        mode = eng.policy.validate_mode
        group = [p[0] for p in gp]
        l_sets = [p[3] for p in gp]
        r_flat, r_seg, _ = pack_segments([p[4] for p in gp])
        r_seen = (np.concatenate([p[5] for p in gp]) if gp
                  else np.zeros((0,), np.int64))
        tids = np.fromiter((d.tid for d in group), np.int64, len(group))
        rcs = np.fromiter((d.r_clock for d in group), np.int64, len(group))
        ver, own, meta = eng.locks.gather(r_flat)
        z = np.zeros((0,), np.int64)
        ok = np_commit_decide(z, z, z, z, ver, own, meta, r_seen, r_seg,
                              tids, rcs, len(group), mode)
        sel_l = [ls for ls, okd in zip(l_sets, ok) if okd]
        if sel_l:
            if FP.ACTIVE is not None:
                FP.fire("pre_clock_tick", int(tids[0]))
            cv = eng.clock.load()
            # encounter group commit record: the heap already holds the
            # surviving members' values — crash from here rolls forward.
            # Durable twin: redo images gathered from the locked heap
            # words, one buffered prepare-group + one fsync'd DECIDE
            wal = eng.wal
            if wal is not None:
                recs, owners = [], []
                for d, okd in zip(group, ok):
                    if not okd or not d.undo:
                        continue
                    a = list(d.undo.keys())
                    recs.append((int(d.tid), a,
                                 [eng.heap[x] for x in a], (cv,), -1, -1))
                    owners.append(d)
                if recs:
                    lsns = wal.append_prepare_group(recs)
                    for d, lsn in zip(owners, lsns):
                        d.wal_lsn = lsn
                    wal.append_decide_group(lsns)
            for d, okd in zip(group, ok):
                if okd:
                    d.publish_started = True
            if FP.ACTIVE is not None:
                FP.fire("pre_release", int(tids[0]))
            eng.locks.unlock_bulk(np.concatenate(sel_l), cv)
            if wal is not None:
                for d, okd in zip(group, ok):
                    if okd and d.wal_lsn is not None:
                        wal.append_complete(d.wal_lsn)
                    d.wal_lsn = None
        self._bookkeep(group, ok, clear_locked=True)
        return ok

    # -- shared epilogue ------------------------------------------------
    def _bookkeep(self, group, ok: np.ndarray,
                  clear_locked: bool = False) -> None:
        eng = self.eng
        for d, okd in zip(group, ok):
            if okd:
                if clear_locked:
                    d.locked_idxs.clear()
                d.stats["commits"] += 1
                d.active = False
                eng.policy.on_finish(eng, d)
            else:
                eng._abort(d)


class ShardedCommitBatcher:
    """Group commit over the SHARDED store: one shard-local publish per
    batch of blind single-shard writers.

    ``add`` collects ready ``ShardStoreHandle`` transactions;
    ``commit_all`` buckets the BLIND writers (no reads anywhere, writes
    confined to one shard — the write-only ingest shape) per shard, and
    each bucket whose write addresses are pairwise disjoint publishes
    through ONE ``MVStoreHandle._publish_locked`` — one clock tick, one
    fused scatter for the whole bucket, the store-level analogue of
    ``CommitBatcher``'s fused group window.

    SOUNDNESS: a blind write-only transaction carries no reads, so any
    serial order of disjoint-address blind writers from the same base
    state yields the same final state — the merged single-tick publish
    IS such an order.  This is deliberately a RELAXATION of the solo
    path (which aborts the second writer at block granularity and
    retries); it admits more schedules, all serializable.  Anything
    outside the shape — any read, multi-shard writes, overlapping
    addresses, versioned or inactive contexts — falls back to
    ``store.commit`` solo, so the batcher is an optimization of the
    write-only ingest case, never of validation.
    """

    def __init__(self, store: Any):
        self.store = store
        self._pending: List[Any] = []
        self.stats = {"grouped": 0, "solo": 0, "groups": 0, "failed": 0}

    def __len__(self) -> int:
        return len(self._pending)

    def add(self, tx: Any) -> None:
        self._pending.append(getattr(tx, "_ctx", tx))

    def commit_all(self) -> List[bool]:
        from repro.api.substrate import Txn
        store = self.store
        ctxs, self._pending = self._pending, []
        results: List[Any] = [None] * len(ctxs)

        by_shard: dict = {}
        solo: List[int] = []
        for i, ctx in enumerate(ctxs):
            ws = [s for s, c in enumerate(ctx.subs) if c.write_buf]
            blind = (ctx.active and len(ws) == 1
                     and not any(c.read_cnt or c.versioned
                                 for c in ctx.subs))
            if blind:
                by_shard.setdefault(ws[0], []).append(i)
            else:
                solo.append(i)

        for s, members in sorted(by_shard.items()):
            if len(members) < 2:
                solo.extend(members)
                continue
            # pairwise address-disjointness in one concatenated unique
            # sweep; an overlapping bucket degrades member-by-member
            merged: dict = {}
            grouped: List[int] = []
            for i in members:
                wb = ctxs[i].subs[s].write_buf
                if any(a in merged for a in wb):
                    solo.append(i)
                    continue
                merged.update(wb)
                grouped.append(i)
            if len(grouped) < 2:
                solo.extend(grouped)
                continue
            shard = store._shards[s]
            with shard._commit_lock:
                g = type(ctxs[grouped[0]].subs[s])(ctxs[grouped[0]].tid)
                g.read_clock = int(shard._state.clock)
                g.read_only = False
                g.write_buf = merged
                shard._publish_locked(g)
            for i in grouped:
                store._counters[ctxs[i].tid]["commits"] += 1
                shard._readers[ctxs[i].tid].attempts = 0
                store._deactivate(ctxs[i])
                results[i] = True
            self.stats["grouped"] += len(grouped)
            self.stats["groups"] += 1

        for i in sorted(solo):
            ctx = ctxs[i]
            self.stats["solo"] += 1
            try:
                store.commit(Txn(store, ctx, ctx.tid))
                results[i] = True
            except AbortTx:
                results[i] = False
        out = [bool(r) for r in results]
        self.stats["failed"] += sum(1 for r in out if not r)
        return out
