"""Transaction descriptor: the one per-thread context every backend shares.

The paper's Alg. 1 thread-locals and the baselines' contexts were two
parallel class hierarchies (``stm._TxCtx`` vs ``baselines._Ctx``) holding
the same state under different names.  ``TxnDescriptor`` is their union:

  * ``read_set``   — ``(lock_idx, version_seen)`` pairs for commit-time
                     revalidation (lock-version backends);
  * ``read_vals``  — ``(addr, value)`` pairs for value validation (NOrec);
  * ``write_map``  — buffered writes (addr -> new value; TL2/NOrec);
  * ``locked_idxs``— the set of encounter-time-locked LOCK INDICES (DCTL
                     family; irrevocable read-locks land here too) —
                     kept separate from ``write_map`` so the commit
                     pipeline's release paths always deal in deduped
                     indices, never raw addresses (``engine/commit.py``
                     normalization note);
  * ``undo``       — in-place write undo log (addr -> old value) for
                     encounter-time backends, including Multiverse;
  * ``versioned_write_set`` — addr -> (vlist, node) for TBD-version
                     rollback (Multiverse only);
  * ``alloc_log``  — txn-local allocations, freed by the engine on abort.

State lifetimes (paper Alg. 1 l.10): ``reset()`` clears per-ATTEMPT state
before each retry; ``reset_operation()`` additionally clears state that
persists across retries of one logical operation (attempt count, the K1
versioned flag, its livelock guard).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.core import modes as M

#: per-descriptor counters the engine aggregates into the stats schema
COUNTER_KEYS = ("commits", "aborts", "versioned_commits", "ro_commits",
                "mode_cas")


class TxnDescriptor:
    __slots__ = (
        "tid", "attempts", "active", "stats",
        # per-attempt
        "r_clock", "read_only", "read_cnt", "read_set", "read_vals",
        "write_map", "locked_idxs", "undo", "versioned_write_set",
        "alloc_log", "local_mode_counter", "local_mode",
        "dedup_read_set", "read_set_seen", "publish_started", "wal_lsn",
        # per-operation (survive retries)
        "versioned", "no_versioning", "initial_versioned_ts", "irrevocable")

    def __init__(self, tid: int):
        self.tid = tid
        self.attempts = 0
        self.active = False
        self.versioned = False
        self.no_versioning = False
        self.irrevocable = False
        self.initial_versioned_ts: Optional[int] = None
        self.stats = {k: 0 for k in COUNTER_KEYS}
        self.reset()

    def reset(self) -> None:
        """Per-attempt reset (called by the engine at ``begin``)."""
        self.r_clock = 0
        self.read_only = True
        self.read_cnt = 0
        self.local_mode_counter = 0
        self.local_mode = M.MODE_Q
        self.read_set: List[tuple] = []
        self.read_vals: List[tuple] = []
        self.write_map: Dict[int, Any] = {}
        self.locked_idxs: set = set()
        self.undo: Dict[int, Any] = {}
        self.versioned_write_set: Dict[int, tuple] = {}
        self.alloc_log: List[tuple] = []
        # traversal-level read-set dedup (engine/traverse.py): while set,
        # the bulk read path skips (lock_idx, version) pairs already
        # tracked — repeated frontier visits stop inflating commit-time
        # revalidation
        self.dedup_read_set = False
        self.read_set_seen: set = set()
        # commit record for crash recovery (reliability/): set once the
        # commit DECIDED and heap publication is about to begin — after a
        # crash, True means roll FORWARD from write_map, False means roll
        # back from undo
        self.publish_started = False
        # the durable twin (reliability/wal.py): lsn of this attempt's
        # WAL PREPARE; an abandoned prepare (abort/crash before DECIDE)
        # simply never replays
        self.wal_lsn: Optional[int] = None

    def reset_operation(self) -> None:
        """Per-operation reset (a NEW logical operation, not a retry)."""
        self.attempts = 0
        self.versioned = False
        self.no_versioning = False
        self.initial_versioned_ts = None

    @property
    def has_writes(self) -> bool:
        return bool(self.write_map or self.locked_idxs or self.undo
                    or self.versioned_write_set)
