"""Batched transactional reads (`Txn.read_bulk`) for lock-version policies.

The paper's long-running read-only transactions scan thousands of words;
word-at-a-time through Python, the scan measures the interpreter rather
than the TM.  This module is the engine-level batch: ONE heap gather
bracketed by TWO consistent lock-word gathers, then a vectorized
stability predicate — so a long read snapshots its whole batch in a
handful of array ops (numpy on CPU, the ``kernels/gather_read.py`` /
``kernels/validate.py`` Pallas launches on TPU via ``KERNEL_INTERPRET=0``).

Soundness argument, per element ``i``:

  * ``pre``/``post`` are consistent (locked, version, tid, flag) tuples —
    the lock table packs each word into one int64, gathered in one
    fancy-index (``ArrayLockTable.gather``), so no field tearing;
  * if ``pre.version == post.version``, both unlocked and unflagged, the
    heap word cannot have been mutated between the two gathers: every
    writer in the lock-version family locks the word before touching data
    and republishes a bumped version on release;
  * ``version <(=) r_clock`` then places the stable value at/before the
    transaction's snapshot — exactly the scalar read's validation, so an
    accepted element is indistinguishable from a scalar read of the same
    address at the same point.

Elements that FAIL the predicate (locked, flagged, version too new, or
torn between the gathers) are NOT errors: the caller re-reads just those
through the policy's scalar path, which spins/extends/aborts with the
policy's exact semantics.  The batch is an optimization of the common
case (a quiescent majority), never a semantic change.

Multiverse's VERSIONED readers (paper SS3.1/SS4.2) add a vectorized
middle tier between the batch and the scalar walk: the failed elements
are precisely the recently-written words a versioned reader serves from
version lists, and the packed VLT mirror (``core/vlt.py`` —
per-lock-index int64 rows of the newest committed ``(timestamp, data)``
pairs, seqlock-bracketed) resolves them in ONE ``PackedVLT.select``
gather — ``np_version_select`` on CPU, the
``kernels/version_select.py`` Pallas kernel when ``KERNEL_INTERPRET=0``
— so the Mode-U/Q hybrid bulk read (``MultiversePolicy.read_bulk`` →
``_bulk_versioned_gather``) only falls through to the per-word
version-list traversal for what the mirror cannot represent (colliding
buckets, non-int payloads, torn rows, versions deeper than the mirror).

Own writes: encounter-time policies (DCTL/TinySTM/Multiverse) see their
in-place values in the heap gather already, but those addresses skip
validation and the read set (the scalar paths return them early);
buffered-write policies (TL2/NOrec) overlay ``write_map`` on the result.
"""
from __future__ import annotations

from typing import Any, Sequence

import numpy as np

__all__ = ["as_addr_array", "bulk_read_lockver", "finish_with_scalar",
           "gather_row", "heap_gather", "shard_partition"]


def shard_partition(shard_ids: np.ndarray, n_shards: int):
    """Group a routed address batch by shard: ``[(sid, positions)]``.

    ``shard_ids[i]`` is the shard owning batch element ``i``
    (``0 <= sid < n_shards``).  Returns one entry per shard actually
    present, ``positions`` ascending (stable sort), so the caller runs
    ONE gather/scatter per shard and reassembles order-preserving with
    ``out[positions] = shard_vals`` — the routing layer between a
    cross-shard bulk op and the per-shard kernel launches.
    """
    sid = np.asarray(shard_ids, np.int64)
    order = np.argsort(sid, kind="stable")
    bounds = np.searchsorted(sid[order], np.arange(n_shards + 1))
    return [(s, order[bounds[s]:bounds[s + 1]])
            for s in range(n_shards) if bounds[s] < bounds[s + 1]]


def as_addr_array(addrs: Sequence[int]) -> np.ndarray:
    """Normalize any address batch (range, list, ndarray) to int64[N]."""
    if isinstance(addrs, np.ndarray):
        return addrs.astype(np.int64, copy=False)
    if isinstance(addrs, range):
        return np.arange(addrs.start, addrs.stop, addrs.step, np.int64)
    return np.fromiter((int(a) for a in addrs), np.int64)


def gather_row(row, addrs: np.ndarray) -> np.ndarray:
    """``row[addrs]`` with the kernel dispatch, for any 1-D value row.

    Fancy-index on CPU; one ``ops.snapshot_read`` (gather_read kernel)
    launch when ``KERNEL_INTERPRET=0``.  The single home of the bounds
    contract on the kernel path: numpy raises on an out-of-range address
    while ``jnp.take`` would CLAMP it to the last word, so the guard
    keeps both paths raising identically.  Serves the word-level array
    heap AND the MVStore live-block / ring-row gathers.
    """
    from repro.kernels import ops
    if not ops.INTERPRET:
        if addrs.size and int(addrs.max(initial=0)) >= row.shape[0]:
            raise IndexError(int(addrs.max()))
        return np.asarray(ops.snapshot_read(row, addrs))
    if isinstance(row, np.ndarray):
        return row[addrs]
    if hasattr(row, "shape"):
        # device-resident (jax) row: gather ON DEVICE and materialize
        # only the batch — ``np.asarray(row)[addrs]`` would host-copy
        # the whole row per call.  jnp fancy-indexing CLAMPS instead of
        # raising, so the bounds contract needs the explicit guard.
        if addrs.size and (int(addrs.max(initial=0)) >= row.shape[0]
                           or int(addrs.min(initial=0)) < 0):
            raise IndexError(int(addrs.max()))
        return np.asarray(row[addrs])
    return np.asarray(row)[addrs]


def heap_gather(heap, addrs: np.ndarray):
    """``heap[addrs]`` in one pass.

    ``ArrayHeap`` answers with a single fancy-index (one ``gather_row``
    kernel launch over ``heap.jnp()`` on TPU); ``ObjectHeap`` with one
    list pass; anything else falls back to scalar indexing.  Returns
    ndarray (array heaps) or list (object heaps).
    """
    g = getattr(heap, "gather", None)
    if g is None:
        return [heap[int(a)] for a in addrs]
    if getattr(heap, "jnp", None) is not None:
        from repro.kernels import ops
        if not ops.INTERPRET:      # real TPU: one gather_read launch
            return gather_row(heap.jnp(), addrs)
    return g(addrs)


def bulk_read_lockver(eng, d, addrs: np.ndarray, *, inclusive: bool,
                      track: bool = True):
    """One batched read attempt against the lock-version protocol.

    ``inclusive`` selects the version predicate for NEW reads:
    ``version <= r_clock`` (TL2/TinySTM-style clocks, bumped on commit
    only) vs strict ``<`` (the Multiverse/DCTL deferred clock, where the
    commit in flight at ``r_clock`` may still be publishing).  ``track``
    appends accepted entries to ``d.read_set`` for commit-time
    revalidation — versioned Multiverse readers pass ``track=False``
    (they read the past; there is nothing to revalidate at commit).

    Returns ``(values, ok)``: ``values`` is the gathered batch (ndarray
    or list), ``ok`` a bool[N] mask; ``values[i]`` is only meaningful
    where ``ok[i]``.  Own in-place writes (``addr in d.undo``) are
    accepted as-is, unvalidated and untracked, like the scalar paths.
    """
    locks = eng.locks
    idxs = locks.index_bulk(addrs)
    ver1, _, meta1 = locks.gather(idxs)
    vals = heap_gather(eng.heap, addrs)
    ver2, _, meta2 = locks.gather(idxs)
    # locked-by-me also fails here: the scalar fallback resolves own locks
    # exactly (and encounter-time policies reach own writes via d.undo)
    stable = ver1 == ver2
    locked = ((meta1 | meta2) & 1) != 0
    flagged = ((meta1 | meta2) & 2) != 0
    if inclusive:
        ok = ~locked & ~flagged & stable & (ver1 <= d.r_clock)
    else:
        ok = ~locked & ~flagged & stable & (ver1 < d.r_clock)
    if d.undo:
        own = np.fromiter(d.undo.keys(), np.int64, len(d.undo))
        own_mask = np.isin(addrs, own)
        ok = ok | own_mask
    else:
        own_mask = None
    if track:
        accept = ok if own_mask is None else (ok & ~own_mask)
        sel = np.nonzero(accept)[0]
        pairs = zip(idxs[sel].tolist(), ver1[sel].tolist())
        if d.dedup_read_set:
            # traversal-level dedup (engine/traverse.py sets the flag):
            # a repeated frontier visit re-proves the same (idx, version)
            # pair — appending it again only inflates commit-time
            # revalidation.  Pairs are deduped, not bare indices: the
            # same index at a DIFFERENT version must still be tracked
            # (V_EQ revalidates against the version seen).
            seen = d.read_set_seen
            rs = d.read_set
            for p in pairs:
                if p not in seen:
                    seen.add(p)
                    rs.append(p)
        else:
            d.read_set.extend(pairs)
    return vals, ok


def finish_with_scalar(eng, d, addrs: np.ndarray, vals, ok, scalar_read):
    """Materialize the batch result: accepted elements from the gather,
    everything else re-read through ``scalar_read(eng, d, addr)`` (which
    spins / extends / aborts with the policy's exact semantics).  Returns
    the gathered ndarray untouched on a clean batch (the fast path the
    eval scans sum over), a list when any element was re-read."""
    if bool(ok.all()):
        return vals
    out = vals if isinstance(vals, list) else vals.tolist()
    for i in np.nonzero(~ok)[0]:
        out[i] = scalar_read(eng, d, int(addrs[i]))
    return out
