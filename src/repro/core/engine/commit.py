"""Commit pipeline helpers: lock-acquire, write-back, version-publish.

The begin/read/write/commit scaffolding the backends used to copy-paste
lives here as policy-agnostic steps over an engine:

  * buffered (TL2-style) commits: ``acquire_write_locks`` then
    ``write_back`` then ``release_locks`` at the new write version;
  * encounter-time (DCTL-style) commits: locks are already held, so the
    pipeline is revalidate + ``release_locks`` at the commit clock;
  * encounter-time aborts: ``rollback_inplace`` restores the undo log and
    releases the held locks at a bumped clock (the deferred-clock abort
    increment that keeps readers from missing the rollback).

Every helper takes the engine explicitly — policies stay ~50-line
stateless-ish objects and the engine stays the single owner of heap,
clock and lock table.
"""
from __future__ import annotations

from typing import Iterable, List, Optional


def acquire_write_locks(eng, d) -> List[int]:
    """Claim every buffered write's lock (commit-time locking).

    On conflict, releases whatever was acquired (versions untouched) and
    aborts the transaction.  Returns the locked indices in acquisition
    order, deduplicated.
    """
    locked: List[int] = []
    for addr in d.write_map:
        idx = eng.locks.index(addr)
        st = eng.locks.read(idx)
        if not eng.locks.try_lock(idx, st, d.tid):
            release_locks(eng, locked)
            eng.abort_txn(d)
        if idx not in locked:
            locked.append(idx)
    return locked


def write_back(eng, d) -> None:
    """Publish buffered writes to the heap (caller holds the locks)."""
    for addr, value in d.write_map.items():
        eng.heap[addr] = value


def release_locks(eng, idxs: Iterable[int],
                  version: Optional[int] = None) -> None:
    for idx in idxs:
        eng.locks.unlock(idx, version)


def rollback_inplace(eng, d, bump_clock: bool = True) -> None:
    """Undo encounter-time in-place writes and release the held locks.

    ``bump_clock`` implements the deferred clock's abort increment: the
    released locks are republished at a FRESH version so any reader that
    validated against the uncommitted value must revalidate and abort.
    """
    for addr, old in d.undo.items():
        eng.heap[addr] = old
    nxt = eng.clock.increment() if bump_clock else None
    release_locks(eng, d.write_map, nxt)
