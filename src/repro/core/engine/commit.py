"""Commit pipeline: batched lock-acquire, write-back, version-publish.

The begin/read/write/commit scaffolding the backends used to copy-paste
lives here as policy-agnostic steps over an engine:

  * buffered (TL2-style) commits: ``acquire_write_locks`` then
    ``write_back`` then ``release_locks`` at the new write version;
  * encounter-time (DCTL-style) commits: locks are already held, so the
    pipeline is revalidate + ``release_locks`` at the commit clock;
  * encounter-time aborts: ``rollback_inplace`` restores the undo log and
    releases the held locks at a bumped clock (the deferred-clock abort
    increment that keeps readers from missing the rollback).

Since PR 5 every step is BATCHED at write sets >= ``BULK_MIN``,
mirroring the ``read_bulk`` architecture: the lock claims become one
``ArrayLockTable.try_lock_bulk`` CAS sweep (all-or-nothing — on
conflict NOTHING was acquired, so there is no partial-hold window),
write-back and undo-restore become one heap ``scatter`` (a fancy-index
assignment on the in-place numpy heaps; the
``kernels/scatter_write.py`` Pallas kernel serves the FUNCTIONAL rows
via ``scatter_row`` — the MVStore commit's device-side block), and
lock release becomes one
``unlock_bulk`` sweep.  Below the threshold the exact historical scalar
loops run; the batch is an optimization of the common update-heavy
case, never a semantic change (``tests/test_commit_bulk.py`` pins
bulk == scalar on every backend).

LOCK-INDEX NORMALIZATION: every release path here deals in DEDUPED lock
indices, never raw heap addresses.  Two addresses can collide into one
lock word (the tables are hash-indexed), and releasing per-address
unlocks that word TWICE — after the first release another thread can
legitimately claim it, and the second release stomps their lock.
``held_write_indices`` is the single home of the address->index
normalization: the undo log's addresses through ``locks.index`` plus
the policy's explicit encounter-time index set (``d.locked_idxs`` —
irrevocable read-locks ride there), deduplicated.  ``rollback_inplace``
historically iterated ``d.write_map`` instead, which only worked
because the DCTL family happened to key it by index; the contract is
now explicit and collision-safe for any policy.

Every helper takes the engine explicitly — policies stay ~50-line
stateless-ish objects and the engine stays the single owner of heap,
clock and lock table.
"""
from __future__ import annotations

import contextlib
from typing import Iterable, List, Optional

import numpy as np

from repro.core.engine.validation import BULK_MIN
from repro.reliability import faultpoints as FP


# ---------------------------------------------------------------------------
# shared vector helpers
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def acquire_ascending(locks):
    """Hold several commit locks at once, released in reverse order.

    The caller passes the locks already sorted by a global total order
    (shard id for the sharded store) — the same ascending discipline
    ``Striped.for_indices`` uses for lock-table stripes, lifted to whole
    commit locks, so two cross-shard commits with overlapping footprints
    can never deadlock.  Unwind (including a simulated crash) releases
    whatever was acquired: lock state models hardware mutexes, which the
    fault-injection contract says still clean up.
    """
    held = []
    try:
        for lk in locks:
            lk.acquire()
            held.append(lk)
        yield
    finally:
        for lk in reversed(held):
            lk.release()


def addr_lock_indices(eng, addrs: Iterable[int]) -> np.ndarray:
    """Heap addresses -> DEDUPED ascending lock indices.

    The normalization every bulk acquire/release shares: vectorized
    through ``index_bulk`` when the lock table has it, the scalar
    ``index`` loop otherwise; ``np.unique`` collapses colliding
    addresses to one claim/release per lock word.
    """
    # materialize first: np.fromiter(..., count=len(...)) needs a sized
    # iterable, and callers legitimately pass generators
    if not hasattr(addrs, "__len__"):
        addrs = list(addrs)
    a = np.fromiter((int(x) for x in addrs), np.int64, len(addrs))
    index_bulk = getattr(eng.locks, "index_bulk", None)
    if index_bulk is not None:
        return np.unique(index_bulk(a))
    return np.unique(np.fromiter((eng.locks.index(int(x)) for x in a),
                                 np.int64, a.size))


def held_write_indices(eng, d) -> np.ndarray:
    """Every lock index this attempt's writes hold, deduplicated.

    Union of the undo log's addresses (normalized via ``locks.index``)
    and the policy's explicit encounter-time index set — DCTL's
    irrevocable mode read-locks indices that never enter the undo log,
    so both sources are needed.
    """
    idxs = set(int(i) for i in getattr(d, "locked_idxs", ()))
    if d.undo:
        idxs.update(int(i) for i in addr_lock_indices(eng, d.undo))
    return np.fromiter(sorted(idxs), np.int64, len(idxs))


def dedup_last_wins(addrs: np.ndarray, values):
    """Collapse duplicate addresses in a write batch, LAST write winning.

    ``Txn.write_bulk`` promises ``for a, v: write(a, v)`` semantics;
    buffered backends get last-write-wins for free from their dict
    update, but a heap ``scatter`` with duplicate indices keeps an
    UNSPECIFIED writer (numpy) or a nondeterministic one (jax scatter).
    The encounter-time bulk paths route through here first; the common
    duplicate-free batch pays one vectorized uniqueness check.
    """
    if np.unique(addrs).size == addrs.size:
        return addrs, values
    m = dict(zip(addrs.tolist(), list(values)))
    return np.fromiter(m.keys(), np.int64, len(m)), list(m.values())


def extend_and_relock(eng, d, idxs: np.ndarray):
    """Snapshot extension for a version-blocked bulk write claim.

    Under the deferred clock, a writer's own previous commit leaves its
    lock words at version == the CURRENT clock, so the next
    transaction's claim (which requires ``version < r_clock``) fails
    even though nothing conflicts — the scalar path eats an abort and a
    full replay for it.  TinySTM's snapshot-extension argument applies
    instead: if no word is foreign-locked or flagged and the read set
    still revalidates RIGHT NOW, the transaction can serialize at a
    later snapshot — an abort-and-replay would re-read exactly the
    values it already holds (that is what revalidation proves).  So:
    advance the snapshot past the current clock (bumping the deferred
    clock, exactly as the abort it replaces would have), revalidate,
    and retry the claim once.  Returns the newly-claimed indices or
    ``None`` (caller aborts / falls back).

    ORDER MATTERS: the clock is bumped BEFORE revalidating, and the
    revalidation runs at the OLD ``r_clock``; only on success does the
    snapshot advance to the bumped value.  Any foreign commit that
    completes after the bump publishes at >= the new snapshot and fails
    the final commit's V_LT; any foreign commit before it is caught by
    the revalidation here (its lock is still held, or its published
    version is >= the old ``r_clock``).  Revalidate-then-bump had a
    hole: a foreign commit landing entirely between the two steps
    publishes at the PRE-bump clock, which the extended snapshot then
    accepts as valid — a stale read the final revalidation can never
    catch.
    """
    ver, own, meta = eng.locks.gather(idxs)
    foreign = ((meta & 1) != 0) & (own != d.tid)
    flagged = (meta & 2) != 0
    if bool((foreign | flagged).any()):
        return None
    candidate = eng.clock.increment()
    if not eng.revalidate(d):
        return None
    d.r_clock = candidate
    return eng.locks.try_lock_bulk(idxs, d.tid, max_version=d.r_clock)


def extend_snapshot(eng, d) -> bool:
    """Scalar twin of ``extend_and_relock``'s clock step.

    The scalar encounter-time write hits the same deferred-clock
    self-conflict as the bulk claim: a writer's own previous commit left
    the lock word at version == the current clock, so ``validate``
    (``version < r_clock``) fails with nothing actually conflicting, and
    back-to-back commits eat one abort each.  The caller has already
    established that the word is neither foreign-locked nor flagged;
    this advances the snapshot and revalidates, after which the caller
    re-reads the word and retries the claim once.

    Same ordering pin as the bulk path: the clock is bumped BEFORE
    revalidating (which runs at the OLD ``r_clock``), and only on
    success does the snapshot advance — a foreign commit racing the
    extension either publishes at >= the new snapshot (caught by the
    final commit's V_LT) or is caught by the revalidation here.
    Returns True iff the snapshot advanced; False means abort.
    """
    candidate = eng.clock.increment()
    if not eng.revalidate(d):
        return False
    d.r_clock = candidate
    return True


def merge_undo(eng, d, addrs: np.ndarray) -> None:
    """Record pre-images for a write batch in one heap gather.

    First write wins: entries already in the undo log are the true
    pre-images (an earlier write in this transaction put them there), so
    the fresh gather only fills the gaps — ``merged.update(d.undo)``
    keeps every existing entry.  The encounter-time ``write_bulk``
    paths call this after their lock sweep and before their scatter.
    """
    from repro.core.engine.bulkread import heap_gather
    olds = heap_gather(eng.heap, addrs)
    if isinstance(olds, np.ndarray):
        olds = olds.tolist()
    merged = dict(zip(addrs.tolist(), olds))
    merged.update(d.undo)
    d.undo = merged


def heap_scatter(heap, addrs, values, tid: int = -1) -> None:
    """``heap[addrs] = values`` in one pass (the write-back twin of
    ``bulkread.heap_gather``).

    ``ArrayHeap`` takes a single fancy-index assignment under its lock;
    ``ObjectHeap`` takes one list pass; anything else falls back to
    scalar stores.  No kernel dispatch here: the in-place numpy heap IS
    the CPU-production representation, and gathering the whole live row
    out just to scatter the same values back would be an O(heap) round
    trip per commit — the ``scatter_write`` kernel serves the
    FUNCTIONAL rows (``scatter_row`` below, the MVStore commit's
    device-side block), which is where a TPU deployment's heap lives.

    When a fault schedule is installed the sweep splits in half around
    the ``mid_scatter`` point — a crash there leaves a PARTIAL-LANE
    heap image (half the record's lanes scattered, the rest not), the
    torn state whole-record idempotent WAL redo must heal.
    """
    sc = getattr(heap, "scatter", None)
    if sc is None:
        def sc(a, v):  # noqa: E731 - scalar-store fallback
            for ai, vi in zip(a, v):
                heap[int(ai)] = vi
    n = len(values) if hasattr(values, "__len__") else 0
    if FP.ACTIVE is not None and n > 1:
        h = n // 2
        sc(addrs[:h], values[:h])
        FP.fire("mid_scatter", tid)
        sc(addrs[h:], values[h:])
        return
    sc(addrs, values)


def scatter_row(row, addrs, values):
    """Functional ``row.at[addrs].set(values)`` with the kernel dispatch.

    The write-back analogue of ``bulkread.gather_row`` for immutable
    (jax) rows: one DONATED ``ops.publish_row`` call — a
    ``scatter_write`` launch when ``KERNEL_INTERPRET=0``, the jitted
    jnp scatter otherwise — so the row never round-trips through the
    host (``write_back`` returns an ndarray, a device->host heap copy
    per commit, which the device path must not pay).  The caller hands
    over ownership of ``row`` (donation invalidates it on backends
    that honor it; readers needing the old row must alias it first).
    Enforces the shared bounds contract (``check_addr_bounds``), where
    jax scatter would silently DROP an out-of-range address and wrap a
    negative one, and keeps the ``write_back`` int64-range guard:
    beyond-int32 payloads route to the exact numpy twin.  Serves the
    MVStore commit's live-block update.
    """
    from repro.core.engine.arrayheap import check_addr_bounds
    from repro.kernels import ops
    a = np.asarray(addrs, np.int64)
    check_addr_bounds(a, row.shape[0])
    vals = np.asarray(values)
    lo, hi = -(1 << 31) + 1, (1 << 31) - 1
    if vals.dtype == np.int64 and vals.size and \
            (int(vals.max()) > hi or int(vals.min()) < lo):
        import jax.numpy as jnp
        return jnp.asarray(ops.write_back(row, a, vals), row.dtype)
    return ops.publish_row(row, a, vals)


# ---------------------------------------------------------------------------
# durable commit log hooks (reliability/wal.py)
# ---------------------------------------------------------------------------
#
# Protocol (the append-before-claim invariant): a PREPARE frame carrying
# the full redo image is buffered-appended BEFORE the claim/scatter
# phase; the fsync'd DECIDE marker lands at the exact instant
# ``publish_started`` flips True, before the first heap mutation — file
# appends are sequential, so the one DECIDE fsync also makes the
# PREPARE durable.  An abandoned prepare (abort, or crash before
# DECIDE) is never replayed: rollback is free.


def wal_log_prepare(eng, d) -> None:
    """Buffered PREPARE from the buffered write map (before the claim)."""
    wal = eng.wal
    if wal is None or not d.write_map:
        return
    wm = d.write_map
    d.wal_lsn = wal.append_prepare(
        d.tid, np.fromiter(wm.keys(), np.int64, len(wm)),
        list(wm.values()), clocks=(eng.clock.load(),))


def wal_log_decide(eng, d) -> None:
    """fsync'd DECIDE at the publish_started flip (buffered path)."""
    wal = eng.wal
    if wal is None or d.wal_lsn is None:
        return
    wal.append_decide(d.wal_lsn)


def wal_log_decide_encounter(eng, d) -> None:
    """PREPARE + DECIDE for encounter-time policies, at their decide
    point (revalidation passed, locks still held).

    In-place backends scattered their values during execution, so the
    redo image is gathered FROM THE HEAP at the undo log's addresses —
    the locks guarantee those words still hold this transaction's
    values.  There is no earlier correct hook: before revalidation the
    commit may still abort (and the undo restore would un-publish the
    prepared image), so prepare and decide collapse into one append +
    one fsync here.
    """
    wal = eng.wal
    if wal is None or not d.undo:
        return
    addrs = np.fromiter(d.undo.keys(), np.int64, len(d.undo))
    vals = eng.heap.gather(addrs)
    d.wal_lsn = wal.append_prepare(
        d.tid, addrs, vals, clocks=(eng.clock.load(),))
    wal.append_decide(d.wal_lsn)


# ---------------------------------------------------------------------------
# pipeline steps
# ---------------------------------------------------------------------------


def acquire_write_locks(eng, d,
                        bulk_min: Optional[int] = None) -> List[int]:
    """Claim every buffered write's lock (commit-time locking).

    On conflict, aborts the transaction with no locks held: the scalar
    loop releases whatever it had acquired (versions untouched); the
    bulk sweep (write sets >= ``bulk_min``, default ``BULK_MIN``) is
    all-or-nothing and never acquired in the first place.  Returns the
    locked indices, deduplicated (ascending on the bulk path,
    acquisition order on the scalar path).
    """
    bm = BULK_MIN if bulk_min is None else bulk_min
    wal_log_prepare(eng, d)
    if FP.ACTIVE is not None:
        FP.fire("pre_claim", d.tid)
    try_bulk = getattr(eng.locks, "try_lock_bulk", None)
    if try_bulk is not None and len(d.write_map) >= bm:
        claimed = try_bulk(addr_lock_indices(eng, d.write_map), d.tid)
        if claimed is None:
            eng.abort_txn(d)
        locked = claimed.tolist()
    else:
        locked: List[int] = []
        for addr in d.write_map:
            idx = eng.locks.index(addr)
            st = eng.locks.read(idx)
            if not eng.locks.try_lock(idx, st, d.tid):
                release_locks(eng, locked)
                eng.abort_txn(d)
            if idx not in locked:
                locked.append(idx)
    if FP.ACTIVE is not None:
        try:
            FP.fire("post_claim", d.tid)
        except BaseException as e:
            # an injected recoverable error must not leak the claim the
            # caller never saw; a simulated crash must leave it held
            if not FP.is_simulated_crash(e):
                release_locks(eng, locked)
            raise
    return locked


def write_back(eng, d, bulk_min: Optional[int] = None) -> None:
    """Publish buffered writes to the heap (caller holds the locks).

    One heap ``scatter`` at write sets >= ``bulk_min`` (write maps are
    dict-keyed, so the addresses are unique — the scatter contract);
    the scalar store loop below it.
    """
    bm = BULK_MIN if bulk_min is None else bulk_min
    wm = d.write_map
    if FP.ACTIVE is not None:
        FP.fire("pre_scatter", d.tid)
    if d.wal_lsn is None:
        # policy skipped acquire_write_locks (or the WAL was attached
        # mid-operation): prepare here so the decide below has a frame
        wal_log_prepare(eng, d)
    # commit record: from here the decision is publish — a crash below
    # rolls FORWARD from write_map (recovery.recover_engine), and the
    # durable DECIDE marker lands BEFORE the first heap mutation
    wal_log_decide(eng, d)
    d.publish_started = True
    if len(wm) >= bm and getattr(eng.heap, "scatter", None) is not None:
        addrs = np.fromiter(wm.keys(), np.int64, len(wm))
        heap_scatter(eng.heap, addrs, list(wm.values()), tid=d.tid)
        if FP.ACTIVE is not None:
            FP.fire("post_scatter", d.tid)
        return
    if FP.ACTIVE is not None and len(wm) > 1:
        # same partial-lane split as heap_scatter, for the scalar path
        items = list(wm.items())
        h = len(items) // 2
        for addr, value in items[:h]:
            eng.heap[addr] = value
        FP.fire("mid_scatter", d.tid)
        for addr, value in items[h:]:
            eng.heap[addr] = value
        FP.fire("post_scatter", d.tid)
        return
    for addr, value in wm.items():
        eng.heap[addr] = value
    if FP.ACTIVE is not None:
        FP.fire("post_scatter", d.tid)


def release_locks(eng, idxs: Iterable[int],
                  version: Optional[int] = None,
                  bulk_min: Optional[int] = None) -> None:
    """Release lock INDICES (never raw addresses), optionally publishing
    ``version``; one ``unlock_bulk`` sweep at batches >= ``bulk_min``."""
    bm = BULK_MIN if bulk_min is None else bulk_min
    arr = idxs if isinstance(idxs, np.ndarray) else None
    n = arr.size if arr is not None else len(idxs)  # type: ignore[arg-type]
    unlock_bulk = getattr(eng.locks, "unlock_bulk", None)
    if unlock_bulk is not None and n >= bm:
        if arr is None:
            # no int() per element: callers pass int/np-int indices and
            # fromiter's dtype cast covers both at C speed
            arr = np.fromiter(idxs, np.int64, n)
        unlock_bulk(arr, version)
        return
    for idx in idxs:
        eng.locks.unlock(int(idx), version)


def rollback_inplace(eng, d, bump_clock: bool = True,
                     bulk_min: Optional[int] = None) -> None:
    """Undo encounter-time in-place writes and release the held locks.

    ``bump_clock`` implements the deferred clock's abort increment: the
    released locks are republished at a FRESH version so any reader that
    validated against the uncommitted value must revalidate and abort.
    The undo restore is one heap ``scatter`` at >= ``bulk_min`` entries,
    and the release set is ``held_write_indices`` — deduped lock
    indices, never per-address unlocks (see the module docstring's
    normalization note).
    """
    bm = BULK_MIN if bulk_min is None else bulk_min
    undo = d.undo
    if len(undo) >= bm and getattr(eng.heap, "scatter", None) is not None:
        addrs = np.fromiter(undo.keys(), np.int64, len(undo))
        heap_scatter(eng.heap, addrs, list(undo.values()), tid=d.tid)
    else:
        for addr, old in undo.items():
            eng.heap[addr] = old
    nxt = eng.clock.increment() if bump_clock else None
    release_locks(eng, held_write_indices(eng, d), nxt, bulk_min=bm)
