"""``TMPolicy`` — what distinguishes one TM algorithm from another.

The engine owns the mechanism every backend shares (heap, clock, lock
table, descriptors, abort/alloc bookkeeping, stats aggregation, retry-
exhaustion cleanup); a policy supplies only the algorithm:

    class MyPolicy(PolicyBase):
        name = "mytm"
        def read(self, eng, d, addr): ...
        def write(self, eng, d, addr, value): ...
        def commit_update(self, eng, d): ...

and becomes a full backend via ``TransactionEngine(MyPolicy(), n)`` (or
``register_backend`` — see API.md for the worked example).  TL2, DCTL,
NOrec and TinySTM are exactly such objects in ``core/baselines.py``;
Multiverse adds its versioning machinery in ``core/stm.py`` through the
same hooks.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

from repro.core.engine import validation as V


@runtime_checkable
class TMPolicy(Protocol):
    """Protocol form of the hook set (see ``PolicyBase`` for defaults)."""

    name: str
    validate_mode: int

    def setup(self, eng) -> None: ...
    def on_begin(self, eng, d) -> None: ...
    def read(self, eng, d, addr: int) -> Any: ...
    def write(self, eng, d, addr: int, value: Any) -> None: ...
    def commit_read_only(self, eng, d) -> None: ...
    def commit_update(self, eng, d) -> None: ...
    def rollback(self, eng, d) -> None: ...
    def on_abort(self, eng, d) -> None: ...
    def on_finish(self, eng, d) -> None: ...
    def validate(self, eng, d) -> bool: ...


class PolicyBase:
    """Default hook implementations: a read-snapshot TM with no writes."""

    name = "policy"
    validate_mode = V.V_LT

    # -- lifecycle -------------------------------------------------------
    def setup(self, eng) -> None:
        """Called once from the engine constructor."""

    def on_operation_start(self, eng, d) -> None:
        """A NEW logical operation begins (not a retry)."""
        d.reset_operation()

    def on_begin(self, eng, d) -> None:
        d.r_clock = eng.clock.load()

    def commit_read_only(self, eng, d) -> None:
        """Read-only commit bookkeeping (nothing to publish)."""

    def commit_update(self, eng, d) -> None:
        raise NotImplementedError

    def rollback(self, eng, d) -> None:
        """Undo this attempt's writes / release its locks."""

    def on_abort(self, eng, d) -> None:
        """Post-rollback bookkeeping (heuristics, attempt counting)."""
        d.attempts += 1

    def on_finish(self, eng, d) -> None:
        """Post-commit bookkeeping (both read-only and update commits)."""
        d.attempts = 0

    def on_retries_exhausted(self, eng, tid: int) -> None:
        """Retry cap hit: flush anything a wedged operation may hold."""

    # -- accesses --------------------------------------------------------
    def read(self, eng, d, addr: int) -> Any:
        raise NotImplementedError

    def read_bulk(self, eng, d, addrs) -> Any:
        """Batched read (``Txn.read_bulk``): default is the scalar loop.

        Lock-version policies override this with the vectorized batch in
        ``engine.bulkread`` (one heap gather bracketed by two lock-word
        gathers); the default keeps every third-party policy correct.
        ``addrs`` arrives as an int64 ndarray (the engine normalizes).
        """
        return [self.read(eng, d, int(a)) for a in addrs]

    def write(self, eng, d, addr: int, value: Any) -> None:
        raise NotImplementedError

    def write_bulk(self, eng, d, addrs, values) -> None:
        """Batched write (``Txn.write_bulk``): default is the scalar loop.

        Buffered policies override with one write-map update;
        encounter-time policies with one ``try_lock_bulk`` claim sweep +
        one undo gather + one heap scatter (``core/baselines.py``,
        ``core/stm.py``).  The default keeps every third-party policy
        correct.  ``addrs`` arrives as an int64 ndarray.
        """
        for a, v in zip(addrs, values):
            self.write(eng, d, int(a), v)

    # -- validation ------------------------------------------------------
    def validate(self, eng, d) -> bool:
        """Is the read set still valid right now?  (``Txn.validate_bulk``)"""
        return V.revalidate(eng.locks, d.read_set, d.r_clock, d.tid,
                            self.validate_mode)

    # -- reporting / teardown -------------------------------------------
    def mode_name(self, eng) -> str:
        return "-"

    def extra_stats(self, eng, out: dict) -> None:
        """Add policy-specific counters to the normalized stats dict."""

    def stop(self, eng) -> None:
        """Tear down background machinery."""
