"""Frontier-at-a-time traversal: pointer-chasing long reads in batches.

``Txn.read_bulk`` made flat scans array operations, but the struct long
reads the paper studies (range queries, size queries) are POINTER chases:
the next address depends on the last value, so a naive port walks the
interpreter hop by hop and the benchmark measures Python, not the TM.
This module closes that gap with level-synchronous traversal: per step,
the words of the ENTIRE current frontier are gathered in ONE
``tx.read_bulk`` batch, and a caller-supplied expand function turns them
into emitted results and the next frontier.  A structure of depth ``D``
with ``N`` nodes costs ``O(D)`` batched reads instead of ``O(N)`` scalar
reads.

Two entry points share the contract:

  * ``traverse_bulk(tx, roots, expand, limit=...)`` — ORDERED traversal
    (DFS/in-order) with early termination: an explicit worklist keeps
    every pending node and every emitted value in left-to-right order, so
    tree range queries emit in key order and can stop at ``limit`` even
    though expansion is breadth-batched.  Also removes the recursion-
    depth hazard of recursive DFS — depth is heap-allocated list length,
    never Python stack.
  * ``chase_bulk(tx, cursors, advance)`` — UNORDERED uniform chase for
    single-word frontiers (overflow chains, free lists): ``advance``
    receives the whole cursor/value arrays and returns the next cursor
    array, so a round is pure numpy with no per-item Python.

Consistency: both functions read ONLY through ``tx.read_bulk``, which
already guarantees that every element is either proven consistent by the
vectorized predicate or transparently re-read through the owning
policy's exact scalar protocol (spin / extend / abort semantics
preserved per element — see ``engine/bulkread.py``).  The traversal
layer therefore inherits each backend's semantics unchanged; what it
adds is purely the batching schedule.  The one observable difference
from a hand-rolled scalar walk: a frontier step reads every pending
node's words even when an earlier sibling would have satisfied ``limit``
first, so a concurrent writer on a node the scalar walk would never have
reached can abort the batched walk — the same (documented) widened
conflict surface as ``abtree``'s whole-node batches.

``expand(state, words, emit, push)`` contract (see API.md "Batched
traversals" for runnable examples):

  * ``state`` — the opaque per-item state given at push time (or the
    root tuple's third element; ``None`` if omitted);
  * ``words`` — this item's ``span`` gathered words, ``words[i]`` being
    the value at ``addr + i`` (ndarray slice on array heaps when the
    batch gathered clean, list slice otherwise);
  * ``emit(value)`` — append ``value`` to the traversal's result, in
    traversal order;
  * ``push(addr, span, state=None)`` — schedule a child item, in
    traversal order relative to this item's other emits/pushes.

``emit``/``push`` must be called synchronously inside ``expand``.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, List, Optional, Sequence

import numpy as np

__all__ = ["chase_bulk", "frontier_addrs", "traverse_bulk"]

_EMIT = True
_PEND = False


class _dedup_read_set:
    """Context manager: dedup read-set tracking for a traversal's reads.

    A traversal revisits lock indices across rounds (sibling nodes
    sharing a lock bucket, chains re-walked by nested queries); while
    the flag is set, ``bulk_read_lockver`` skips (idx, version) pairs
    already tracked, so commit-time revalidation stays proportional to
    the DISTINCT footprint, not the visit count.  Restores the previous
    flag on exit, so nested traversals compose; contexts without the
    flag (MVStore's ``_MVCtx`` — no read set to dedup) are a no-op.
    """

    __slots__ = ("_ctx", "_prev")

    def __init__(self, tx):
        ctx = getattr(tx, "_ctx", None)
        self._ctx = ctx if hasattr(ctx, "dedup_read_set") else None

    def __enter__(self):
        if self._ctx is not None:
            self._prev = self._ctx.dedup_read_set
            self._ctx.dedup_read_set = True

    def __exit__(self, *exc):
        if self._ctx is not None:
            self._ctx.dedup_read_set = self._prev


def frontier_addrs(bases: np.ndarray, spans: np.ndarray):
    """Flatten ``[(base, span), ...]`` into one address vector.

    Returns ``(addrs, starts, ends)`` where item ``k``'s words live at
    ``addrs[starts[k]:ends[k]]`` — the single home of the span-
    concatenation arithmetic (vectorized: no per-item ``range``)."""
    ends = np.cumsum(spans)
    starts = ends - spans
    addrs = np.repeat(bases - starts, spans) + np.arange(int(ends[-1]),
                                                         dtype=np.int64)
    return addrs, starts, ends


def traverse_bulk(tx, roots: Iterable[Sequence], expand: Callable,
                  *, limit: Optional[int] = None) -> List[Any]:
    """Ordered frontier-at-a-time traversal; returns emitted values.

    ``roots`` is an iterable of ``(addr, span)`` or ``(addr, span,
    state)`` items.  Per round, every pending item's words are gathered
    in ONE ``tx.read_bulk`` batch and ``expand`` replaces each item — in
    worklist order — with its emits and child pushes, so the result list
    is exactly the scalar DFS emission order.  ``limit`` stops the
    traversal as soon as the RESOLVED prefix holds that many values
    (items right of an unexpanded node are never emitted early).
    """
    work: List[tuple] = []
    for r in roots:
        work.append((_PEND, int(r[0]), int(r[1]),
                     r[2] if len(r) > 2 else None))
    out: List[Any] = []
    with _dedup_read_set(tx):
        return _traverse_loop(tx, work, out, expand, limit)


def _traverse_loop(tx, work: List[tuple], out: List[Any],
                   expand: Callable, limit: Optional[int]) -> List[Any]:
    while work:
        # drain the resolved prefix (everything left of the first
        # pending item is final — this is what preserves DFS order)
        i, n = 0, len(work)
        while i < n and work[i][0]:
            out.append(work[i][1])
            i += 1
            if limit is not None and len(out) >= limit:
                return out
        if i:
            del work[:i]
        if not work:
            break
        # ONE batched read of the whole pending frontier
        pend = [e for e in work if not e[0]]
        m = len(pend)
        bases = np.fromiter((e[1] for e in pend), np.int64, m)
        spans = np.fromiter((e[2] for e in pend), np.int64, m)
        addrs, starts, ends = frontier_addrs(bases, spans)
        words = tx.read_bulk(addrs)
        # expand each pending item in place, order preserved
        new_work: List[tuple] = []
        append = new_work.append

        def emit(value):
            append((_EMIT, value, 0, None))

        def push(addr, span, state=None):
            append((_PEND, int(addr), int(span), state))

        k = 0
        for e in work:
            if e[0]:
                append(e)
            else:
                expand(e[3], words[int(starts[k]):int(ends[k])], emit, push)
                k += 1
        work = new_work
    return out


def chase_bulk(tx, cursors, advance: Callable) -> int:
    """Vectorized pointer chase for uniform single-word frontiers.

    Per round, the words at every cursor address are gathered in ONE
    ``tx.read_bulk`` batch and ``advance(cursors, values)`` returns the
    next cursor array (empty/None ends the chase) — accumulation lives
    in the caller's closure, so a round is a handful of numpy ops with
    no per-item Python at all.  Returns the number of rounds (== the
    longest chain's length in hops), which is also the number of
    ``read_bulk`` calls issued.
    """
    cur = np.asarray(cursors, dtype=np.int64)
    rounds = 0
    with _dedup_read_set(tx):
        while cur.size:
            vals = tx.read_bulk(cur)
            rounds += 1
            nxt = advance(cur, vals)
            if nxt is None:
                break
            cur = np.asarray(nxt, dtype=np.int64)
    return rounds
