"""``TransactionEngine`` — one runtime, pluggable TM policies.

The engine owns everything the five word-level backends used to each
re-implement: the heap, the global clock, the (array-backed) lock table,
per-thread transaction descriptors, begin/commit/abort orchestration,
transactional allocation rollback, stats aggregation, and the retry-
exhaustion safety net.  A ``TMPolicy`` supplies only the algorithm
(read/write/validate/commit/rollback), so a backend is the ~50 lines
that differ from the textbook, not the ~200 that don't.

Lifecycle contract (what ``repro.api`` drives):

  * ``begin(tid)`` resets the descriptor, runs ``policy.on_begin`` and
    returns a ``_Tx`` handle;
  * ``_try_commit(d)`` routes read-only descriptors (no write footprint)
    to ``policy.commit_read_only`` and everything else to
    ``policy.commit_update``; commit counters and ``active`` are engine
    business;
  * ``_abort(d)`` is IDEMPOTENT and does not raise: rollback via the
    policy, free txn-local allocations, count, run ``policy.on_abort``.
    Policy code that needs to abort-and-longjmp calls ``abort_txn``;
  * ``release_thread_locks(tid)`` / ``on_retries_exhausted(tid)`` force-
    release anything a capped transaction still holds so one starved
    thread can never wedge later writers (paper SS5's retry cap).
"""
from __future__ import annotations

from typing import Any, List, Optional

from repro.core.clock import GlobalClock
from repro.core.engine import bulkread as B
from repro.core.engine import validation as V
from repro.core.engine.arrayheap import ArrayLockTable, ObjectHeap
from repro.core.engine.descriptor import COUNTER_KEYS, TxnDescriptor
from repro.core.engine.errors import AbortTx
from repro.core.stats_schema import RECOVERY_STAT_KEYS, base_stats


class TMBase:
    """Shared heap + allocation interface (structures build on this)."""

    def __init__(self, n_threads: int, heap=None):
        self.n_threads = n_threads
        self.heap = heap if heap is not None else ObjectHeap()
        self.name = type(self).__name__

    # heap ---------------------------------------------------------------
    def alloc(self, n: int, init: Any = None) -> int:
        return self.heap.alloc(n, init)

    def peek(self, addr: int) -> Any:
        """Non-transactional read (test/debug only)."""
        return self.heap[addr]

    @property
    def _heap(self):
        # historical name: pre-engine code indexed the raw list directly
        return self.heap

    def stop(self) -> None:  # pragma: no cover - overridden
        pass


class _Tx:
    """Handle passed to user transaction bodies."""

    __slots__ = ("_tm", "_ctx")

    def __init__(self, tm: "TransactionEngine", ctx: TxnDescriptor):
        self._tm = tm
        self._ctx = ctx

    def read(self, addr: int) -> Any:
        return self._tm.tm_read(self._ctx, addr)

    def read_bulk(self, addrs) -> Any:
        return self._tm.tm_read_bulk(self._ctx, addrs)

    def traverse_bulk(self, roots, expand, *, limit: Optional[int] = None):
        """Frontier-at-a-time traversal (see ``engine/traverse.py``)."""
        from repro.core.engine.traverse import traverse_bulk
        return traverse_bulk(self, roots, expand, limit=limit)

    def chase_bulk(self, cursors, advance) -> int:
        """Vectorized single-word pointer chase (``engine/traverse.py``)."""
        from repro.core.engine.traverse import chase_bulk
        return chase_bulk(self, cursors, advance)

    def write(self, addr: int, value: Any) -> None:
        self._tm.tm_write(self._ctx, addr, value)

    def write_bulk(self, addrs, values) -> None:
        self._tm.tm_write_bulk(self._ctx, addrs, values)

    def alloc(self, n: int, init: Any = None) -> int:
        return self._tm.tx_alloc(self._ctx, n, init)

    @property
    def read_count(self) -> int:
        return self._ctx.read_cnt


class TransactionEngine(TMBase):
    def __init__(self, policy, n_threads: int, lock_bits: int = 16,
                 heap=None):
        super().__init__(n_threads, heap=heap)
        self.policy = policy
        self.name = policy.name
        self.clock = GlobalClock(0)
        self.locks = ArrayLockTable(lock_bits)
        self._descs = [TxnDescriptor(t) for t in range(n_threads)]
        # durability (reliability/wal.py): when attached, the commit
        # pipeline appends a PREPARE before the claim and fsyncs a
        # DECIDE at the publish_started flip; recovery accumulates its
        # typed counters here so stats()/normalize_stats surface them
        self.wal = None
        self.recovery_counters = {k: 0 for k in RECOVERY_STAT_KEYS}
        policy.setup(self)

    # ------------------------------------------------------------------
    # transaction lifecycle
    # ------------------------------------------------------------------
    def ctx(self, tid: int) -> TxnDescriptor:
        return self._descs[tid]

    def begin_operation(self, tid: int) -> None:
        """A NEW logical operation (fresh retry loop) starts on ``tid``."""
        self.policy.on_operation_start(self, self._descs[tid])

    def begin(self, tid: int) -> _Tx:
        d = self._descs[tid]
        d.reset()
        self.policy.on_begin(self, d)
        d.active = True
        return _Tx(self, d)

    def _try_commit(self, d: TxnDescriptor) -> None:
        if d.read_only and not d.has_writes:
            self.policy.commit_read_only(self, d)
            d.stats["ro_commits"] += 1
        else:
            self.policy.commit_update(self, d)
            d.stats["commits"] += 1
            if self.wal is not None and d.wal_lsn is not None:
                # publish finished: buffered COMPLETE marker (replay is
                # idempotent without it; recovery uses it to report
                # decided-but-unpublished as rolled forward)
                self.wal.append_complete(d.wal_lsn)
                d.wal_lsn = None
        d.active = False
        self.policy.on_finish(self, d)

    def _abort(self, d: TxnDescriptor) -> None:
        """Roll back an attempt.  Idempotent; does NOT raise."""
        if not d.active:
            return
        self.policy.rollback(self, d)
        # free txn-local allocations (nobody else can have seen them: the
        # addresses were only reachable via this txn's unpublished writes)
        blank = None if isinstance(self.heap, ObjectHeap) else 0
        for base, n in d.alloc_log:
            for i in range(n):
                self.heap[base + i] = blank
        d.alloc_log.clear()
        d.stats["aborts"] += 1
        d.active = False
        self.policy.on_abort(self, d)

    def abort_txn(self, d: TxnDescriptor) -> None:
        """Abort + longjmp (policy-internal conflict path)."""
        self._abort(d)
        raise AbortTx()

    # ------------------------------------------------------------------
    # accesses
    # ------------------------------------------------------------------
    def tm_read(self, d: TxnDescriptor, addr: int) -> Any:
        d.read_cnt += 1
        return self.policy.read(self, d, addr)

    def tm_read_bulk(self, d: TxnDescriptor, addrs) -> Any:
        """Batched read: the whole address batch in one policy call.

        Counts as ``len(addrs)`` reads (heuristics like K1/K2/K3 and the
        paper's MinModeUReadCount are calibrated on words read, and a
        bulk scan reads just as many words as a scalar one).
        """
        a = B.as_addr_array(addrs)
        d.read_cnt += a.size
        return self.policy.read_bulk(self, d, a)

    def tm_write(self, d: TxnDescriptor, addr: int, value: Any) -> None:
        self.policy.write(self, d, addr, value)

    def tm_write_bulk(self, d: TxnDescriptor, addrs, values) -> None:
        """Batched write: the whole (addrs, values) batch in one policy
        call — buffered policies fold it into the write map in one dict
        update; encounter-time policies claim the locks in one
        ``try_lock_bulk`` sweep (see each policy's ``write_bulk``)."""
        self.policy.write_bulk(self, d, B.as_addr_array(addrs), values)

    def tx_alloc(self, d: TxnDescriptor, n: int, init: Any = None) -> int:
        base = self.alloc(n, init)
        d.alloc_log.append((base, n))
        return base

    # ------------------------------------------------------------------
    # validation (scalar below BULK_MIN, vectorized above)
    # ------------------------------------------------------------------
    def revalidate(self, d: TxnDescriptor, mode: Optional[int] = None,
                   r_clock: Optional[int] = None) -> bool:
        return V.revalidate(
            self.locks, d.read_set,
            d.r_clock if r_clock is None else r_clock, d.tid,
            self.policy.validate_mode if mode is None else mode)

    def validate_ctx(self, d: TxnDescriptor) -> bool:
        """``Txn.validate_bulk`` lands here via the substrate adapter."""
        return self.policy.validate(self, d)

    # ------------------------------------------------------------------
    # retry-cap safety net
    # ------------------------------------------------------------------
    def release_thread_locks(self, tid: int) -> int:
        """Force-release every lock still held by ``tid``.

        Released locks are republished at a bumped clock so any reader
        that validated against a half-done write revalidates and aborts —
        the same deferred-clock rule the abort path uses.
        """
        held = self._held_by(tid)
        if len(held) == 0:
            return 0
        nxt = self.clock.increment()
        for idx in held:
            self.locks.unlock(int(idx), nxt)
        return len(held)

    def _held_by(self, tid: int) -> List[int]:
        held_by = getattr(self.locks, "held_by", None)
        if held_by is not None:
            return list(held_by(tid))
        return [i for i in range(self.locks.size)
                if (st := self.locks.read(i)).locked and st.tid == tid]

    def on_retries_exhausted(self, tid: int) -> None:
        """Called by ``repro.api.run`` before raising MaxRetriesExceeded."""
        d = self._descs[tid]
        self._abort(d)                    # no-op unless an attempt is live
        self.release_thread_locks(tid)
        self.policy.on_retries_exhausted(self, tid)

    # ------------------------------------------------------------------
    # stats / teardown
    # ------------------------------------------------------------------
    def stats(self) -> dict:
        out = base_stats(backend=self.name,
                         mode=self.policy.mode_name(self))
        for d in self._descs:
            for k in COUNTER_KEYS:
                out[k] += d.stats[k]
        for k, v in self.recovery_counters.items():
            out[k] += v
        self.policy.extra_stats(self, out)
        return out

    def stop(self) -> None:
        self.policy.stop(self)
