"""Array-backed heap + lock table: the engine's vectorizable substrate.

Two heap flavors behind one three-method interface (``alloc`` /
``__getitem__`` / ``__setitem__``):

  * ``ObjectHeap`` — the historical Python list; holds arbitrary objects
    (struct tests store strings), the default for every backend;
  * ``ArrayHeap``  — words in a contiguous int64 numpy buffer with
    capacity doubling and an on-demand ``jnp()`` view, so bulk kernels
    (``kernels/validate.py``, future sharded stores) can touch the whole
    heap in one launch.  Numeric words only.

``ArrayLockTable`` packs each versioned lock word ``(locked, version,
tid, flag)`` into ONE int64 array element::

    bits 18..63  version        (commit clock)
    bits  2..17  tid + 2        (supports the -2 background/-1 none tids)
    bit   1      locked
    bit   0      flag           (versioning-in-progress)

A single packed word makes the bulk path sound: ``gather(idxs)`` fancy-
indexes the array ONCE, so each gathered element is a consistent
(locked, version, tid, flag) tuple — gathering parallel arrays field by
field could tear a word between fields, which the scalar path never does.
"""
from __future__ import annotations

import threading
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core.locks import LockState, LockTable

_TID_BIAS = 2                    # stored tid = tid + 2 (tid >= -2)
_TID_BITS = 16
_TID_MASK = (1 << _TID_BITS) - 1
_VER_SHIFT = 2 + _TID_BITS


def pack_lock(st: LockState) -> int:
    return ((st.version << _VER_SHIFT)
            | ((st.tid + _TID_BIAS) & _TID_MASK) << 2
            | (1 << 1 if st.locked else 0)
            | (1 if st.flag else 0))


def unpack_lock(word: int) -> LockState:
    return LockState(bool(word & 2), word >> _VER_SHIFT,
                     ((word >> 2) & _TID_MASK) - _TID_BIAS, bool(word & 1))


_UNLOCKED_WORD = pack_lock(LockState(False, 0, -1, False))


def check_addr_bounds(idx: np.ndarray, n: int) -> None:
    """Raise unless every address lands in ``[0, n)`` — the bounds
    contract every bulk gather/scatter shares, failing loudly at BOTH
    ends: past the frontier (matching the scalar accessors) AND
    negative, which would wrap under numpy/jax fancy indexing and
    silently hit a word near the end of the buffer."""
    if not idx.size:
        return
    lo, hi = int(idx.min()), int(idx.max())
    if lo < 0 or hi >= n:
        raise IndexError(lo if lo < 0 else hi)


class ObjectHeap:
    """Plain Python-list heap: any value, no vectorization."""

    def __init__(self):
        self._cells: List[Any] = []
        self._lock = threading.Lock()

    def alloc(self, n: int, init: Any = None) -> int:
        with self._lock:
            base = len(self._cells)
            self._cells.extend([init] * n)
            return base

    def __getitem__(self, addr: int) -> Any:
        return self._cells[addr]

    def __setitem__(self, addr: int, value: Any) -> None:
        self._cells[addr] = value

    def __len__(self) -> int:
        return len(self._cells)

    def gather(self, addrs) -> List[Any]:
        """Batched read (``Txn.read_bulk``): one pass, no vectorization
        possible over arbitrary objects — but still one bounds check and
        no per-word lock/validate Python round-trips."""
        cells = self._cells
        return [cells[int(a)] for a in addrs]

    def scatter(self, addrs, values) -> None:
        """Batched write-back (the commit pipeline's ``write_back``):
        one pass over arbitrary objects — the list analogue of
        ``ArrayHeap.scatter``, so the bulk commit path has one
        interface on both heaps."""
        cells = self._cells
        for a, v in zip(addrs, values):
            cells[int(a)] = v


class ArrayHeap:
    """Numeric word heap in one int64 numpy buffer (doubling growth).

    ``len()`` is the allocated frontier, not the capacity; reads beyond it
    raise like the list heap does.  ``jnp()`` returns the live words as a
    jax array (a copy — jax buffers are immutable) for kernel consumption.
    """

    def __init__(self, capacity: int = 1024):
        self._buf = np.zeros(max(capacity, 1), np.int64)
        self._len = 0
        self._lock = threading.Lock()

    def alloc(self, n: int, init: Any = None) -> int:
        fill = 0 if init is None else int(init)
        with self._lock:
            base = self._len
            need = base + n
            if need > self._buf.shape[0]:
                cap = self._buf.shape[0]
                while cap < need:
                    cap *= 2
                grown = np.zeros(cap, np.int64)
                grown[:base] = self._buf[:base]
                self._buf = grown
            self._buf[base:need] = fill
            self._len = need
            return base

    def __getitem__(self, addr: int) -> int:
        # both ends: a negative address would wrap to the end of the
        # buffer (numpy indexing), same contract as the bulk paths
        if addr < 0 or addr >= self._len:
            raise IndexError(addr)
        return int(self._buf[addr])

    def __setitem__(self, addr: int, value: Any) -> None:
        if addr < 0 or addr >= self._len:
            raise IndexError(addr)
        # under the lock: a concurrent alloc() may be copying into a grown
        # buffer, and a write that raced the copy would land in the
        # discarded old array and silently vanish (ObjectHeap never
        # rebinds its list, so only the array heap has this hazard)
        with self._lock:
            self._buf[addr] = int(value)

    def __len__(self) -> int:
        return self._len

    def gather(self, addrs) -> np.ndarray:
        """Batched read: one fancy-index copy of ``buf[addrs]``.

        The copy is taken under the heap lock so a concurrent ``alloc``
        cannot swap the buffer out mid-gather (the same hazard
        ``__setitem__`` guards against); each element is then a plain
        int64 word.  Bounds are checked against the allocation frontier,
        matching the scalar ``__getitem__`` contract.
        """
        idx = np.asarray(addrs, np.int64)
        with self._lock:
            check_addr_bounds(idx, self._len)
            return self._buf[idx]

    def scatter(self, addrs, values) -> None:
        """Batched write-back: one fancy-index assignment of
        ``buf[addrs] = values`` under the heap lock (the same
        buffer-swap hazard ``__setitem__`` guards against).  Bounds are
        checked against the allocation frontier, matching the scalar
        ``__setitem__`` contract; values coerce through int64 exactly
        like the scalar ``int(value)`` does.  Addresses must be unique
        (write sets are dict-keyed) — with duplicates numpy keeps an
        unspecified writer, where the scalar loop keeps the last.
        """
        idx = np.asarray(addrs, np.int64)
        vals = np.asarray(values)
        if vals.dtype.kind not in "iu":       # match scalar int(value)
            vals = np.fromiter((int(v) for v in values), np.int64,
                               idx.size)
        with self._lock:
            check_addr_bounds(idx, self._len)
            self._buf[idx] = vals

    def jnp(self):
        import jax.numpy as jnp
        return jnp.asarray(self._buf[:self._len])


class ArrayLockTable(LockTable):
    """``LockTable`` semantics over a packed int64 numpy array.

    Inherits ``validate``/``try_lock``/``index`` (they are written against
    ``read``/``cas``) and overrides only the storage layer, adding the two
    bulk operations the vectorized hot path needs: ``gather`` and
    ``held_by``.
    """

    def __init__(self, bits: int):
        self.bits = bits
        self.size = 1 << bits
        self._words = np.full(self.size, _UNLOCKED_WORD, np.int64)
        from repro.core.clock import Striped
        # 128 stripes, not 1024: a bulk sweep acquires every DISTINCT
        # stripe its batch covers, so stripe count bounds the per-sweep
        # Python lock traffic (a 1k-word claim is <=128 acquires, not
        # ~1k) — while scalar CAS contention, which stripes exist to
        # spread, stays negligible at this port's thread counts
        self._stripes = Striped(128)

    # -- storage ops -------------------------------------------------------
    def read(self, idx: int) -> LockState:
        return unpack_lock(int(self._words[idx]))

    def read_wait_unflagged(self, idx: int) -> LockState:
        while True:
            w = int(self._words[idx])
            if not (w & 1):
                return unpack_lock(w)

    def cas(self, idx: int, expect: LockState, new: LockState) -> bool:
        with self._stripes.for_index(idx):
            if int(self._words[idx]) != pack_lock(expect):
                return False
            self._words[idx] = pack_lock(new)
            return True

    def store(self, idx: int, new: LockState) -> None:
        with self._stripes.for_index(idx):
            self._words[idx] = pack_lock(new)

    def lock_and_flag(self, idx: int, tid: int) -> LockState:
        while True:
            st = unpack_lock(int(self._words[idx]))
            if not st.locked and not st.flag:
                if self.cas(idx, st, LockState(True, st.version, tid, True)):
                    return st

    def unlock(self, idx: int, version: Optional[int] = None) -> None:
        with self._stripes.for_index(idx):
            st = unpack_lock(int(self._words[idx]))
            v = version if version is not None else st.version
            self._words[idx] = pack_lock(LockState(False, v, -1, False))

    # -- bulk ops ----------------------------------------------------------
    def index_bulk(self, addrs: np.ndarray) -> np.ndarray:
        """Vectorized ``index``: the Fibonacci hash of many addresses at
        once (uint64 arithmetic wraps mod 2**64 exactly like the scalar
        Python path masks it)."""
        from repro.core.locks import _GOLDEN
        a = np.asarray(addrs, np.uint64) * np.uint64(_GOLDEN)
        return (a >> np.uint64(64 - self.bits)).astype(np.int64)

    def gather(self, idxs: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                                np.ndarray]:
        """One consistent snapshot of many lock words.

        Returns ``(version int64[N], owner int32[N], meta int32[N])`` with
        meta bit0 = locked, bit1 = flag — the layout the bulk validators
        (numpy and the Pallas kernel) consume.
        """
        w = self._words[idxs]                       # single fancy-index copy
        ver = w >> _VER_SHIFT
        own = (((w >> 2) & _TID_MASK) - _TID_BIAS).astype(np.int32)
        meta = (((w >> 1) & 1) | ((w & 1) << 1)).astype(np.int32)
        return ver, own, meta

    def held_by(self, tid: int) -> np.ndarray:
        """Indices currently write-locked by ``tid`` (exhaustion cleanup)."""
        w = self._words
        mask = ((w & 2) != 0) & ((((w >> 2) & _TID_MASK) - _TID_BIAS) == tid)
        return np.nonzero(mask)[0]

    def try_lock_bulk(self, idxs: np.ndarray, tid: int,
                      max_version: Optional[int] = None
                      ) -> Optional[np.ndarray]:
        """All-or-nothing bulk claim: one CAS sweep over many indices.

        Deduplicates ``idxs`` (colliding addresses share a lock word,
        exactly like the scalar acquire loop's ``if idx not in locked``),
        then — holding every covering stripe, acquired in ascending
        order — checks the whole batch with ONE gather and, only if
        every word is claimable, claims the free ones with ONE scatter.
        Claimable means: free and unflagged (a word locked or flagged by
        someone else conflicts; a word locked by ``tid`` passes
        untouched), and — when ``max_version`` is given — free words
        must also carry ``version < max_version`` (the encounter-time
        write's validate-then-lock, atomically: the version is checked
        under the same stripes the claim holds, so it cannot advance in
        between like a separate gather would allow).

        On ANY conflict nothing is mutated and ``None`` returns (the
        scalar loop releases what it had acquired; the bulk sweep never
        acquires in the first place — same end state, no partial-hold
        window for other writers to conflict on).

        Returns the NEWLY-ACQUIRED unique indices (ascending int64[n]) —
        words already held by ``tid`` are excluded, so an unwinding
        caller can release exactly what this call took without touching
        locks earlier writes legitimately hold.  Per-word claim
        semantics match ``try_lock``: version preserved, flag cleared.
        """
        uniq = np.unique(np.asarray(idxs, np.int64))

        def conflicts(w):
            locked = (w & 2) != 0
            flagged = (w & 1) != 0
            own = locked & ((((w >> 2) & _TID_MASK) - _TID_BIAS) == tid)
            c = (locked | flagged) & ~own
            if max_version is not None:
                c |= ~locked & ((w >> _VER_SHIFT) >= max_version)
            return c

        # test-and-test-and-set: a conflict visible in a plain gather is
        # authoritative for FAILING (the caller retries/aborts either
        # way), so the common doomed sweep skips the stripe dance
        if bool(conflicts(self._words[uniq]).any()):
            return None
        stripes = self._stripes.for_indices(uniq)
        for s in stripes:
            s.acquire()
        try:
            w = self._words[uniq]
            if bool(conflicts(w).any()):
                return None
            locked = (w & 2) != 0
            free = ~locked
            new = ((w >> _VER_SHIFT) << _VER_SHIFT) \
                | (((tid + _TID_BIAS) & _TID_MASK) << 2) | 2
            self._words[uniq[free]] = new[free]
            return uniq[free]
        finally:
            for s in stripes:
                s.release()

    def striped(self, idxs: np.ndarray):
        """Context manager holding every stripe covering ``idxs``
        (acquired ascending, like the bulk sweeps) — the group-commit
        batcher's atomicity bracket: gather + verdict + claim run as one
        hoisted CAS window instead of per-transaction sweeps.  Pair with
        ``words_at``/``store_words``; do NOT call the self-locking ops
        (``try_lock_bulk``/``unlock_bulk``/``cas``) inside."""
        from contextlib import contextmanager

        stripes = self._stripes.for_indices(np.asarray(idxs, np.int64))

        @contextmanager
        def _hold():
            for s in stripes:
                s.acquire()
            try:
                yield
            finally:
                for s in stripes:
                    s.release()

        return _hold()

    def words_at(self, idxs: np.ndarray) -> np.ndarray:
        """Raw packed words, one consistent fancy-index copy — the group
        commit's gather (fields come from the shared bit math in
        ``kernels/commit_fused``'s caller)."""
        return self._words[np.asarray(idxs, np.int64)]

    def store_words(self, idxs: np.ndarray, words: np.ndarray) -> None:
        """Raw word scatter.  Caller MUST hold ``striped(idxs)`` (or the
        words must be claim words only this thread may release) — this
        is the storage primitive under the batcher's claim/stamp steps,
        with no locking of its own."""
        self._words[np.asarray(idxs, np.int64)] = words

    def claim_words(self, words: np.ndarray, tids: np.ndarray) -> np.ndarray:
        """Locked spellings of ``words`` claimed by per-entry ``tids``
        (version preserved, flag cleared) — vectorized ``try_lock``'s
        store half for the group claim."""
        return ((words >> _VER_SHIFT) << _VER_SHIFT) \
            | (((np.asarray(tids, np.int64) + _TID_BIAS) & _TID_MASK) << 2) \
            | 2

    def unlock_bulk(self, idxs: np.ndarray,
                    version: Optional[int] = None) -> None:
        """Release many locks in one sweep (commit publish / rollback).

        ``version`` republishes every word at that clock (the commit /
        deferred-clock-abort paths); ``None`` preserves each word's
        current version (the failed-acquire cleanup path).  Duplicate
        indices are safe WITHIN the sweep — every occurrence stores the
        same unlocked word while the stripes are held, so no explicit
        dedup pass is needed (unlike repeated scalar ``unlock`` calls,
        where a second release could stomp a lock another thread
        acquired in between — the hazard ``engine/commit.py``'s index
        normalization exists for).
        """
        arr = np.asarray(idxs, np.int64)
        stripes = self._stripes.for_indices(arr)
        for s in stripes:
            s.acquire()
        try:
            if version is None:
                w = self._words[arr]
                self._words[arr] = ((w >> _VER_SHIFT) << _VER_SHIFT) \
                    | _UNLOCKED_WORD
            else:
                self._words[arr] = (version << _VER_SHIFT) \
                    | _UNLOCKED_WORD
        finally:
            for s in stripes:
                s.release()
