"""Global clock + atomic primitives for the Layer-A STM.

CPython has no std::atomic; CAS/fetch-add are emulated with striped host
locks.  This changes constant factors, never the algorithm: every lock
protects exactly one CAS/load/store linearization point (DESIGN.md SS2,
honesty note).
"""
from __future__ import annotations

import threading


class AtomicInt:
    __slots__ = ("_v", "_lock")

    def __init__(self, v: int = 0):
        self._v = v
        self._lock = threading.Lock()

    def load(self) -> int:
        return self._v  # aligned word read (GIL-atomic in CPython)

    def store(self, v: int) -> None:
        with self._lock:
            self._v = v

    def increment(self) -> int:
        """fetch-add(1) + 1 — returns the NEW value (paper: gClock.increment)."""
        with self._lock:
            self._v += 1
            return self._v

    def cas(self, expect: int, new: int) -> bool:
        with self._lock:
            if self._v != expect:
                return False
            self._v = new
            return True


class GlobalClock(AtomicInt):
    """DCTL-style deferred clock: read at txn begin/commit; incremented by
    aborting writers (paper Alg. 1 line 30)."""


class Striped:
    """Stripe of host locks for per-address CAS emulation."""

    def __init__(self, n: int = 256):
        self._locks = [threading.Lock() for _ in range(n)]
        self._mask = n - 1

    def for_index(self, idx: int) -> threading.Lock:
        return self._locks[idx & self._mask]

    def for_indices(self, idxs) -> list:
        """The DISTINCT stripe locks covering ``idxs``, ascending.

        The bulk lock-table operations hold every stripe their index
        batch touches for the whole compare-and-sweep; ascending
        acquisition order keeps two concurrent bulk sweeps deadlock-free
        (scalar CAS holds a single stripe, so it can never close a
        cycle).
        """
        import numpy as np

        hit = np.bincount(np.asarray(idxs, np.int64) & self._mask,
                          minlength=self._mask + 1)
        locks = self._locks
        return [locks[int(i)] for i in np.nonzero(hit)[0]]
