"""Snapshot-consistent asynchronous checkpointing via the MVStore.

This is the paper's long-running read as a first-class feature: a
checkpoint is a versioned read-only transaction.  The writer (trainer)
never pauses — the checkpointer resolves a consistent parameter view at
its read clock (`mv_snapshot`) and serializes in a background thread.  In
Mode Q a hot trainer will abort the unversioned read (clock advanced) and
the checkpointer's retries eventually flip the store to Mode U via the
K-heuristics, exactly like any other reader.

On-disk layout:  <dir>/step_<n>/manifest.json + <leaf-index>.npy files.
Restore rebuilds the TrainState (params + moments + clocks) and the data
pipeline resumes from the recorded step (bitwise-deterministic stream).
"""
from __future__ import annotations

import dataclasses
import enum
import json
import os
import queue
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import mvstore
from repro.reliability import faultpoints as FP


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), x) for p, x in flat], treedef


def save_checkpoint(directory: str, step: int, state, *,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous write of a (already consistent) state pytree."""
    d = os.path.join(directory, f"step_{step:08d}")
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(state)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            arr = arr.astype(np.float32)   # np.save can't hold bf16
        fn = f"{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"path": path, "file": fn, "shape": list(arr.shape),
             "dtype": logical_dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if FP.ACTIVE is not None:
        # a crash here leaves only the .tmp directory — restore_checkpoint
        # skips it and recovery replays from the previous manifest
        FP.fire("pre_manifest_publish")
    os.replace(tmp, d)          # atomic publish (restart-crash safe)
    return d


def restore_checkpoint(directory: str, template) -> Tuple[int, Any, Dict]:
    """Latest checkpoint under ``directory`` restored into ``template``'s
    structure.  Returns (step, state, extra)."""
    steps = sorted(p for p in os.listdir(directory)
                   if p.startswith("step_") and not p.endswith(".tmp"))
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, steps[-1])
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    flat, treedef = _flatten(template)
    leaves = []
    for path, leaf in flat:
        e = by_path[path]
        arr = np.load(os.path.join(d, e["file"]))
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype)
                      if hasattr(leaf, "dtype") else arr)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return manifest["step"], state, manifest.get("extra", {})


class SubmitOutcome(enum.Enum):
    """Typed result of ``CheckpointManager.submit``.

    Truthiness preserves the historical bool contract (only SAVED is
    truthy), but callers can now tell a snapshot-read conflict (ABORTED —
    retry next step, the reader's K-heuristics saw the abort) from a
    DROPPED snapshot (QUEUE_FULL — the serializer is behind; the read
    succeeded but nothing will reach disk)."""

    SAVED = "saved"
    QUEUE_FULL = "queue_full"
    ABORTED = "aborted"

    def __bool__(self) -> bool:
        return self is SubmitOutcome.SAVED


class CheckpointManager:
    """Async checkpointer: a snapshot-reader thread that serializes
    consistent views while training proceeds."""

    def __init__(self, directory: str, *, keep: int = 3,
                 reader=None):
        self.directory = directory
        self.keep = keep
        self.reader = reader          # optional mvcontroller.ReaderHandle
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._inflight = 0
        self._cv = threading.Condition()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()
        self.saved = []
        self.errors = []
        self.dropped = 0

    def submit(self, step: int, mv_state: mvstore.MVStoreState, opt_state,
               *, extra=None) -> SubmitOutcome:
        """Take a consistent snapshot NOW (versioned read at the current
        clock) and enqueue serialization.

        ABORTED: the snapshot read conflicted (caller may retry next step
        — the reader retry loop).  QUEUE_FULL: the snapshot was read
        consistently but DROPPED because the serializer is behind; the
        drop is counted in ``stats()`` and the reader does NOT record a
        commit for it (historically it did, silently skewing the
        K-heuristics toward a checkpoint that never existed)."""
        read_clock = int(mv_state.clock)
        if self.reader is not None:
            self.reader.begin(read_clock)
        view, ok = mvstore.mv_snapshot(mv_state, read_clock)
        n_reads = len(jax.tree.leaves(view))
        if not bool(ok):
            if self.reader is not None:
                self.reader.on_abort(n_reads)
            return SubmitOutcome.ABORTED
        # materialize on host before the trainer donates the buffers
        host_view = jax.tree.map(np.asarray, view)
        host_opt = jax.tree.map(np.asarray, opt_state)
        with self._cv:
            try:
                self._q.put_nowait((step, host_view, host_opt, extra))
            except queue.Full:
                self.dropped += 1
                if self.reader is not None:
                    # the read was consistent but nothing durable came of
                    # it — an abort, as far as the heuristics go
                    self.reader.on_abort(n_reads)
                return SubmitOutcome.QUEUE_FULL
            self._inflight += 1
        # on_commit only after the snapshot is durably enqueued
        if self.reader is not None:
            self.reader.on_commit(n_reads, read_clock)
        return SubmitOutcome.SAVED

    def stats(self) -> Dict[str, Any]:
        return {"saved": len(self.saved), "dropped": self.dropped,
                "errors": len(self.errors), "inflight": self._inflight}

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, view, opt, extra = item
            try:
                save_checkpoint(self.directory, step,
                                {"params": view, "opt": opt}, extra=extra)
                self.saved.append(step)
                self._gc()
            except Exception as e:  # noqa: BLE001
                self.errors.append(repr(e))
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    def _gc(self):
        steps = sorted(p for p in os.listdir(self.directory)
                       if p.startswith("step_")
                       and not p.endswith(".tmp"))
        for old in steps[:-self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.directory, old),
                          ignore_errors=True)

    def wait_idle(self, timeout: float = 30.0):
        with self._cv:
            self._cv.wait_for(lambda: self._inflight == 0, timeout=timeout)

    def close(self):
        self.wait_idle()
        self._q.put(None)
        self._worker.join(timeout=5)
