from repro.checkpoint.snapshotter import (  # noqa: F401
    CheckpointManager,
    restore_checkpoint,
    save_checkpoint,
)
