"""Model zoo: unified decoder/enc-dec stacks for the 10 assigned archs."""
from repro.models.model_zoo import (  # noqa: F401
    batch_shapes,
    cache_axes,
    concrete_batch,
    decode_fn,
    init_cache,
    init_params,
    input_specs,
    loss_fn,
    model_flops,
    model_meta,
    param_counts,
    prefill_fn,
)
