"""Shared model components: norms, RoPE, losses, dtype helpers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.launch.sharding import ParamMeta


def rmsnorm_meta(d: int) -> ParamMeta:
    return ParamMeta((d,), (None,), init="ones", dtype="float32")


def rmsnorm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                   # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_xent(logits, labels, vocab_size: int, z_loss: float = 1e-4):
    """Cross entropy in f32 over a (possibly vocab-sharded) logits tensor.

    ``vocab_size`` masks out padded vocab rows (Megatron-style vocab pad).
    Returns mean loss over tokens.
    """
    lf = logits.astype(jnp.float32)
    pad = lf.shape[-1] - vocab_size
    if pad > 0:
        mask = jnp.arange(lf.shape[-1]) < vocab_size
        lf = jnp.where(mask, lf, -1e30)
    m = jnp.max(lf, axis=-1, keepdims=True)
    shifted = lf - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) \
        + jax.lax.stop_gradient(m)[..., 0]
    # one-hot contraction (not take_along_axis): stays elementwise + a
    # reduction over the (possibly model-sharded) vocab dim, so GSPMD only
    # needs an all-reduce — never an all-gather of the logits.
    onehot = jax.nn.one_hot(labels, lf.shape[-1], dtype=lf.dtype)
    label_logit = jnp.sum(lf * onehot, axis=-1)
    nll = lse - label_logit
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse * lse)
    return loss


def count_params(tree) -> int:
    return int(sum(x.size for x in jax.tree.leaves(tree)))


def model_flops_per_token(n_params_active: int) -> int:
    """The 6*N rule (fwd+bwd) per token; callers scale by tokens/step."""
    return 6 * n_params_active
