"""Unified model interface: meta/init/loss/prefill/decode + input specs.

Everything the launcher, dry-run, trainer and server need, dispatched on the
architecture family.  ``input_specs`` follows the assignment contract:
modality frontends are stubs — the specs hand the model precomputed
patch/frame embeddings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.launch.sharding import (ParamMeta, Rules, abstract_params,
                                   materialize, param_specs)
from repro.models import encdec, transformer
from repro.models.transformer import VOCAB_PAD_MULTIPLE


def model_meta(cfg: ModelConfig) -> dict:
    if cfg.is_encdec:
        return encdec.encdec_meta(cfg)
    return transformer.lm_meta(cfg)


def init_params(cfg: ModelConfig, key):
    return materialize(model_meta(cfg), key)


def loss_fn(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    if cfg.is_encdec:
        return encdec.encdec_loss(params, batch, cfg, pcfg)
    return transformer.lm_loss(params, batch, cfg, pcfg)


def prefill_fn(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    if cfg.is_encdec:
        return encdec.encdec_prefill(params, batch, cfg, pcfg)
    return transformer.lm_prefill(params, batch["tokens"], cfg, pcfg,
                                  prefix_embeds=batch.get("patch_embeds"))


def decode_fn(params, cache, cache_len, token, cfg: ModelConfig,
              pcfg: ParallelConfig):
    if cfg.is_encdec:
        return encdec.encdec_decode_step(params, cache, cache_len, token,
                                         cfg, pcfg)
    return transformer.lm_decode_step(params, cache, cache_len, token, cfg,
                                      pcfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    if cfg.is_encdec:
        return encdec.encdec_init_cache(cfg, batch, max_len,
                                        cfg.frontend_len, dtype)
    return transformer.init_cache(cfg, batch, max_len, dtype)


def cache_axes(cfg: ModelConfig):
    if cfg.is_encdec:
        return encdec.encdec_cache_axes()
    return transformer.cache_logical_axes(cfg)


# ---------------------------------------------------------------------------
# Input specs / concrete batches
# ---------------------------------------------------------------------------


def _text_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if cfg.frontend == "vision":
        return shape.seq_len - cfg.frontend_len
    return shape.seq_len


def batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """(shape, dtype, logical-axes) for every model input of a cell."""
    B = shape.global_batch
    st = _text_len(cfg, shape)
    tok_ax = ("batch", None)
    emb_ax = ("batch", None, None)
    if shape.kind == "decode":
        out = {"token": ((B,), jnp.int32, ("batch",)),
               "cache_len": ((B,), jnp.int32, ("batch",))}
        return out
    out = {"tokens": ((B, st), jnp.int32, tok_ax)}
    if shape.kind == "train":
        out["labels"] = ((B, st), jnp.int32, tok_ax)
    if cfg.frontend == "vision":
        out["patch_embeds"] = ((B, cfg.frontend_len, cfg.d_model),
                               jnp.bfloat16, emb_ax)
    if cfg.frontend == "audio":
        out["frame_embeds"] = ((B, cfg.frontend_len, cfg.d_model),
                               jnp.bfloat16, emb_ax)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig, rules: Rules,
                mesh) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins (sharded, no allocation) for the dry-run."""
    from jax.sharding import NamedSharding
    out = {}
    for name, (shp, dt, ax) in batch_shapes(cfg, shape).items():
        out[name] = jax.ShapeDtypeStruct(
            shp, dt, sharding=NamedSharding(mesh, rules.spec(ax)))
    return out


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, key):
    """Small concrete batch for smoke tests / the e2e trainer."""
    out = {}
    for name, (shp, dt, _) in batch_shapes(cfg, shape).items():
        k, key = jax.random.split(key)
        if dt == jnp.int32:
            hi = cfg.vocab_size if name in ("tokens", "labels", "token") \
                else shp[-1] if name == "cache_len" else cfg.vocab_size
            if name == "cache_len":
                out[name] = jnp.full(shp, max(shape.seq_len - 1, 1),
                                     jnp.int32)
            else:
                out[name] = jax.random.randint(k, shp, 0, hi, jnp.int32)
        else:
            out[name] = jax.random.normal(k, shp, jnp.float32).astype(dt)
    return out


# ---------------------------------------------------------------------------
# Parameter accounting (MODEL_FLOPS = 6 * N_active * D)
# ---------------------------------------------------------------------------


def _meta_leaves_with_path(meta):
    flat, _ = jax.tree_util.tree_flatten_with_path(
        meta, is_leaf=lambda x: isinstance(x, ParamMeta))
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf


def param_counts(cfg: ModelConfig) -> Dict[str, int]:
    """total / active / embed-only parameter counts from the meta tree.

    'active' is the 6*N*D numerator: embedding gathers contribute no FLOPs
    (tied embeddings count once — they matmul as the LM head) and routed
    expert weights participate at k/E density.
    """
    total = active = embed = 0
    k, e = cfg.moe.experts_per_token, cfg.moe.num_experts
    for path, m in _meta_leaves_with_path(model_meta(cfg)):
        n = 1
        for s in m.shape:
            n *= s
        total += n
        if "embed" in path:
            embed += n
            if cfg.tie_embeddings:
                active += n  # used as the LM-head matmul
            continue
        if "moe" in path and "shared" not in path and "router" not in path:
            n = int(n * (k / max(e, 1)))
        active += n
    return {"total": total, "active": active, "embed": embed}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D for train cells, 2*N per generated token for decode/prefill."""
    n_active = param_counts(cfg)["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * _text_len(cfg, shape)
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * _text_len(cfg, shape)
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token each
