"""Attention: GQA with a blockwise (flash-style) XLA lowering.

The training/prefill path never materializes the full [S, S] score matrix:
it scans over (q-block, kv-block) pairs — only the causally-reachable lower
triangle of block pairs — maintaining online-softmax statistics.  This is
FlashAttention expressed in XLA ops, so the multi-pod dry-run's
cost_analysis reports the true S^2/2 causal FLOPs and a VMEM-sized working
set (honest roofline inputs).  The Pallas kernel in kernels/flash_attention
is the TPU-target implementation of the same schedule; ``impl='pallas'``
switches to it.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _pairs(nq: int, nk: int, causal: bool) -> np.ndarray:
    """(qi, ki) schedule; causal keeps only the reachable lower triangle."""
    out = []
    for qi in range(nq):
        for ki in range(nk):
            if causal and ki > qi:
                continue
            out.append((qi, ki))
    return np.asarray(out, dtype=np.int32)


def blockwise_attention(q, k, v, *, causal: bool, block_q: int = 1024,
                        block_k: int = 1024, scale: Optional[float] = None,
                        unroll: bool = False):
    """q: [B, Sq, H, D]; k, v: [B, Sk, KV, D] -> [B, Sq, H, D].

    H must be a multiple of KV (GQA).  Block sizes are clamped to the
    sequence lengths; causal requires Sq == Sk and equal blocks.
    ``unroll`` replaces the pair scan with a python loop (roofline probes).
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if causal:
        assert Sq == Sk, "causal blockwise attention needs Sq == Sk"
        bq = bk = min(bq, bk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    # [n, B, KV, G|1, T, D] block-major layouts
    qb = q.reshape(B, nq, bq, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nk, bk, KV, D).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, bk, KV, D).transpose(1, 0, 3, 2, 4)

    tri = jnp.tril(jnp.ones((bq, bk), bool)) if causal else None

    if unroll:
        rows = []
        for qi in range(nq):
            m = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
            l = jnp.zeros((B, KV, G, bq), jnp.float32)
            acc = jnp.zeros((B, KV, G, bq, D), jnp.float32)
            for ki in range(qi + 1 if causal else nk):
                s = jnp.einsum("bkgtd,bkud->bkgtu", qb[qi], kb[ki],
                               preferred_element_type=jnp.float32) * scale
                if causal and ki == qi:
                    s = jnp.where(tri, s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l = l * corr + jnp.sum(p, axis=-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "bkgtu,bkud->bkgtd", p.astype(v.dtype), vb[ki],
                    preferred_element_type=jnp.float32)
                m = m_new
            rows.append(acc / jnp.maximum(l[..., None], 1e-30))
        out = jnp.stack(rows)                     # [nq, B, KV, G, bq, D]
        out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, D)
        return out.astype(q.dtype)

    acc0 = jnp.zeros((nq, B, KV, G, bq, D), jnp.float32)
    m0 = jnp.full((nq, B, KV, G, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, B, KV, G, bq), jnp.float32)

    def step(carry, pair):
        acc, m, l = carry
        qi, ki = pair[0], pair[1]
        qblk = jax.lax.dynamic_index_in_dim(qb, qi, 0, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, ki, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, ki, 0, keepdims=False)
        s = jnp.einsum("bkgtd,bkud->bkgtu", qblk, kblk,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            s = jnp.where(jnp.logical_or(qi != ki, tri), s, NEG_INF)
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_old - m_new)
        l_new = l_old * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgtu,bkud->bkgtd", p.astype(v.dtype), vblk,
                        preferred_element_type=jnp.float32)
        a_new = a_old * corr[..., None] + pv
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        return (acc, m, l), None

    pairs = jnp.asarray(_pairs(nq, nk, causal))
    # checkpoint the pair body: its backward otherwise saves the f32
    # [B,KV,G,Tq,Tk] score/softmax tensors for EVERY pair (n^2/2 blocks of
    # S^2 memory — exactly what blockwise attention exists to avoid)
    (acc, _, l), _ = jax.lax.scan(jax.checkpoint(step), (acc0, m0, l0),
                                  pairs)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # back to [B, Sq, H, D]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def naive_attention(q, k, v, *, causal: bool, scale: Optional[float] = None):
    """Reference: full score matrix (small shapes / oracles only)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("btkgd,bukd->bkgtu", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgtu,bukd->btkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def attention(q, k, v, *, causal: bool, impl: str = "blockwise",
              block_q: int = 1024, block_k: int = 1024,
              scale: Optional[float] = None, unroll: bool = False):
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, scale=scale)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal,
                                    block_q=block_q, block_k=block_k)
    return blockwise_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, scale=scale, unroll=unroll)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     scale: Optional[float] = None, chunk: int = 0,
                     unroll: bool = False):
    """Single-token decode vs a KV cache.

    q: [B, H, D]; k_cache/v_cache: [B, S, KV, D]; cache_len: [B] int32
    (number of valid positions).  ``chunk`` > 0 scans the KV in chunks
    (long-context; keeps the score row tiled).
    """
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, KV, G, D)

    if chunk and S % chunk == 0 and S > chunk:
        nc = S // chunk
        kb = k_cache.reshape(B, nc, chunk, KV, D).transpose(1, 0, 3, 2, 4)
        vb = v_cache.reshape(B, nc, chunk, KV, D).transpose(1, 0, 3, 2, 4)

        if unroll:
            m = jnp.full((B, KV, G), NEG_INF, jnp.float32)
            l = jnp.zeros((B, KV, G), jnp.float32)
            acc = jnp.zeros((B, KV, G, D), jnp.float32)
            for ci in range(nc):
                s = jnp.einsum("bkgd,bkud->bkgu", qg, kb[ci],
                               preferred_element_type=jnp.float32) * scale
                pos = ci * chunk + jnp.arange(chunk)
                s = jnp.where(pos[None, None, None, :]
                              < cache_len[:, None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l = l * corr + jnp.sum(p, axis=-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "bkgu,bkud->bkgd", p.astype(vb.dtype), vb[ci],
                    preferred_element_type=jnp.float32)
                m = m_new
            out = acc / jnp.maximum(l[..., None], 1e-30)
            return out.reshape(B, H, D).astype(q.dtype)

        def step(carry, xs):
            m, l, acc = carry
            kblk, vblk, ci = xs
            s = jnp.einsum("bkgd,bkud->bkgu", qg, kblk,
                           preferred_element_type=jnp.float32) * scale
            pos = ci * chunk + jnp.arange(chunk)
            s = jnp.where(pos[None, None, None, :] < cache_len[:, None, None,
                                                              None],
                          s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgu,bkud->bkgd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G), jnp.float32)
        a0 = jnp.zeros((B, KV, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0), (kb, vb, jnp.arange(nc)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(B, H, D).astype(q.dtype)

    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    s = jnp.where(pos[None, None, None, :] < cache_len[:, None, None, None],
                  s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, D).astype(q.dtype)
