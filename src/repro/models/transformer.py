"""Decoder-only LM: scanned layer groups, train / prefill / decode paths.

Layers are stacked and scanned in *groups* of one interleave period (period
1 for uniform archs; 8 for jamba's 1:7 attn:mamba + alternating dense/MoE
pattern) so the traced HLO contains one period regardless of depth — this
is what keeps 88-layer lowering tractable and the compiled program compact.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.sharding import ParamMeta, shard_act, stack_meta
from repro.models import blocks
from repro.models import mamba as mamba_mod
from repro.models.common import rmsnorm, rmsnorm_meta, softmax_xent

VOCAB_PAD_MULTIPLE = 256


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map (>=0.5) vs jax.experimental.shard_map: on the older
    API, skip replication checking the same way check_vma=False does."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def layer_period(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 1
    p = 1
    if cfg.attn_layer_period:
        p = cfg.attn_layer_period
    if cfg.moe.num_experts:
        p = math.lcm(p, cfg.moe.every_n_layers)
    return p


def layer_kinds(cfg: ModelConfig):
    """[(mixer, ffn)] for each sub-layer of one period."""
    kinds = []
    for i in range(layer_period(cfg)):
        mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
        if cfg.is_moe_layer(i):
            ffn = "moe"
        elif cfg.d_ff:
            ffn = "dense"
        else:
            ffn = "none"
        kinds.append((mixer, ffn))
    return kinds


def n_groups(cfg: ModelConfig) -> int:
    p = layer_period(cfg)
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return cfg.n_layers // p


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def lm_meta(cfg: ModelConfig) -> dict:
    vpad = cfg.padded_vocab(VOCAB_PAD_MULTIPLE)
    group = {f"sub{j}": blocks.sublayer_meta(cfg, kind)
             for j, kind in enumerate(layer_kinds(cfg))}
    meta = {
        "embed": ParamMeta((vpad, cfg.d_model), ("fsdp", "tp"),
                           init="embed", dtype=cfg.dtype),
        "layers": stack_meta(group, n_groups(cfg)),
        "final_norm": rmsnorm_meta(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        meta["lm_head"] = ParamMeta((cfg.d_model, vpad), ("fsdp", "vocab"),
                                    dtype=cfg.dtype)
    return meta


def embed_lookup(table, tokens, pcfg: ParallelConfig):
    from repro.launch.sharding import current_mesh, current_rules

    rules, mesh = current_rules(), current_mesh()
    if pcfg.gather_mode == "onehot":
        oh = jax.nn.one_hot(tokens, table.shape[0], dtype=table.dtype)
        h = oh @ table
    elif mesh is not None and rules is not None:
        # Explicit shard_map: GSPMD's gather partitioning mishandles a
        # 2D-sharded table (fsdp x tp).  Each device all-gathers the table
        # rows over the fsdp axis (cheap: the width stays tp-sharded) and
        # gathers locally; the backward transposes to scatter-add +
        # reduce-scatter automatically.
        fsdp_ax = rules.get("fsdp")

        def body(tbl, tok):
            if fsdp_ax is not None:
                tbl = jax.lax.all_gather(tbl, fsdp_ax, axis=0, tiled=True)
            return jnp.take(tbl, tok, axis=0)

        h = _shard_map(
            body, mesh=mesh,
            in_specs=(rules.spec(("fsdp", "tp")),
                      rules.spec(("batch", None))),
            out_specs=rules.spec(("batch", None, "tp")))(table, tokens)
    else:
        h = jnp.take(table, tokens, axis=0)
    return shard_act(h, ("batch", None, None))


def lm_logits(params, h, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    return shard_act(logits, ("batch", None, "vocab"))


# ---------------------------------------------------------------------------
# Sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def lm_forward(params, tokens, cfg: ModelConfig, pcfg: ParallelConfig, *,
               prefix_embeds=None, want_cache: bool = False):
    """tokens: [B, S_text].  Returns (hidden [B, S_total, d], cache, aux)."""
    kinds = layer_kinds(cfg)
    h = embed_lookup(params["embed"], tokens, pcfg)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
        h = shard_act(h, ("batch", None, None))
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]

    def group_body(carry, gp):
        x, aux = carry
        caches = {}
        for j, kind in enumerate(kinds):
            x, c, a = blocks.sublayer_apply(
                gp[f"sub{j}"], x, kind, cfg, pcfg, positions=positions,
                want_cache=want_cache)
            aux = aux + a
            if want_cache:
                caches[f"sub{j}"] = c
        return (x, aux), caches if want_cache else None

    remat_on = pcfg.remat != "none" and not want_cache
    if remat_on:
        group_body = jax.checkpoint(group_body)
    k = 1
    if remat_on and pcfg.remat.startswith("group:"):
        k = int(pcfg.remat.split(":")[1])

    if pcfg.scan_layers and k > 1 and not want_cache:
        # Two-level checkpointing: scan over super-groups of k periods,
        # saving one residual per super-group instead of per period —
        # peak activation memory / k at ~(1 + 1/k) recompute cost.
        G = n_groups(cfg)
        assert G % k == 0, (G, k)
        stacked = jax.tree.map(
            lambda x: x.reshape((G // k, k) + x.shape[1:]),
            params["layers"])

        def outer_body(carry, gpk):
            for j in range(k):
                gp = jax.tree.map(lambda t: t[j], gpk)
                carry, _ = group_body(carry, gp)
            return carry, None

        (h, aux), _ = jax.lax.scan(
            jax.checkpoint(outer_body),
            (h, jnp.zeros((), jnp.float32)), stacked)
        caches = None
    elif pcfg.scan_layers:
        (h, aux), caches = jax.lax.scan(
            group_body, (h, jnp.zeros((), jnp.float32)), params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        cs = []
        for g in range(n_groups(cfg)):
            gp = jax.tree.map(lambda x: x[g], params["layers"])
            (h, aux), c = group_body((h, aux), gp)
            cs.append(c)
        caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *cs)
                  if want_cache else None)
    h = rmsnorm(h, params["final_norm"], cfg.rms_eps)
    return h, caches, aux


def lm_loss(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    """batch: tokens [B, S_text], labels [B, S_text], optional
    patch_embeds/frame_embeds [B, F, d].  Returns scalar loss."""
    prefix = batch.get("patch_embeds")
    h, _, aux = lm_forward(params, batch["tokens"], cfg, pcfg,
                           prefix_embeds=prefix)
    if prefix is not None:
        h = h[:, prefix.shape[1]:]
    logits = lm_logits(params, h, cfg)
    loss = softmax_xent(logits, batch["labels"], cfg.vocab_size)
    return loss + aux


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Zeroed decode cache for the scanned stack (leaves lead with groups)."""
    kinds = layer_kinds(cfg)
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    group_cache = {}
    for j, (mixer, _) in enumerate(kinds):
        if mixer == "attn":
            group_cache[f"sub{j}"] = {
                "k": jnp.zeros((batch, max_len, kv * dh), dtype),
                "v": jnp.zeros((batch, max_len, kv * dh), dtype),
            }
        else:
            st = mamba_mod.mamba_init_state(batch, cfg.d_model, cfg.mamba,
                                            dtype)
            group_cache[f"sub{j}"] = dict(st._asdict())
    g = n_groups(cfg)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (g,) + x.shape), group_cache)


def cache_logical_axes(cfg: ModelConfig):
    """Logical axes tree matching init_cache output (for dry-run specs)."""
    kinds = layer_kinds(cfg)
    group = {}
    for j, (mixer, _) in enumerate(kinds):
        if mixer == "attn":
            group[f"sub{j}"] = {
                "k": (None, "batch", "seq_shard", "kv_flat"),
                "v": (None, "batch", "seq_shard", "kv_flat"),
            }
        else:
            group[f"sub{j}"] = {
                "ssm": (None, "batch", "tp", None, None),
                "conv_x": (None, "batch", None, "tp"),
                "conv_B": (None, "batch", None, None),
                "conv_C": (None, "batch", None, None),
            }
    return group


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def lm_prefill(params, tokens, cfg: ModelConfig, pcfg: ParallelConfig, *,
               prefix_embeds=None):
    """Returns (last-position logits [B, V], cache, cache_len [B])."""
    h, caches, _ = lm_forward(params, tokens, cfg, pcfg,
                              prefix_embeds=prefix_embeds, want_cache=True)
    logits = lm_logits(params, h[:, -1:], cfg)[:, 0]
    B, S = h.shape[0], h.shape[1]
    return logits, caches, jnp.full((B,), S, jnp.int32)


def lm_decode_step(params, cache, cache_len, token, cfg: ModelConfig,
                   pcfg: ParallelConfig):
    """One decode step.  token: [B] int32; cache_len: [B] valid positions.

    Returns (logits [B, V], new_cache, new_cache_len).
    """
    kinds = layer_kinds(cfg)
    h = embed_lookup(params["embed"], token[:, None], pcfg)

    def apply_group(x, gp, gc):
        new_gc = {}
        for j, kind in enumerate(kinds):
            x, c, _ = blocks.sublayer_apply(
                gp[f"sub{j}"], x, kind, cfg, pcfg, positions=None,
                cache=gc[f"sub{j}"], cache_len=cache_len, moe_groups=1)
            new_gc[f"sub{j}"] = c
        return x, new_gc

    if pcfg.scan_layers:
        # The cache rides in the CARRY (not xs/ys): XLA aliases while-loop
        # carries in place, so the multi-GB KV buffers are updated without
        # a second copy (xs/ys stacking would double-buffer them).
        def group_body(carry, xs):
            x, full_cache = carry
            gp, g = xs
            gc = jax.tree.map(
                lambda buf: jax.lax.dynamic_index_in_dim(
                    buf, g, 0, keepdims=False), full_cache)
            x, new_gc = apply_group(x, gp, gc)
            full_cache = jax.tree.map(
                lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                    buf, new.astype(buf.dtype), g, 0), full_cache, new_gc)
            return (x, full_cache), None

        (h, new_cache), _ = jax.lax.scan(
            group_body, (h, cache),
            (params["layers"], jnp.arange(n_groups(cfg))))
    else:
        new_cache = cache
        for g in range(n_groups(cfg)):
            gp = jax.tree.map(lambda x: x[g], params["layers"])
            gc = jax.tree.map(lambda x: x[g], new_cache)
            h, nc = apply_group(h, gp, gc)
            new_cache = jax.tree.map(
                lambda buf, new: buf.at[g].set(new.astype(buf.dtype)),
                new_cache, nc)
    h = rmsnorm(h, params["final_norm"], cfg.rms_eps)
    logits = lm_logits(params, h, cfg)[:, 0]
    return logits, new_cache, cache_len + 1
