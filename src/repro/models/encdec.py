"""Encoder-decoder (seamless-m4t): bidirectional encoder over stub frame
embeddings, causal decoder with cross-attention.  Both stacks are scanned."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.sharding import ParamMeta, shard_act, stack_meta
from repro.models import blocks
from repro.models import ffn as ffn_mod
from repro.models.common import rmsnorm, rmsnorm_meta, softmax_xent
from repro.models.transformer import (VOCAB_PAD_MULTIPLE, embed_lookup,
                                      lm_logits)


def encdec_meta(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    vpad = cfg.padded_vocab(VOCAB_PAD_MULTIPLE)
    enc_layer = {
        "norm_attn": rmsnorm_meta(d),
        "attn": blocks.attn_meta(cfg),
        "norm_ffn": rmsnorm_meta(d),
        "ffn": ffn_mod.ffn_meta(d, cfg.d_ff, cfg.dtype),
    }
    dec_layer = {
        "norm_self": rmsnorm_meta(d),
        "self_attn": blocks.attn_meta(cfg),
        "norm_cross": rmsnorm_meta(d),
        "cross_attn": blocks.attn_meta(cfg, cross=True),
        "norm_ffn": rmsnorm_meta(d),
        "ffn": ffn_mod.ffn_meta(d, cfg.d_ff, cfg.dtype),
    }
    return {
        "embed": ParamMeta((vpad, d), ("fsdp", "tp"), init="embed",
                           dtype=cfg.dtype),
        "encoder": stack_meta(enc_layer, cfg.n_encoder_layers),
        "enc_norm": rmsnorm_meta(d),
        "decoder": stack_meta(dec_layer, cfg.n_layers),
        "final_norm": rmsnorm_meta(d),
        "lm_head": ParamMeta((d, vpad), ("fsdp", "vocab"), dtype=cfg.dtype),
    }


def encode(params, frame_embeds, cfg: ModelConfig, pcfg: ParallelConfig):
    """frame_embeds: [B, F, d] (stub audio frontend output)."""
    h = shard_act(frame_embeds, ("batch", None, None))
    F = h.shape[1]
    positions = jnp.arange(F)[None, :]

    def body(x, lp):
        y = blocks.attn_apply(lp["attn"],
                              rmsnorm(x, lp["norm_attn"], cfg.rms_eps),
                              cfg, pcfg, positions=positions, causal=False)
        x = x + y
        x = x + ffn_mod.ffn_apply(
            lp["ffn"], rmsnorm(x, lp["norm_ffn"], cfg.rms_eps))
        return x, None

    if pcfg.remat == "block":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["encoder"])
    return rmsnorm(h, params["enc_norm"], cfg.rms_eps)


def decode_seq(params, tokens, enc_out, cfg: ModelConfig,
               pcfg: ParallelConfig, *, want_cache: bool = False):
    """Full-sequence decoder pass (train / prefill)."""
    h = embed_lookup(params["embed"], tokens, pcfg)
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        y = blocks.attn_apply(
            lp["self_attn"], rmsnorm(x, lp["norm_self"], cfg.rms_eps),
            cfg, pcfg, positions=positions, causal=True,
            want_cache=want_cache)
        if want_cache:
            y, (sk, sv) = y
        x = x + y
        hc = rmsnorm(x, lp["norm_cross"], cfg.rms_eps)
        yc = blocks.attn_apply(lp["cross_attn"], hc, cfg, pcfg,
                               positions=positions, causal=False,
                               kv_source=enc_out, use_rope=False,
                               want_cache=want_cache)
        if want_cache:
            yc, (ck, cv) = yc
        x = x + yc
        x = x + ffn_mod.ffn_apply(
            lp["ffn"], rmsnorm(x, lp["norm_ffn"], cfg.rms_eps))
        cache = ({"k": sk, "v": sv, "cross_k": ck, "cross_v": cv}
                 if want_cache else None)
        return x, cache

    if pcfg.remat == "block" and not want_cache:
        body = jax.checkpoint(body)
    h, caches = jax.lax.scan(body, h, params["decoder"])
    return rmsnorm(h, params["final_norm"], cfg.rms_eps), caches


def encdec_loss(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    enc_out = encode(params, batch["frame_embeds"], cfg, pcfg)
    h, _ = decode_seq(params, batch["tokens"], enc_out, cfg, pcfg)
    logits = lm_logits(params, h, cfg)
    return softmax_xent(logits, batch["labels"], cfg.vocab_size)


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int, dtype):
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    layer = {
        "k": jnp.zeros((batch, max_len, kv * dh), dtype),
        "v": jnp.zeros((batch, max_len, kv * dh), dtype),
        "cross_k": jnp.zeros((batch, enc_len, kv * dh), dtype),
        "cross_v": jnp.zeros((batch, enc_len, kv * dh), dtype),
    }
    L = cfg.n_layers
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), layer)


def encdec_cache_axes():
    ax = (None, "batch", "seq_shard", "kv_flat")
    return {"k": ax, "v": ax, "cross_k": ax, "cross_v": ax}


def encdec_prefill(params, batch, cfg: ModelConfig, pcfg: ParallelConfig):
    enc_out = encode(params, batch["frame_embeds"], cfg, pcfg)
    h, caches = decode_seq(params, batch["tokens"], enc_out, cfg, pcfg,
                           want_cache=True)
    logits = lm_logits(params, h[:, -1:], cfg)[:, 0]
    B, S = batch["tokens"].shape
    return logits, caches, jnp.full((B,), S, jnp.int32)


def encdec_decode_step(params, cache, cache_len, token, cfg: ModelConfig,
                       pcfg: ParallelConfig):
    h = embed_lookup(params["embed"], token[:, None], pcfg)
    B = token.shape[0]
    enc_len = cache["cross_k"].shape[2]
    cross_len = jnp.full((B,), enc_len, jnp.int32)

    def body(carry, xs):
        x, full_cache = carry
        lp, li = xs
        lc = jax.tree.map(
            lambda buf: jax.lax.dynamic_index_in_dim(
                buf, li, 0, keepdims=False), full_cache)
        y, ck, cv = blocks.attn_decode(
            lp["self_attn"], rmsnorm(x, lp["norm_self"], cfg.rms_eps),
            cfg, pcfg, cache_k=lc["k"], cache_v=lc["v"],
            cache_len=cache_len)
        x = x + y
        yc, _, _ = blocks.attn_decode(
            lp["cross_attn"], rmsnorm(x, lp["norm_cross"], cfg.rms_eps),
            cfg, pcfg, cache_k=lc["cross_k"], cache_v=lc["cross_v"],
            cache_len=cache_len, cross=True, cross_len=cross_len)
        x = x + yc
        x = x + ffn_mod.ffn_apply(
            lp["ffn"], rmsnorm(x, lp["norm_ffn"], cfg.rms_eps))
        # self-attn cache rides in the carry -> in-place while-loop alias
        full_cache = jax.tree.map(
            lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                buf, new.astype(buf.dtype), li, 0),
            full_cache, {"k": ck, "v": cv, "cross_k": lc["cross_k"],
                         "cross_v": lc["cross_v"]})
        return (x, full_cache), None

    (h, new_cache), _ = jax.lax.scan(
        body, (h, cache),
        (params["decoder"], jnp.arange(cfg.n_layers)))
    h = rmsnorm(h, params["final_norm"], cfg.rms_eps)
    logits = lm_logits(params, h, cfg)[:, 0]
    return logits, new_cache, cache_len + 1
