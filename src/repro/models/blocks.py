"""Decoder sub-layers: attention / mamba mixers + dense/MoE FFN, pre-norm."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.sharding import ParamMeta, shard_act
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.common import apply_rope, rmsnorm, rmsnorm_meta


# ---------------------------------------------------------------------------
# Attention sub-layer
# ---------------------------------------------------------------------------


def attn_meta(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    m = {
        "w_q": ParamMeta((d, h * dh), ("fsdp", "tp"), dtype=cfg.dtype),
        "w_k": ParamMeta((d, kv * dh), ("fsdp", "kv_flat"), dtype=cfg.dtype),
        "w_v": ParamMeta((d, kv * dh), ("fsdp", "kv_flat"), dtype=cfg.dtype),
        "w_o": ParamMeta((h * dh, d), ("tp", "fsdp"), dtype=cfg.dtype),
    }
    if cfg.qkv_bias and not cross:
        m["b_q"] = ParamMeta((h * dh,), ("tp",), init="zeros",
                             dtype=cfg.dtype)
        m["b_k"] = ParamMeta((kv * dh,), ("kv_flat",), init="zeros",
                             dtype=cfg.dtype)
        m["b_v"] = ParamMeta((kv * dh,), ("kv_flat",), init="zeros",
                             dtype=cfg.dtype)
    return m


def _qkv(p, x, cfg: ModelConfig):
    q = x @ p["w_q"]
    k = x @ p["w_k"]
    v = x @ p["w_v"]
    if "b_q" in p:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    return q, k, v


def attn_apply(p, x, cfg: ModelConfig, pcfg: ParallelConfig, *,
               positions, causal: bool = True,
               kv_source: Optional[jnp.ndarray] = None,
               use_rope: bool = True, want_cache: bool = False):
    """Full-sequence attention (train / prefill / encoder / cross).

    x: [B, S, d].  ``kv_source`` switches to cross-attention.
    Returns y or (y, (k_flat, v_flat)) when ``want_cache``.
    """
    B, S, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    src = x if kv_source is None else kv_source
    q = x @ p["w_q"]
    k = src @ p["w_k"]
    v = src @ p["w_v"]
    if "b_q" in p:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = shard_act(q, ("batch", None, "tp"))
    k = shard_act(k, ("batch", None, "kv_flat"))
    v = shard_act(v, ("batch", None, "kv_flat"))
    qh = q.reshape(B, S, h, dh)
    kh = k.reshape(B, src.shape[1], kv, dh)
    vh = v.reshape(B, src.shape[1], kv, dh)
    if use_rope:
        qh = apply_rope(qh, positions, cfg.rope_theta)
        kh = apply_rope(kh, positions if kv_source is None
                        else jnp.arange(src.shape[1])[None], cfg.rope_theta)
    o = attn_mod.attention(qh, kh, vh, causal=causal, impl=pcfg.attn_impl,
                           block_q=pcfg.attn_block_q,
                           block_k=pcfg.attn_block_k,
                           unroll=pcfg.probe_unroll)
    y = o.reshape(B, S, h * dh) @ p["w_o"]
    y = shard_act(y, ("batch", None, None))
    if want_cache:
        return y, (kh.reshape(B, -1, kv * dh), vh.reshape(B, -1, kv * dh))
    return y


def attn_decode(p, x, cfg: ModelConfig, pcfg: ParallelConfig, *,
                cache_k, cache_v, cache_len,
                cross: bool = False, cross_len=None):
    """One-token decode.  x: [B, 1, d]; cache_*: [B, Smax, kv*dh];
    cache_len: [B] valid positions.  Self-attention appends to the cache;
    cross-attention reads it.  Returns (y, cache_k, cache_v)."""
    B = x.shape[0]
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(p, x, cfg)
    qh = q.reshape(B, 1, h, dh)
    if not cross:
        qh = apply_rope(qh, cache_len[:, None], cfg.rope_theta)
        kh = apply_rope(k.reshape(B, 1, kv, dh), cache_len[:, None],
                        cfg.rope_theta)
        bidx = jnp.arange(B)
        cache_k = cache_k.at[bidx, cache_len].set(
            kh.reshape(B, kv * dh).astype(cache_k.dtype))
        cache_v = cache_v.at[bidx, cache_len].set(
            v.reshape(B, kv * dh).astype(cache_v.dtype))
        valid = cache_len + 1
    else:
        valid = cross_len
    S = cache_k.shape[1]
    kc = cache_k.reshape(B, S, kv, dh)
    vc = cache_v.reshape(B, S, kv, dh)
    o = attn_mod.decode_attention(qh[:, 0], kc, vc, valid,
                                  chunk=pcfg.decode_attn_chunk,
                                  unroll=pcfg.probe_unroll)
    y = o.reshape(B, 1, h * dh) @ p["w_o"]
    return shard_act(y, ("batch", None, None)), cache_k, cache_v


# ---------------------------------------------------------------------------
# Unified sub-layer (mixer + optional FFN), used by the scanned stacks
# ---------------------------------------------------------------------------


def sublayer_meta(cfg: ModelConfig, kind: Tuple[str, str]) -> dict:
    mixer, ffn = kind
    d = cfg.d_model
    m = {"norm_mixer": rmsnorm_meta(d)}
    if mixer == "attn":
        m["attn"] = attn_meta(cfg)
    else:
        m["mamba"] = mamba_mod.mamba_meta(d, cfg.mamba, cfg.dtype)
    if ffn == "dense":
        m["ffn"] = ffn_mod.ffn_meta(d, cfg.d_ff, cfg.dtype)
        m["norm_ffn"] = rmsnorm_meta(d)
    elif ffn == "moe":
        m["moe"] = moe_mod.moe_meta(d, cfg.moe, cfg.dtype)
        m["norm_ffn"] = rmsnorm_meta(d)
    return m


def sublayer_apply(p, x, kind, cfg: ModelConfig, pcfg: ParallelConfig, *,
                   positions, cache=None, cache_len=None,
                   want_cache: bool = False, moe_groups=None):
    """Apply one (mixer, ffn) sub-layer.

    Sequence mode: cache is None (train) or absent-but-wanted (prefill).
    Decode mode: cache is this sub-layer's state dict; returns new cache.
    Returns (y, new_cache_or_None, aux_loss).
    """
    mixer, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    h = rmsnorm(x, p["norm_mixer"], cfg.rms_eps)
    decode = cache is not None and x.shape[1] == 1

    if mixer == "attn":
        if decode:
            y, ck, cv = attn_decode(p["attn"], h, cfg, pcfg,
                                    cache_k=cache["k"], cache_v=cache["v"],
                                    cache_len=cache_len)
            new_cache = {"k": ck, "v": cv}
        elif want_cache:
            y, (ck, cv) = attn_apply(p["attn"], h, cfg, pcfg,
                                     positions=positions, want_cache=True)
            new_cache = {"k": ck, "v": cv}
        else:
            y = attn_apply(p["attn"], h, cfg, pcfg, positions=positions)
    else:
        if decode or want_cache:
            mstate = mamba_mod.MambaState(**cache) if cache is not None \
                else None
            if mstate is None and want_cache:
                mstate = mamba_mod.mamba_init_state(
                    x.shape[0], cfg.d_model, cfg.mamba, x.dtype)
            y, mnew = mamba_mod.mamba_apply(
                p["mamba"], h, cfg.mamba, rms_eps=cfg.rms_eps, state=mstate,
                remat_chunk=pcfg.remat != "none",
                unroll=pcfg.probe_unroll)
            new_cache = dict(mnew._asdict())
        else:
            y = mamba_mod.mamba_apply(p["mamba"], h, cfg.mamba,
                                      rms_eps=cfg.rms_eps,
                                      remat_chunk=pcfg.remat != "none",
                                      unroll=pcfg.probe_unroll)
    x = x + y

    if ffn == "dense":
        h = rmsnorm(x, p["norm_ffn"], cfg.rms_eps)
        x = x + ffn_mod.ffn_apply(p["ffn"], h)
    elif ffn == "moe":
        h = rmsnorm(x, p["norm_ffn"], cfg.rms_eps)
        y, aux = moe_mod.moe_apply(p["moe"], h, cfg.moe,
                                   capacity_factor=pcfg.moe_capacity_factor,
                                   groups=moe_groups)
        x = x + y
    return x, new_cache, aux
