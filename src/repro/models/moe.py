"""Top-k mixture-of-experts with expert parallelism over the 'model' axis.

Dispatch is sort-based (MegaBlocks-style), not GShard one-hot-einsum:
the [tokens, experts, capacity] dense dispatch tensor of the einsum
formulation is O(N*E*C) and cannot fit HBM at assigned sizes, so routing is
computed with integer sort/scatter/gather ops (O(N*k)) and the only large
tensors are the dispatched token buffers themselves.

Tokens are routed within *groups* (default: one group per sequence, as in
GShard).  The group dim stays batch-sharded through routing — every gather
/scatter is group-local, so GSPMD emits no routing collectives — and the
single reshard of the dispatch buffer from batch-sharded to expert-sharded
is the all-to-all (visible as such in the dry-run HLO, SSRoofline).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.launch.sharding import ParamMeta, shard_act


def moe_meta(d_model: int, cfg: MoEConfig, dtype: str) -> dict:
    e, f = cfg.num_experts, cfg.d_ff_expert
    p = {
        "w_router": ParamMeta((d_model, e), (None, None), dtype="float32"),
        "w_gate": ParamMeta((e, d_model, f), ("experts", "fsdp", None),
                            dtype=dtype),
        "w_up": ParamMeta((e, d_model, f), ("experts", "fsdp", None),
                          dtype=dtype),
        "w_down": ParamMeta((e, f, d_model), ("experts", None, "fsdp"),
                            dtype=dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": ParamMeta((d_model, fs), ("fsdp", "tp"), dtype=dtype),
            "w_up": ParamMeta((d_model, fs), ("fsdp", "tp"), dtype=dtype),
            "w_down": ParamMeta((fs, d_model), ("tp", "fsdp"), dtype=dtype),
        }
    return p


def _capacity(tokens_per_group: int, cfg: MoEConfig,
              capacity_factor: float) -> int:
    cap = int(tokens_per_group * cfg.experts_per_token * capacity_factor
              / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8


def route(x_groups, w_router, cfg: MoEConfig, capacity_factor: float):
    """Compute dispatch/combine indices.

    x_groups: [G, N, d] -> (slot_token [G, E*C] int32 with sentinel N,
    slot_of  [G, N, k] int32 with sentinel E*C, weights [G, N, k] f32,
    aux_loss scalar).
    """
    G, N, _ = x_groups.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = _capacity(N, cfg, capacity_factor)

    logits = (x_groups.astype(jnp.float32) @ w_router)        # [G, N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = jax.lax.top_k(probs, K)                    # [G, N, K]
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch): mean prob * mean assignment per expert.
    me = jnp.mean(probs, axis=1)                              # [G, E]
    ce = jnp.zeros((G, E), jnp.float32).at[
        jnp.arange(G)[:, None, None], sel].add(1.0) / (N * K)
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

    flat_e = sel.reshape(G, N * K)                            # [G, NK]
    order = jnp.argsort(flat_e, axis=-1, stable=True)         # [G, NK]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jnp.zeros((G, E), jnp.int32).at[
        jnp.arange(G)[:, None], flat_e].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts             # [G, E]
    pos = (jnp.arange(N * K)[None, :]
           - jnp.take_along_axis(starts, sorted_e, axis=-1))  # [G, NK]
    keep = pos < C
    slot_sorted = jnp.where(keep, sorted_e * C + pos, E * C)  # [G, NK]
    token_sorted = order // K                                 # token index

    gi = jnp.arange(G)[:, None]
    # slot -> token map (sentinel token id N reads the zero pad row)
    slot_token = jnp.full((G, E * C + 1), N, jnp.int32).at[
        gi, slot_sorted].set(jnp.where(keep, token_sorted, N))[:, :E * C]
    # token -> its K slots, in original (token, k) order
    slot_of = jnp.full((G, N * K), E * C, jnp.int32).at[
        gi, order].set(slot_sorted).reshape(G, N, K)
    return slot_token, slot_of, weights, aux


def moe_apply(params, x, cfg: MoEConfig, *, capacity_factor: float = 1.25,
              groups: Optional[int] = None):
    """x: [B, S, d] -> ([B, S, d], aux_loss).

    ``groups``: routing group count; default one group per sequence (B).
    Decode callers (S == 1) pass groups=1 so the whole batch is one group.
    """
    B, S, d = x.shape
    G = groups if groups else B
    x_groups = x.reshape(G, (B * S) // G, d)
    N = x_groups.shape[1]
    E, K = cfg.num_experts, cfg.experts_per_token
    C = _capacity(N, cfg, capacity_factor)

    slot_token, slot_of, weights, aux = route(
        x_groups, params["w_router"], cfg, capacity_factor)

    # dispatch: gather token rows into [G, E, C, d]; pad row N reads zeros.
    # take_along_axis (NOT advanced int-array indexing): GSPMD recognizes
    # it as a batched gather over the group dim — int-array indexing makes
    # the partitioner replicate the GLOBAL dispatch buffer on every chip
    # (measured 12 GB/chip/layer on moonshot; EXPERIMENTS.md SSPerf A1).
    xp = jnp.concatenate(
        [x_groups, jnp.zeros((G, 1, d), x.dtype)], axis=1)    # [G, N+1, d]
    xd = jnp.take_along_axis(xp, slot_token[:, :, None], axis=1)
    xd = xd.reshape(G, E, C, d)
    # reshard: batch-sharded groups -> expert-sharded buffers (all-to-all)
    xd = shard_act(xd, ("batch", "experts", None, None))

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xd, params["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xd, params["w_up"])
    yd = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    # reshard back to batch-sharded groups (all-to-all)
    yd = shard_act(yd, ("batch", None, None, None))

    yflat = jnp.concatenate(
        [yd.reshape(G, E * C, d),
         jnp.zeros((G, 1, d), yd.dtype)], axis=1)             # [G, EC+1, d]
    y_tok = jnp.take_along_axis(
        yflat, slot_of.reshape(G, N * K)[:, :, None], axis=1)
    y_tok = y_tok.reshape(G, N, K, d)                         # [G, N, K, d]
    # combine in bf16: an f32 upcast here makes every backward cotangent
    # through the dispatch buffers f32 (2x collective bytes)
    y = jnp.sum(y_tok * weights[..., None].astype(y_tok.dtype), axis=2)
    y = y.astype(x.dtype).reshape(B, S, d)

    if "shared" in params:
        from repro.models.ffn import ffn_apply
        y = y + ffn_apply(params["shared"], x)
    return shard_act(y, ("batch", None, None)), aux * cfg.router_aux_weight
