"""Dense feed-forward (SwiGLU) with Megatron column/row parallel sharding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import ParamMeta, shard_act


def ffn_meta(d_model: int, d_ff: int, dtype: str) -> dict:
    # column-parallel in (tp on d_ff), row-parallel out (fsdp on d_ff)
    return {
        "w_gate": ParamMeta((d_model, d_ff), ("fsdp", "tp"), dtype=dtype),
        "w_up": ParamMeta((d_model, d_ff), ("fsdp", "tp"), dtype=dtype),
        "w_down": ParamMeta((d_ff, d_model), ("tp", "fsdp"), dtype=dtype),
    }


def ffn_apply(params, x):
    """x: [B, S, d] -> [B, S, d]."""
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shard_act(h, ("batch", None, "tp"))
    y = h @ params["w_down"]
    return shard_act(y, ("batch", None, None))
