"""Mamba-2 SSD (state-space duality) mixer, chunked-scan formulation.

Training/prefill use the chunked SSD algorithm: quadratic attention-like
compute *within* a chunk (MXU-friendly Q x Q matmuls) and a linear state
recurrence *across* chunks (lax.scan).  Decode is the O(1) recurrent update.
The per-chunk body is the compute hot spot that kernels/ssd_scan.py
implements as a Pallas kernel; this module is the pure-XLA lowering used by
the dry-run and the ref oracle.

Projections are kept separate (w_z/w_x/w_B/w_C/w_dt) rather than fused so
each output dim shards cleanly over the 'model' axis (DESIGN.md SS6).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig
from repro.launch.sharding import ParamMeta, shard_act
from repro.models.common import rmsnorm, rmsnorm_meta


class SSMDims(NamedTuple):
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int


def ssm_dims(d_model: int, cfg: MambaConfig) -> SSMDims:
    d_inner = cfg.expand * d_model
    assert d_inner % cfg.head_dim == 0
    return SSMDims(d_inner, d_inner // cfg.head_dim, cfg.head_dim,
                   cfg.d_state)


def mamba_meta(d_model: int, cfg: MambaConfig, dtype: str) -> dict:
    dims = ssm_dims(d_model, cfg)
    di, h, n = dims.d_inner, dims.n_heads, dims.d_state
    return {
        "w_z": ParamMeta((d_model, di), ("fsdp", "tp"), dtype=dtype),
        "w_x": ParamMeta((d_model, di), ("fsdp", "tp"), dtype=dtype),
        "w_B": ParamMeta((d_model, n), ("fsdp", None), dtype=dtype),
        "w_C": ParamMeta((d_model, n), ("fsdp", None), dtype=dtype),
        "w_dt": ParamMeta((d_model, h), ("fsdp", "tp"), dtype=dtype),
        "conv_x": ParamMeta((cfg.d_conv, di), (None, "tp"), init="normal",
                            scale=0.5, dtype="float32"),
        "conv_B": ParamMeta((cfg.d_conv, n), (None, None), init="normal",
                            scale=0.5, dtype="float32"),
        "conv_C": ParamMeta((cfg.d_conv, n), (None, None), init="normal",
                            scale=0.5, dtype="float32"),
        "A_log": ParamMeta((h,), ("tp",), init="zeros", dtype="float32"),
        "D": ParamMeta((h,), ("tp",), init="ones", dtype="float32"),
        "dt_bias": ParamMeta((h,), ("tp",), init="zeros", dtype="float32"),
        "norm": rmsnorm_meta(di),
        "w_out": ParamMeta((di, d_model), ("tp", "fsdp"), dtype=dtype),
    }


def _causal_conv(x, w, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x: [B, S, C]; w: [K, C].

    With ``state`` ([B, K-1, C], previous raw inputs) performs the decode
    step (S == 1) and returns (y, new_state); otherwise returns y.
    """
    k = w.shape[0]
    if state is not None:
        buf = jnp.concatenate([state, x], axis=1)        # [B, K, C]
        y = jnp.einsum("bkc,kc->bc", buf.astype(jnp.float32),
                       w.astype(jnp.float32))[:, None, :]
        return y.astype(x.dtype), buf[:, 1:]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1]].astype(jnp.float32)
            * w[i].astype(jnp.float32) for i in range(k))
    return y.astype(x.dtype)


def ssd_chunk_scan(xh, dt, A, B_, C_, *, chunk: int, init_state=None,
                   remat_chunk: bool = True, impl: str = "xla",
                   unroll: bool = False):
    """Chunked SSD scan.

    xh: [B, S, H, P]; dt: [B, S, H] (post-softplus); A: [H] (negative);
    B_, C_: [B, S, N].  Returns (y [B, S, H, P], final_state [B, H, N, P]).
    """
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.ssd_scan(xh, dt, A, B_, C_, chunk=chunk,
                             init_state=init_state)
    Bsz, S, H, Pd = xh.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xc = xh.reshape(Bsz, nc, Q, H, Pd).swapaxes(0, 1)    # [nc, B, Q, H, P]
    dtc = dt.reshape(Bsz, nc, Q, H).swapaxes(0, 1)
    Bc = B_.reshape(Bsz, nc, Q, N).swapaxes(0, 1)
    Cc = C_.reshape(Bsz, nc, Q, N).swapaxes(0, 1)

    def chunk_body(state, xs):
        x_q, dt_q, b_q, c_q = xs                          # per-chunk slices
        dA = dt_q * A[None, None, :]                      # [B, Q, H] (<= 0)
        cum = jnp.cumsum(dA, axis=1)                      # inclusive
        # intra-chunk (attention-like, causal with decay weights)
        cb = jnp.einsum("bin,bjn->bij", c_q.astype(jnp.float32),
                        b_q.astype(jnp.float32))          # [B, Q, Q]
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        m = jnp.where(tri[None, :, :, None],
                      cb[..., None] * decay * dt_q[:, None, :, :], 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m,
                             x_q.astype(jnp.float32))
        # contribution of the carried state
        y_inter = jnp.einsum("bin,bhnp->bihp", c_q.astype(jnp.float32),
                             state) * jnp.exp(cum)[..., None]
        # state update for the next chunk
        sdecay = jnp.exp(cum[:, -1:, :] - cum) * dt_q     # [B, Q, H]
        s_new = jnp.einsum("bjn,bjhp->bhnp", b_q.astype(jnp.float32),
                           x_q.astype(jnp.float32) * sdecay[..., None])
        state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + s_new
        return state, (y_intra + y_inter).astype(xh.dtype)

    if remat_chunk:
        chunk_body = jax.checkpoint(chunk_body)
    state0 = (init_state if init_state is not None
              else jnp.zeros((Bsz, H, N, Pd), jnp.float32))
    if unroll:
        state, ys = state0, []
        for c in range(nc):
            state, yq = chunk_body(state, (xc[c], dtc[c], Bc[c], Cc[c]))
            ys.append(yq)
        yc = jnp.stack(ys)
    else:
        state, yc = jax.lax.scan(chunk_body, state0, (xc, dtc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(Bsz, S, H, Pd)
    return y, state


def ssd_decode_step(state, x, dt, A, B_, C_):
    """O(1) recurrent step.  state: [B, H, N, P]; x: [B, H, P];
    dt: [B, H]; B_, C_: [B, N].  Returns (y [B, H, P], new_state)."""
    dA = jnp.exp(dt * A[None, :])                         # [B, H]
    upd = jnp.einsum("bn,bhp->bhnp", B_.astype(jnp.float32),
                     x.astype(jnp.float32) * dt[..., None])
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhnp,bn->bhp", state, C_.astype(jnp.float32))
    return y.astype(x.dtype), state


class MambaState(NamedTuple):
    ssm: jnp.ndarray      # [B, H, N, P] f32
    conv_x: jnp.ndarray   # [B, K-1, d_inner]
    conv_B: jnp.ndarray   # [B, K-1, N]
    conv_C: jnp.ndarray   # [B, K-1, N]


def mamba_init_state(batch: int, d_model: int, cfg: MambaConfig,
                     dtype) -> MambaState:
    dims = ssm_dims(d_model, cfg)
    k = cfg.d_conv - 1
    return MambaState(
        ssm=jnp.zeros((batch, dims.n_heads, dims.d_state, dims.head_dim),
                      jnp.float32),
        conv_x=jnp.zeros((batch, k, dims.d_inner), dtype),
        conv_B=jnp.zeros((batch, k, dims.d_state), dtype),
        conv_C=jnp.zeros((batch, k, dims.d_state), dtype),
    )


def mamba_apply(params, x, cfg: MambaConfig, *, rms_eps: float = 1e-5,
                state: Optional[MambaState] = None, impl: str = "xla",
                remat_chunk: bool = True, unroll: bool = False):
    """Mamba-2 block.  x: [B, S, d].

    Sequence mode (state=None): returns y [B, S, d].
    Decode mode (state given, S==1): returns (y, new_state).
    """
    Bsz, S, d = x.shape
    dims = ssm_dims(d, cfg)
    H, Pd, N = dims.n_heads, dims.head_dim, dims.d_state

    z = x @ params["w_z"]                                  # [B, S, di]
    xr = x @ params["w_x"]
    br = x @ params["w_B"]
    cr = x @ params["w_C"]
    dt_raw = x @ params["w_dt"]                            # [B, S, H]
    z = shard_act(z, ("batch", None, "tp"))
    xr = shard_act(xr, ("batch", None, "tp"))

    decode = state is not None and S == 1
    if decode:
        xc, conv_x = _causal_conv(xr, params["conv_x"], state.conv_x)
        bc, conv_B = _causal_conv(br, params["conv_B"], state.conv_B)
        cc, conv_C = _causal_conv(cr, params["conv_C"], state.conv_C)
    else:
        xc = _causal_conv(xr, params["conv_x"])
        bc = _causal_conv(br, params["conv_B"])
        cc = _causal_conv(cr, params["conv_C"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    bc = jax.nn.silu(bc.astype(jnp.float32)).astype(x.dtype)
    cc = jax.nn.silu(cc.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))      # [H], negative
    xh = xc.reshape(Bsz, S, H, Pd)

    if decode:
        y1, ssm = ssd_decode_step(state.ssm, xh[:, 0], dt[:, 0], A,
                                  bc[:, 0], cc[:, 0])
        y = y1[:, None]                                    # [B, 1, H, P]
        new_state = MambaState(ssm, conv_x, conv_B, conv_C)
    else:
        y, final = ssd_chunk_scan(
            xh, dt, A, bc, cc, chunk=cfg.chunk,
            init_state=state.ssm if state is not None else None,
            remat_chunk=remat_chunk, impl=impl, unroll=unroll)
        new_state = (MambaState(final, *_tail_conv(xr, br, cr, cfg))
                     if state is not None else None)

    y = y + xh.astype(jnp.float32).astype(y.dtype) \
        * params["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(Bsz, S, dims.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rmsnorm(y, params["norm"], rms_eps)
    out = y @ params["w_out"]
    out = shard_act(out, ("batch", None, None))
    if state is not None:
        return out, new_state
    return out


def _tail_conv(xr, br, cr, cfg: MambaConfig):
    k = cfg.d_conv - 1
    return xr[:, -k:], br[:, -k:], cr[:, -k:]
