"""deepseek-7b [dense] — llama-architecture, MHA (kv == heads).

30L d_model=4096 32H (GQA kv=32) d_ff=11008 vocab=102400.  [arXiv:2401.02954; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    head_dim=128,
    supports_long_context=False,
    long_context_note="pure full attention decoder",
    source="arXiv:2401.02954; hf",
)
