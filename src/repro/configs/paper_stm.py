"""The paper's own experimental configuration (SS5 of the paper).

These are the STM-level tunables and workload definitions used to reproduce
the paper's figures with the Layer-A faithful STM (core/stm.py + structs/).
"""
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MultiverseParams:
    """Tunable parameters, defaults exactly as SS5 'Tunable Parameters'."""

    k1: int = 100      # unversioned-reader attempts before going versioned
    k2: int = 16       # attempts before an unversioned reader CASes Q->QtoU
    k3: int = 28       # attempts before a versioned reader CASes Q->QtoU
    s: int = 10        # consecutive small txns to clear the sticky-U bit
    l: int = 10        # length of the commit-ts-delta average list (L)
    p: float = 0.10    # prefix fraction of the sorted delta list (P)
    lock_table_bits: int = 16       # 2^16 entries in lock/bloom/VLT tables
    bloom_bits: int = 64            # bits per per-bucket bloom filter
    unversion_poll_ms: float = 2.0  # background-thread poll period
    max_ring: int = 0               # 0 = unbounded version lists (paper)


@dataclass(frozen=True)
class WorkloadConfig:
    """One benchmark workload, paper SS5 style.

    Percentages over regular-thread ops; remaining weight after search/rq is
    split equally between insert and delete.  Dedicated updaters perform
    writes that never commit read-only and are NOT counted in throughput.
    """

    name: str
    structure: str = "abtree"       # abtree | hashmap | extbst
    prefill: int = 1_000_000
    key_range: int = 2_000_000
    search_pct: float = 0.8999
    rq_pct: float = 0.0001
    rq_size: int = 10_000
    n_threads: int = 8
    n_dedicated_updaters: int = 0
    duration_s: float = 2.0
    trials: int = 1
    updater_sleep_s: float = 0.0   # throttle dedicated updaters (GIL cal.)


# The representative workloads of Fig. 1 / Fig. 6 (scaled down for this
# container in benchmarks/ -- prefill and duration shrink, ratios preserved).
FIG6_WORKLOADS = [
    WorkloadConfig("no_rq_0upd", rq_pct=0.0, search_pct=0.90),
    WorkloadConfig("rq_0upd", rq_pct=0.0001, search_pct=0.8999),
    WorkloadConfig("no_rq_16upd", rq_pct=0.0, search_pct=0.90,
                   n_dedicated_updaters=4),
    WorkloadConfig("rq_16upd", rq_pct=0.0001, search_pct=0.8999,
                   n_dedicated_updaters=4),
]

DEFAULT_PARAMS = MultiverseParams()
