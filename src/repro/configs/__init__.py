"""Config registry: ``get_config('<arch-id>')`` and reduced smoke variants.

Arch ids use dashes (CLI form, e.g. ``--arch qwen2.5-3b``); module names use
underscores.  ``SHAPES`` holds the assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    SHAPES,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    MVStoreConfig,
    ParallelConfig,
    RunConfig,
    ShapeConfig,
)

from repro.configs import (  # noqa: E402  (registry imports)
    deepseek_7b,
    jamba_v0_1_52b,
    llama4_scout_17b_a16e,
    mamba2_780m,
    minitron_4b,
    mistral_large_123b,
    moonshot_v1_16b_a3b,
    paligemma_3b,
    qwen2_5_3b,
    seamless_m4t_medium,
)

_MODULES = [
    jamba_v0_1_52b,
    paligemma_3b,
    qwen2_5_3b,
    deepseek_7b,
    mistral_large_123b,
    minitron_4b,
    mamba2_780m,
    llama4_scout_17b_a16e,
    moonshot_v1_16b_a3b,
    seamless_m4t_medium,
]

REGISTRY: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

ARCH_IDS = sorted(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {', '.join(ARCH_IDS)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests: same family/structure, tiny dims.
# ---------------------------------------------------------------------------


def smoke_config(name: str) -> ModelConfig:
    """A reduced config of the same family as ``name``.

    Keeps the structural features (GQA ratio, MoE routing, hybrid interleave,
    enc-dec, frontend stubs) while shrinking width/depth/vocab so one
    forward/train step runs on CPU in seconds.
    """
    full = get_config(name)
    n_layers = {
        "hybrid": 8,   # one full interleave period
        "moe": 2,
        "ssm": 2,
    }.get(full.family, 2)
    if full.is_encdec:
        n_layers = 2
    kv_ratio = max(1, full.n_heads // max(full.n_kv_heads, 1))
    n_heads = 4 if full.n_heads else 0
    n_kv = max(1, n_heads // kv_ratio) if n_heads else 0
    moe = full.moe
    if moe.num_experts:
        moe = dataclasses.replace(
            moe,
            num_experts=min(8, moe.num_experts),
            experts_per_token=min(2, moe.experts_per_token),
            d_ff_expert=64,
        )
    mamba = dataclasses.replace(
        full.mamba, d_state=16, head_dim=8, chunk=32)
    return dataclasses.replace(
        full,
        name=full.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16 if n_heads else 0,
        d_ff=128 if full.d_ff else 0,
        vocab_size=512,
        moe=moe,
        mamba=mamba,
        n_encoder_layers=2 if full.is_encdec else 0,
        frontend_len=min(full.frontend_len, 8),
        attn_layer_period=full.attn_layer_period and 4,
        attn_layer_offset=full.attn_layer_offset and 2,
    )


SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
SMOKE_DECODE_SHAPE = ShapeConfig(
    "smoke_decode", seq_len=32, global_batch=2, kind="decode")

__all__ = [
    "ARCH_IDS",
    "REGISTRY",
    "SHAPES",
    "SMOKE_SHAPE",
    "SMOKE_DECODE_SHAPE",
    "MambaConfig",
    "ModelConfig",
    "MoEConfig",
    "MVStoreConfig",
    "ParallelConfig",
    "RunConfig",
    "ShapeConfig",
    "get_config",
    "get_shape",
    "smoke_config",
]
