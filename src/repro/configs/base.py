"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input-shape
cells are ``ShapeConfig``s; parallel/runtime knobs live in ``ParallelConfig``
and ``MVStoreConfig`` (the paper's technique).  Configs are plain frozen
dataclasses so they hash (usable as jit static args) and print reproducibly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shape cells (assigned): seq_len x global_batch, and which step they lower.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Parallelism / performance knobs.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelConfig:
    """How a step is sharded over the production mesh.

    The mesh axes are ('pod',) 'data', 'model'.  Defaults implement
    DP+FSDP over 'data' (and 'pod'), Megatron TP + expert parallelism over
    'model'.  ``pipeline_stages`` > 1 activates the optional pipeline
    schedule over the 'pod' axis (multi-pod meshes only).
    """

    fsdp: bool = True                 # shard params/opt-state over 'data' too
    microbatches: int = 1             # gradient-accumulation steps (scan)
    remat: str = "block"              # 'none' | 'block' (checkpoint each layer)
    attn_impl: str = "blockwise"      # 'blockwise' | 'pallas' | 'naive'
    attn_block_q: int = 1024          # blockwise-attention tile sizes
    attn_block_k: int = 1024
    decode_attn_chunk: int = 0        # 0 = unchunked decode attention
    pipeline_stages: int = 1          # >1: pipeline over 'pod'
    moe_capacity_factor: float = 1.25
    # beyond-paper perf knobs (hillclimb; see EXPERIMENTS.md SSPerf)
    gather_mode: str = "take"         # embedding lookup: 'take' | 'onehot'
    scan_layers: bool = True
    # roofline-probe mode: unroll every inner scan (attention pair loop,
    # SSD chunk loop, decode chunks, microbatches) so HLO cost analysis
    # counts true per-step work (XLA counts while bodies once)
    probe_unroll: bool = False


@dataclass(frozen=True)
class MVStoreConfig:
    """The paper's technique (dynamic multiversioning) at the parameter-store
    level.  ``ring_slots`` is R, the bounded version-list length (TPU
    adaptation of the paper's unbounded lists).  ``mode`` selects the traced
    local mode of the compiled step ('Q' = unversioned fast path, 'U' =
    copy-on-write versioned commit).  See core/mvstore.py.
    """

    enabled: bool = True
    ring_slots: int = 2
    mode: str = "Q"                   # local mode baked into the traced step
    fused_commit: bool = False        # use the fused_adamw Pallas kernel path

    def replace(self, **kw) -> "MVStoreConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Model architecture.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    num_shared_experts: int = 0
    every_n_layers: int = 1           # MoE replaces FFN every n layers
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    d_conv: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    moe: MoEConfig = field(default_factory=MoEConfig)
    # SSM / hybrid
    mamba: MambaConfig = field(default_factory=MambaConfig)
    attn_layer_period: int = 0        # hybrid: 1 attention layer per period
    attn_layer_offset: int = 0
    # encoder-decoder
    is_encdec: bool = False
    n_encoder_layers: int = 0
    # modality frontend (stub): number of prepended embedding positions
    frontend: str = "none"            # none | vision | audio
    frontend_len: int = 0
    # capability flags
    supports_long_context: bool = False  # sub-quadratic path for long_500k
    long_context_note: str = ""
    # numerics
    dtype: str = "bfloat16"
    source: str = ""                  # provenance tag from the assignment

    # -- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def padded_vocab(self, multiple: int = 256) -> int:
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple

    def is_moe_layer(self, i: int) -> bool:
        m = self.moe
        if m.num_experts == 0:
            return False
        return (i % m.every_n_layers) == (m.every_n_layers - 1)

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid archs: which mixer a layer uses (attention vs mamba)."""
        if self.family == "ssm":
            return False
        if self.attn_layer_period <= 0:
            return True
        return (i % self.attn_layer_period) == self.attn_layer_offset

    def supports_shape(self, shape: ShapeConfig) -> Tuple[bool, str]:
        """Whether a shape cell is runnable; returns (ok, skip-reason)."""
        if shape.name == "long_500k" and not self.supports_long_context:
            return False, (
                "long_500k skipped: pure full-attention arch (no "
                "sub-quadratic path); see DESIGN.md SS5"
            )
        return True, ""


@dataclass(frozen=True)
class RunConfig:
    """One fully-specified run: arch x shape x parallelism x MVStore mode."""

    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    mvstore: MVStoreConfig = field(default_factory=MVStoreConfig)
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
