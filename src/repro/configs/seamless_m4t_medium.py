"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.  [arXiv:2308.11596; hf]

Per the assignment the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings for the encoder.  12 encoder + 12 decoder
layers; decode shapes run the decoder (self-KV cache of seq_len, cross-attn
over a fixed 4096-frame encoder output).  Vocab 256206 is not 16-divisible;
padded to a multiple of 256 (256256) for TP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    is_encdec=True,
    n_encoder_layers=12,
    frontend="audio",
    frontend_len=4096,
    supports_long_context=False,
    long_context_note="enc-dec full attention",
    source="arXiv:2308.11596; hf",
)
