"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  [arXiv:2403.19887; hf]

Real Jamba uses attn_layer_period=8 / offset=4 and MoE every 2nd layer with
16 experts top-2; its mamba mixer is Mamba-1 with d_state=16 — we use our
SSD (Mamba-2 style) mixer with d_state=16, noted as a deviation in DESIGN.md
(the SSD formulation is the TPU-native chunked form of the same SSM).
Hybrid + SSM decode path -> supports long_500k.
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    moe=MoEConfig(
        num_experts=16,
        experts_per_token=2,
        d_ff_expert=14336,
        every_n_layers=2,
    ),
    mamba=MambaConfig(d_state=16, expand=2, head_dim=64, d_conv=4, chunk=256),
    attn_layer_period=8,
    attn_layer_offset=4,
    supports_long_context=True,
    long_context_note=(
        "hybrid 1:7 attn:mamba; the 4 attention layers decode in O(seq) per "
        "token against a 500k KV cache that fits when sharded"
    ),
    source="arXiv:2403.19887; hf",
)
