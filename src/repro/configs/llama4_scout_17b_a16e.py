"""llama4-scout-17b-a16e [moe] — MoE 16e top-1 + shared expert, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Llama-4 routes top-1 over 16 experts plus one always-on shared expert;
every layer is MoE.  (Its interleaved NoPE/chunked attention is not modeled;
we treat it as full attention -> long_500k skipped.)
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    moe=MoEConfig(
        num_experts=16,
        experts_per_token=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        every_n_layers=1,
    ),
    supports_long_context=False,
    long_context_note="treated as full attention (chunked-attn not modeled)",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
