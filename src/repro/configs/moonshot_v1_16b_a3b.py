"""moonshot-v1-16b-a3b [moe] — kimi/moonlight-style fine-grained MoE, 64e top-6.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840.
[hf:moonshotai/Moonlight-16B-A3B; hf]

Fine-grained experts (d_ff_expert=1408), 64 experts top-6, every layer MoE.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    head_dim=128,
    moe=MoEConfig(
        num_experts=64,
        experts_per_token=6,
        d_ff_expert=1408,
        every_n_layers=1,
    ),
    supports_long_context=False,
    long_context_note="pure full attention decoder",
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
