"""mamba2-780m [ssm] — attention-free, SSD (state-space duality).

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128.  [arXiv:2405.21060; unverified]

d_inner = expand*d_model = 3072, SSD head_dim 64 -> 48 SSD heads.
Vocab 50280 is not 16-divisible; padded to a multiple of 256 (50432) for TP
(Megatron-style; logits over pad ids are masked to -inf).
Attention-free -> runs long_500k natively (O(1) decode state).
"""
from repro.configs.base import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    mamba=MambaConfig(d_state=128, expand=2, head_dim=64, d_conv=4, chunk=256),
    tie_embeddings=True,
    supports_long_context=True,
    long_context_note="pure SSM: O(1) state decode, chunked-scan prefill",
    source="arXiv:2405.21060; unverified",
)
