"""paligemma-3b [vlm] — SigLIP vision frontend (stub) + gemma decoder.

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.  [arXiv:2407.07726; hf]

Per the assignment the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (224px/14 -> 256 patches) that are
prepended to the token embeddings.  gemma uses head_dim=256 (8 heads x 256 =
2048) and MQA (kv=1).  Vocab 257216 is 16-divisible; padded to %256 anyway.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    frontend="vision",
    frontend_len=256,
    supports_long_context=False,
    long_context_note="pure full attention decoder",
    source="arXiv:2407.07726; hf",
)
