"""Deterministic synthetic data pipeline.

Each (step, shard) pair maps to an independent counter-based stream, so:
  * every data-parallel host materializes ONLY its shard (no host holds the
    global batch);
  * restarts are exactly reproducible (checkpoint stores just the step);
  * elastic rescaling re-partitions deterministically (shard i of N draws
    the same tokens regardless of which host computes it).

The token process is a noisy affine walk over the vocab — enough structure
that a small LM's loss falls measurably within tens of steps (the e2e
test's assertion), with an exact analytic entropy floor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: int = 3          # next = a*tok + c + U[0, noise)  (mod V)
    a: int = 5
    c: int = 17

    def shard_batch(self, step: int, shard: int, n_shards: int
                    ) -> Dict[str, np.ndarray]:
        """The rows of the global batch owned by ``shard``."""
        assert self.global_batch % n_shards == 0
        rows = self.global_batch // n_shards
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[step, shard, 0, 0]))
        start = rng.integers(0, self.vocab_size, size=(rows, 1))
        steps = rng.integers(0, self.noise,
                             size=(rows, self.seq_len))
        toks = np.empty((rows, self.seq_len + 1), np.int64)
        toks[:, 0] = start[:, 0]
        for t in range(self.seq_len):
            toks[:, t + 1] = (self.a * toks[:, t] + self.c
                              + steps[:, t]) % self.vocab_size
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return self.shard_batch(step, 0, 1)

    def entropy_floor(self) -> float:
        return float(np.log(self.noise))


def make_batch_iterator(cfg: ModelConfig, shape: ShapeConfig, *,
                        seed: int = 0, shard: int = 0, n_shards: int = 1,
                        start_step: int = 0,
                        frontend_dim: Optional[int] = None
                        ) -> Iterator[Dict[str, np.ndarray]]:
    """Batches for a model config: tokens/labels (+ stub frontend
    embeddings for vlm/audio archs)."""
    text_len = shape.seq_len - (cfg.frontend_len
                                if cfg.frontend == "vision" else 0)
    src = SyntheticLM(cfg.vocab_size, text_len, shape.global_batch,
                      seed=seed)
    d = frontend_dim or cfg.d_model
    step = start_step
    while True:
        batch = src.shard_batch(step, shard, n_shards)
        if cfg.frontend == "vision":
            rng = np.random.Generator(np.random.Philox(
                key=seed + 1, counter=[step, shard, 0, 0]))
            batch["patch_embeds"] = rng.standard_normal(
                (batch["tokens"].shape[0], cfg.frontend_len, d),
                dtype=np.float32)
        if cfg.frontend == "audio":
            rng = np.random.Generator(np.random.Philox(
                key=seed + 1, counter=[step, shard, 0, 0]))
            batch["frame_embeds"] = rng.standard_normal(
                (batch["tokens"].shape[0], cfg.frontend_len, d),
                dtype=np.float32)
        yield batch
        step += 1
