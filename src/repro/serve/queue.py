"""Request queue with arrival timestamping and admission control.

The queue is the service's back-pressure boundary: an open-loop load
generator offers requests at wall-clock arrival times regardless of how
fast the scheduler drains them, so when the store is slow (e.g. Mode-Q
aborts burn decode steps) depth grows and the queue SHEDS instead of
letting latency run away unbounded.  Shedding is a typed outcome
(`Admission`), never an exception — the caller records it in telemetry.

Admission rejects when either bound trips:
  * depth:  queued requests >= ``max_depth``
  * wait:   estimated queue wait exceeds ``wait_budget_s``, where the
    estimate is ``depth * service_time / n_servers`` — the classic
    M/M/c eyeball using observed per-request service time fed back by
    the scheduler (``note_service_time``).

With ``autotune=True`` (the default when a budget is set) the wait
estimate uses ``max(EMA, rolling p99)`` of observed service times
instead of the EMA alone: an EMA is mean-seeking, so a bimodal service
distribution (fast cache-hit decodes + occasional Mode-Q abort storms)
lets the mean admit a queue whose TAIL blows the budget.  Tracking the
p99 reservoir effectively TIGHTENS the budget under slow-tail service —
``effective_wait_budget_s`` reports the equivalent fixed budget — and
relaxes back as the tail drains, with no operator knob.

Thread-safe: the load generator and the scheduler loop may live on
different threads (examples/serve_snapshots.py does exactly that).
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional


class Admission(enum.Enum):
    """Typed admission outcome for one offered request."""

    ADMITTED = "admitted"
    SHED_DEPTH = "shed_depth"      # bounded queue full
    SHED_WAIT = "shed_wait"        # estimated wait over budget
    CLOSED = "closed"              # queue draining / shut down

    @property
    def shed(self) -> bool:
        return self in (Admission.SHED_DEPTH, Admission.SHED_WAIT)


class Outcome(enum.Enum):
    """Lifecycle outcome of an admitted request."""

    PENDING = "pending"
    COMPLETED = "completed"
    FAILED_ABORTS = "failed_aborts"   # gave up after max snapshot aborts


@dataclasses.dataclass
class Request:
    """One generation request moving through queue -> slot -> done.

    Timestamps are perf_counter seconds; ``-1.0`` means "not yet".
    ``pinned_clock`` is the snapshot clock the request is being served
    at (re-pinned after a Mode-Q abort); ``served_clocks`` records every
    clock a produced token actually came from, so telemetry can tell a
    single-version request from one that silently mixed parameter
    versions (the unversioned baseline's failure mode).
    """

    rid: int
    payload: Any = None               # model path: [S] int32 prompt
    max_new: int = 8                  # tokens wanted (incl. prefill token)
    t_arrival: float = -1.0
    t_admitted: float = -1.0
    t_dequeued: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0
    pinned_clock: int = -1
    served_clocks: List[int] = dataclasses.field(default_factory=list)
    tokens: List[int] = dataclasses.field(default_factory=list)
    aborts: int = 0                   # snapshot-read aborts (Mode Q)
    prefill_retries: int = 0
    outcome: Outcome = Outcome.PENDING

    @property
    def queue_wait_s(self) -> float:
        if self.t_dequeued < 0 or self.t_arrival < 0:
            return 0.0
        return self.t_dequeued - self.t_arrival

    @property
    def ttft_s(self) -> float:
        if self.t_first_token < 0 or self.t_arrival < 0:
            return 0.0
        return self.t_first_token - self.t_arrival

    @property
    def latency_s(self) -> float:
        if self.t_done < 0 or self.t_arrival < 0:
            return 0.0
        return self.t_done - self.t_arrival

    @property
    def mixed_versions(self) -> bool:
        return len(set(self.served_clocks)) > 1


class RequestQueue:
    """Bounded FIFO with wait-budget admission control.

    ``n_servers`` is the scheduler's slot count — the wait estimate
    assumes freed slots drain the queue ``n_servers`` at a time.  The
    service-time EMA starts at ``est_service_s`` and is updated by the
    scheduler on every completion, so admission adapts to the measured
    speed of the store it happens to be serving from.
    """

    def __init__(self, max_depth: int = 64,
                 wait_budget_s: Optional[float] = None,
                 n_servers: int = 1, est_service_s: float = 0.05,
                 ema_alpha: float = 0.2, autotune: bool = True,
                 reservoir_capacity: int = 512):
        from repro.serve.metrics import PercentileReservoir
        self.max_depth = max_depth
        self.wait_budget_s = wait_budget_s
        self.n_servers = max(1, n_servers)
        self.ema_alpha = ema_alpha
        self.autotune = autotune
        self._service_ema = est_service_s
        self._service_p99 = PercentileReservoir(capacity=reservoir_capacity)
        self._q: deque = deque()
        self._lock = threading.Lock()
        self._closed = False
        self.counters: Dict[str, int] = {
            "offered": 0, "admitted": 0, "shed_depth": 0,
            "shed_wait": 0, "closed": 0,
        }

    # -- admission ------------------------------------------------------
    def offer(self, req: Request, now: Optional[float] = None) -> Admission:
        """Admit or shed ``req``; stamps arrival/admission times."""
        now = time.perf_counter() if now is None else now
        req.t_arrival = now if req.t_arrival < 0 else req.t_arrival
        with self._lock:
            self.counters["offered"] += 1
            if self._closed:
                self.counters["closed"] += 1
                return Admission.CLOSED
            if len(self._q) >= self.max_depth:
                self.counters["shed_depth"] += 1
                return Admission.SHED_DEPTH
            if (self.wait_budget_s is not None
                    and self._estimated_wait() > self.wait_budget_s):
                self.counters["shed_wait"] += 1
                return Admission.SHED_WAIT
            req.t_admitted = now
            self._q.append(req)
            self.counters["admitted"] += 1
            return Admission.ADMITTED

    def get(self, now: Optional[float] = None) -> Optional[Request]:
        """Non-blocking pop for the scheduler's refill pass."""
        with self._lock:
            if not self._q:
                return None
            req = self._q.popleft()
        req.t_dequeued = time.perf_counter() if now is None else now
        return req

    # -- feedback / introspection --------------------------------------
    def note_service_time(self, dt: float) -> None:
        """Scheduler feedback: observed per-request service seconds."""
        with self._lock:
            a = self.ema_alpha
            self._service_ema = (1 - a) * self._service_ema + a * dt
            self._service_p99.add(dt)

    def _per_request_s(self) -> float:
        # caller holds the lock.  Autotune: plan for the TAIL, not the
        # mean — max(EMA, p99) so a slow-tail service distribution
        # tightens admission while a uniform one degrades to the EMA.
        if self.autotune and self._service_p99.count:
            p99 = self._service_p99.percentile(99)
            if p99 == p99:                  # not NaN
                return max(self._service_ema, p99)
        return self._service_ema

    def _estimated_wait(self) -> float:
        # caller holds the lock
        return len(self._q) * self._per_request_s() / self.n_servers

    def estimated_wait_s(self) -> float:
        with self._lock:
            return self._estimated_wait()

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._q)

    @property
    def service_ema_s(self) -> float:
        with self._lock:
            return self._service_ema

    @property
    def service_p99_s(self) -> float:
        with self._lock:
            return self._service_p99.percentile(99)

    @property
    def effective_wait_budget_s(self) -> Optional[float]:
        """The fixed budget this queue currently behaves like: the
        configured budget scaled by ``ema / max(ema, p99)``.  Equal to
        ``wait_budget_s`` when autotune is off or the tail is no slower
        than the mean; TIGHTER (smaller) under a slow tail."""
        with self._lock:
            if self.wait_budget_s is None:
                return None
            per = self._per_request_s()
            if per <= 0:
                return self.wait_budget_s
            return self.wait_budget_s * self._service_ema / per

    # -- drain ----------------------------------------------------------
    def close(self) -> None:
        """Stop admitting; already-queued requests still drain."""
        with self._lock:
            self._closed = True

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed
