"""Continuous-batching scheduler over a fixed slot pool.

The scheduler owns WHICH request runs in WHICH slot and at WHAT
snapshot clock; it knows nothing about models or stores.  Executors
implement the ``SlotExecutor`` protocol:

  * ``n_slots``                    — fixed decode batch width
  * ``current_clock()``            — the store's commit clock now
  * ``prefill(slot, req, clock)``  — admit a request into a slot at a
    pinned snapshot clock; returns ``StepResult`` (ok + first token)
  * ``decode(slots, clocks)``      — ONE decode step for the active
    slots, each resolved at its pinned clock; returns a ``StepResult``
    per slot

Scheduling policy (the continuous-batching part): every ``step()``
first REFILLS free slots from the queue — a freed slot takes a new
request immediately, the batch never drains to empty before admitting
more — then runs one decode step for everything active.  A request's
snapshot clock is pinned at prefill; a Mode-Q snapshot abort (ok=False)
throws away the request's tokens and re-pins it at a fresh clock
(counted per request, surfaced in telemetry), and a request that aborts
``max_request_aborts`` times is failed — that is the abort-driven
shedding the serving eval's baselines exhibit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Protocol, Sequence

from repro.serve.metrics import ServeMetrics
from repro.serve.queue import Request, RequestQueue


@dataclasses.dataclass(frozen=True)
class StepResult:
    """One slot's outcome for one prefill/decode step."""

    ok: bool                      # snapshot read succeeded
    clock: int                    # clock the parameters came from
    token: Optional[int] = None   # produced token (None: non-token executor)


class SlotExecutor(Protocol):
    n_slots: int

    def current_clock(self) -> int: ...

    def prefill(self, slot: int, req: Request, clock: int) -> StepResult: ...

    def decode(self, slots: Sequence[int], clocks: Sequence[int]
               ) -> List[StepResult]: ...


@dataclasses.dataclass
class _Slot:
    req: Request
    produced: int = 0             # tokens produced so far (incl. prefill)
    decoding: bool = False        # False until prefill succeeds


class ContinuousBatchingScheduler:
    """Keeps ``executor.n_slots`` slots full from ``queue``."""

    def __init__(self, queue: RequestQueue, executor: SlotExecutor,
                 metrics: Optional[ServeMetrics] = None, *,
                 max_request_aborts: int = 8):
        self.queue = queue
        self.executor = executor
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.max_request_aborts = max_request_aborts
        self.slots: List[Optional[_Slot]] = [None] * executor.n_slots

    # -- introspection --------------------------------------------------
    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None and s.decoding)

    @property
    def busy(self) -> bool:
        return any(s is not None for s in self.slots) \
            or self.queue.depth > 0

    # -- the loop body ---------------------------------------------------
    def step(self) -> bool:
        """One scheduler iteration: refill freed slots, one decode step.

        Returns True if any slot did work (prefill or decode) — the
        service loop uses False to idle-sleep instead of spinning.
        """
        worked = self._refill()
        m = self.metrics
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and s.decoding]
        # occupancy counts steps with work IN the system (occupied slots
        # or queued requests); pure idle polling would otherwise dominate
        # the denominator under light open-loop load
        if any(s is not None for s in self.slots) or self.queue.depth > 0:
            m.on_step(len(active), len(self.slots))
        if not active:
            return worked
        clocks = [self.slots[i].req.pinned_clock for i in active]
        results = self.executor.decode(active, clocks)
        now = time.perf_counter()
        for i, res in zip(active, results):
            slot = self.slots[i]
            if res.ok:
                self._advance(i, slot, res, now)
            else:
                self._abort(i, slot, now)
        return True

    def run_until_drained(self, timeout_s: Optional[float] = None,
                          idle_sleep_s: float = 1e-4) -> bool:
        """Graceful drain: close the queue, finish in-flight requests.

        Returns True if fully drained, False on timeout (remaining
        requests are failed so callers see a complete accounting).
        """
        self.queue.close()
        t0 = time.perf_counter()
        try:
            while self.busy:
                if timeout_s is not None \
                        and time.perf_counter() - t0 > timeout_s:
                    self._fail_remaining()
                    return False
                if not self.step():
                    time.sleep(idle_sleep_s)
        except BaseException:
            # executor crash mid-drain: leave no slot half-served — every
            # in-flight request is either re-admitted (salvageable) or
            # failed (at the abort cap) before the crash propagates
            self._crash_sweep()
            raise
        return True

    # -- internals -------------------------------------------------------
    def _refill(self) -> bool:
        """Fill free slots from the queue and prefill newcomers/re-pins."""
        worked = False
        for i, slot in enumerate(self.slots):
            if slot is None:
                req = self.queue.get()
                if req is None:
                    continue
                slot = _Slot(req)
                self.slots[i] = slot
            if slot.decoding:
                continue
            worked = True
            rc = self.executor.current_clock()
            res = self.executor.prefill(i, slot.req, rc)
            now = time.perf_counter()
            if not res.ok:
                # prefill snapshot raced a commit: retry next pass at a
                # fresher clock (counted — this is Mode Q's retry path)
                slot.req.prefill_retries += 1
                self.metrics.on_prefill_retry()
                continue
            req = slot.req
            req.pinned_clock = res.clock
            req.served_clocks.append(res.clock)
            if res.token is not None:
                req.tokens.append(res.token)
            slot.produced = 1
            slot.decoding = True
            if req.t_first_token < 0:
                req.t_first_token = now
            if slot.produced >= req.max_new:
                self._complete(i, slot, now)
        return worked

    def _advance(self, i: int, slot: _Slot, res: StepResult,
                 now: float) -> None:
        req = slot.req
        req.served_clocks.append(res.clock)
        if res.token is not None:
            req.tokens.append(res.token)
        slot.produced += 1
        if slot.produced >= req.max_new:
            self._complete(i, slot, now)

    def _abort(self, i: int, slot: _Slot, now: float) -> None:
        """Mode-Q snapshot abort: restart the request at a fresh clock."""
        req = slot.req
        req.aborts += 1
        self.metrics.on_snapshot_abort()
        if req.aborts >= self.max_request_aborts:
            self.metrics.on_failed(req, now)
            self._free(i)
            return
        # discard progress; _refill() re-prefills at a fresh clock
        req.tokens.clear()
        req.served_clocks.clear()
        req.pinned_clock = -1
        slot.produced = 0
        slot.decoding = False

    def _complete(self, i: int, slot: _Slot, now: float) -> None:
        req = slot.req
        self.metrics.on_complete(req, now,
                                 store_clock=self.executor.current_clock())
        if req.t_dequeued >= 0:
            self.queue.note_service_time(now - req.t_dequeued)
        self._free(i)

    def _free(self, i: int) -> None:
        self.slots[i] = None

    def _crash_sweep(self) -> dict:
        """Sweep the slot pool after an executor crash.

        A request caught mid-decode when the executor died holds a
        pinned clock and partial tokens that no longer mean anything —
        the snapshot it was reading may not survive recovery.  Requests
        below the abort cap are re-admitted: progress discarded, decode
        state reset, charged one abort, left in their slot so a later
        drain (same or fresh scheduler over this slot list) re-prefills
        them at a post-recovery clock.  Requests at the cap are FAILED
        so callers still see a complete accounting.  Queued (never
        admitted) requests are untouched — they carry no stale state.
        """
        now = time.perf_counter()
        swept = {"readmitted": 0, "failed": 0}
        for i, slot in enumerate(self.slots):
            if slot is None or not slot.decoding:
                continue
            req = slot.req
            req.aborts += 1
            self.metrics.on_snapshot_abort()
            if req.aborts >= self.max_request_aborts:
                self.metrics.on_failed(req, now)
                self._free(i)
                swept["failed"] += 1
                continue
            req.tokens.clear()
            req.served_clocks.clear()
            req.pinned_clock = -1
            slot.produced = 0
            slot.decoding = False
            swept["readmitted"] += 1
        return swept

    def _fail_remaining(self) -> None:
        now = time.perf_counter()
        for i, slot in enumerate(self.slots):
            if slot is not None:
                self.metrics.on_failed(slot.req, now)
                self._free(i)
        while True:
            req = self.queue.get()
            if req is None:
                break
            self.metrics.on_failed(req, now)
