"""The serving loop: queue -> continuous-batching scheduler -> metrics.

``SnapshotService`` wires the pieces from this package around a
``SlotExecutor`` and runs either an OPEN loop (requests arrive at
wall-clock times from ``OpenLoopLoadGen`` regardless of service speed —
the honest way to measure tail latency, since a closed loop hides
queueing collapse) or a CLOSED loop (``serve_requests``: offer a fixed
set, drain).  Both end with a graceful drain: the queue closes, slots
finish their in-flight requests, and the summary accounts for every
offered request (completed / shed / failed).

``StoreExecutor`` + ``SyntheticTrainer`` give the store-level scenario
the eval's ``serving`` workload measures: a trainer thread commits
parameter versions into an MVStore every few milliseconds while the
scheduler answers requests from snapshots.  Every committed version
writes CLOCK into every element of every block, so a torn read — a
resolved view mixing versions within one step — is detectable by
inspection (`violations`); serving policies:

  * ``U``     multiverse Mode-U ring: per-request pinned clock served
              from the version ring; commits never abort a reader.
  * ``Q``     Mode-Q validation: unversioned live reads validated
              against the clock; a commit since pin => ok=False, the
              request restarts at a fresh clock (abort/retry path).
  * ``live``  unversioned baseline: always reads the live value and
              never aborts — requests silently mix parameter versions
              across steps (reported, not gated).

CLI (also ``python -m repro.serve``):

    PYTHONPATH=src python -m repro.serve --mode U --duration 2 \
        --target-qps 60
"""
from __future__ import annotations

import argparse
import dataclasses
import random
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MVStoreConfig
from repro.core import mvstore
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import Admission, Request, RequestQueue
from repro.serve.scheduler import (ContinuousBatchingScheduler, StepResult)

SERVE_POLICIES = ("U", "Q", "live")


@dataclasses.dataclass
class ServiceConfig:
    """Knobs for the synthetic store-serving scenario (CLI/eval both)."""

    mode: str = "U"                   # serving policy: U | Q | live
    n_slots: int = 4
    max_new: int = 12                 # tokens per request (incl. prefill)
    queue_depth: int = 64
    wait_budget_s: Optional[float] = 0.5
    # wait-budget autotune: admission plans for max(EMA, p99) of
    # observed service times, tightening the budget under a slow tail
    # (see RequestQueue); False pins the PR-6 fixed-budget behavior
    autotune_wait_budget: bool = True
    max_request_aborts: int = 8
    target_qps: float = 60.0
    duration_s: float = 2.0
    arrival: str = "poisson"          # or "uniform"
    # trainer cadence relative to the ~max_new*(work_s+overhead) request
    # span picks the Mode-Q failure mode.  Mode-U requests ride the ring
    # through commits untouched either way.  Just ABOVE the span
    # (default): a Mode-Q request aborts once mid-flight, restarts
    # phase-aligned with the commit and completes — a latency tax.
    # BELOW the span: even phase-aligned restarts meet the next commit,
    # so Mode-Q requests abort until max_request_aborts sheds them — the
    # paper's reader-starvation regime (the serving eval's headline)
    commit_interval_s: float = 0.028
    ring_slots: int = 8
    n_blocks: int = 4
    block_size: int = 64
    work_s: float = 0.0015            # simulated decode compute per step
    seed: int = 0
    drain_timeout_s: float = 10.0


# ---------------------------------------------------------------------------
# the committing trainer (the writer side of the scenario)
# ---------------------------------------------------------------------------


class SyntheticTrainer:
    """Background thread committing versions into a small MVStore.

    Every commit writes the NEW clock value into every element of every
    block, so any consistent view satisfies "all elements equal one
    clock" — the invariant ``StoreExecutor`` checks per resolved step.
    ``state`` is an immutable ``MVStoreState`` swapped atomically, the
    same publication discipline the real trainer uses.
    """

    def __init__(self, mode: str = "U", n_blocks: int = 4,
                 block_size: int = 64, ring_slots: int = 8,
                 commit_interval_s: float = 0.02):
        store_mode = "U" if mode == "U" else "Q"
        self.cfg = MVStoreConfig(ring_slots=ring_slots, mode=store_mode)
        self.local_mode = store_mode
        versioned = "all" if store_mode == "U" else "none"
        params = {f"b{i}": jnp.zeros((block_size,), jnp.int32)
                  for i in range(n_blocks)}
        self.state = mvstore.mv_init(params, self.cfg, versioned=versioned)
        self.commit_interval_s = commit_interval_s
        self.commits = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def commit_once(self) -> None:
        state = self.state
        c = int(state.clock) + 1
        new_params = {k: jnp.full(v.shape, c, jnp.int32)
                      for k, v in state.live.items()}
        self.state = mvstore.mv_commit(state, new_params,
                                       local_mode=self.local_mode,
                                       cfg=self.cfg)
        self.commits += 1

    def _run(self) -> None:
        while not self._stop.wait(self.commit_interval_s):
            self.commit_once()

    def start(self) -> "SyntheticTrainer":
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None


# ---------------------------------------------------------------------------
# the store-level slot executor (the reader side)
# ---------------------------------------------------------------------------


@jax.jit
def _resolve_versioned(state, rc):
    return mvstore.mv_snapshot(state, rc, assume_versioned=True)


@jax.jit
def _resolve_validated(state, rc):
    return mvstore.mv_snapshot(state, rc, assume_versioned=False)


class StoreExecutor:
    """SlotExecutor answering requests from MVStore parameter snapshots.

    Stateless per slot (the synthetic "model" is the resolve itself plus
    ``work_s`` of simulated decode compute per step), so all the slot
    bookkeeping lives in the scheduler where it is testable.  Resolves
    once per DISTINCT pinned clock per step — the batched-decode shape —
    and checks the all-elements-equal-one-clock invariant on every
    successful resolve, counting breaks into ``metrics.violations``.
    """

    def __init__(self, state_fn, policy: str = "U", n_slots: int = 4,
                 work_s: float = 0.0015, check: bool = True,
                 metrics: Optional[ServeMetrics] = None):
        if policy not in SERVE_POLICIES:
            raise ValueError(f"policy must be one of {SERVE_POLICIES}")
        self.state_fn = state_fn
        self.policy = policy
        self.n_slots = n_slots
        self.work_s = work_s
        self.check = check
        self.metrics = metrics

    def current_clock(self) -> int:
        return int(self.state_fn().clock)

    def warmup(self) -> None:
        """Compile the resolve outside the measured window."""
        state = self.state_fn()
        self._resolve(state, int(state.clock))

    # -- resolution ------------------------------------------------------
    def _resolve(self, state, rc: int) -> Tuple[Any, bool, int]:
        """-> (view, ok, clock the view actually came from)."""
        if self.policy == "live":
            return state.live, True, int(state.clock)
        fn = (_resolve_versioned if self.policy == "U"
              else _resolve_validated)
        view, ok = fn(state, rc)
        return view, bool(ok), rc

    def _verify(self, view) -> None:
        leaves = [np.asarray(l) for l in jax.tree.leaves(view)]
        vals = {int(l.flat[0]) for l in leaves}
        torn = len(vals) != 1 or any((l != l.flat[0]).any() for l in leaves)
        if torn and self.metrics is not None:
            self.metrics.on_violation()

    # -- SlotExecutor ----------------------------------------------------
    def prefill(self, slot: int, req: Request, clock: int) -> StepResult:
        _, ok, served = self._resolve(self.state_fn(), clock)
        if not ok:
            return StepResult(False, clock)
        return StepResult(True, served)

    def decode(self, slots: Sequence[int], clocks: Sequence[int]
               ) -> List[StepResult]:
        state = self.state_fn()
        if self.work_s:
            time.sleep(self.work_s)       # simulated batched decode step
        resolved: Dict[int, Tuple[Any, bool, int]] = {}
        for rc in set(clocks):
            view, ok, served = self._resolve(state, rc)
            if ok and self.check:
                self._verify(view)
            resolved[rc] = (view, ok, served)
        return [StepResult(resolved[rc][1], resolved[rc][2])
                for rc in clocks]


# ---------------------------------------------------------------------------
# open-loop load generation
# ---------------------------------------------------------------------------


class OpenLoopLoadGen:
    """Precomputed arrival schedule at ``target_qps`` for ``duration_s``.

    Open loop: arrivals fire at their scheduled offsets whether or not
    the service keeps up — back-pressure shows up as queue depth and
    shedding, not as a quietly slowed generator.
    """

    def __init__(self, target_qps: float, duration_s: float,
                 seed: int = 0, arrival: str = "poisson"):
        rng = random.Random(seed)
        self.arrivals: List[float] = []
        t = 0.0
        mean_gap = 1.0 / max(target_qps, 1e-9)
        while True:
            t += (rng.expovariate(target_qps) if arrival == "poisson"
                  else mean_gap)
            if t >= duration_s:
                break
            self.arrivals.append(t)
        self._next = 0

    def pop_due(self, t_rel: float) -> int:
        """Number of arrivals whose scheduled time has passed."""
        n = 0
        while (self._next < len(self.arrivals)
               and self.arrivals[self._next] <= t_rel):
            self._next += 1
            n += 1
        return n

    @property
    def exhausted(self) -> bool:
        return self._next >= len(self.arrivals)

    @property
    def total(self) -> int:
        return len(self.arrivals)


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------


class SnapshotService:
    """Queue -> scheduler -> metrics, with graceful drain.

    Owns nothing it was handed (an external executor/queue/metrics is
    used as-is); ``synthetic()`` builds the self-contained store-level
    scenario with an owned ``SyntheticTrainer`` that ``run_open_loop``
    starts and stops around the measured window.
    """

    def __init__(self, executor, cfg: Optional[ServiceConfig] = None, *,
                 queue: Optional[RequestQueue] = None,
                 metrics: Optional[ServeMetrics] = None,
                 trainer: Optional[SyntheticTrainer] = None):
        self.cfg = cfg or ServiceConfig()
        self.metrics = metrics if metrics is not None \
            else ServeMetrics(seed=self.cfg.seed)
        self.queue = queue if queue is not None else RequestQueue(
            max_depth=self.cfg.queue_depth,
            wait_budget_s=self.cfg.wait_budget_s,
            n_servers=self.cfg.n_slots,
            est_service_s=self.cfg.max_new * max(self.cfg.work_s, 1e-4),
            autotune=self.cfg.autotune_wait_budget)
        self.executor = executor
        if getattr(executor, "metrics", None) is None \
                and hasattr(executor, "metrics"):
            executor.metrics = self.metrics
        self.scheduler = ContinuousBatchingScheduler(
            self.queue, executor, self.metrics,
            max_request_aborts=self.cfg.max_request_aborts)
        self.trainer = trainer
        self._rid = 0

    @classmethod
    def synthetic(cls, cfg: Optional[ServiceConfig] = None
                  ) -> "SnapshotService":
        cfg = cfg or ServiceConfig()
        trainer = SyntheticTrainer(
            mode=cfg.mode, n_blocks=cfg.n_blocks,
            block_size=cfg.block_size, ring_slots=cfg.ring_slots,
            commit_interval_s=cfg.commit_interval_s)
        metrics = ServeMetrics(seed=cfg.seed)
        executor = StoreExecutor(lambda: trainer.state, policy=cfg.mode,
                                 n_slots=cfg.n_slots, work_s=cfg.work_s,
                                 metrics=metrics)
        return cls(executor, cfg, metrics=metrics, trainer=trainer)

    # -- submission ------------------------------------------------------
    def submit(self, payload: Any = None, max_new: Optional[int] = None,
               now: Optional[float] = None) -> Tuple[Request, Admission]:
        self._rid += 1
        req = Request(rid=self._rid, payload=payload,
                      max_new=max_new or self.cfg.max_new)
        return req, self.queue.offer(req, now=now)

    # -- loops -----------------------------------------------------------
    def run_open_loop(self, load_gen: Optional[OpenLoopLoadGen] = None
                      ) -> Dict:
        cfg = self.cfg
        gen = load_gen or OpenLoopLoadGen(cfg.target_qps, cfg.duration_s,
                                          seed=cfg.seed,
                                          arrival=cfg.arrival)
        if hasattr(self.executor, "warmup"):
            self.executor.warmup()
        own_trainer = self.trainer is not None
        if own_trainer:
            self.trainer.start()
        t0 = time.perf_counter()
        try:
            while True:
                t_rel = time.perf_counter() - t0
                for _ in range(gen.pop_due(t_rel)):
                    self.submit()
                if gen.exhausted and t_rel >= cfg.duration_s:
                    break
                if not self.scheduler.step():
                    time.sleep(5e-5)
            drained = self.scheduler.run_until_drained(
                cfg.drain_timeout_s)
            measured = time.perf_counter() - t0
        finally:
            if own_trainer:
                self.trainer.stop()
        return self.summary(measured, drained=drained, offered=gen.total)

    def serve_requests(self, payloads: Sequence[Any]) -> Dict:
        """Closed loop: offer everything up front, drain, summarize."""
        if hasattr(self.executor, "warmup"):
            self.executor.warmup()
        own_trainer = self.trainer is not None
        if own_trainer:
            self.trainer.start()
        t0 = time.perf_counter()
        try:
            for p in payloads:
                self.submit(payload=p)
            drained = self.scheduler.run_until_drained(
                self.cfg.drain_timeout_s)
            measured = time.perf_counter() - t0
        finally:
            if own_trainer:
                self.trainer.stop()
        return self.summary(measured, drained=drained,
                            offered=len(payloads))

    # -- reporting -------------------------------------------------------
    def summary(self, measured_s: float, drained: bool = True,
                offered: Optional[int] = None) -> Dict:
        cfg = self.cfg
        row = self.metrics.summary(measured_s,
                                   backend=f"serve-{cfg.mode}",
                                   mode=cfg.mode if cfg.mode in ("Q", "U")
                                   else "-")
        row.update({
            "policy": cfg.mode,
            "target_qps": cfg.target_qps,
            "duration_s": measured_s,
            "n_slots": cfg.n_slots,
            "max_new": cfg.max_new,
            "drained": drained,
            "offered": offered if offered is not None
            else self.queue.counters["offered"],
            "trainer_commits": self.trainer.commits
            if self.trainer is not None else 0,
        })
        row.update({f"q_{k}": v for k, v in self.queue.counters.items()})
        row["shed"] = (self.queue.counters["shed_depth"]
                       + self.queue.counters["shed_wait"])
        return row


def format_summary(row: Dict) -> str:
    return (f"policy={row['policy']:<4s} qps={row['qps']:6.1f}"
            f"/{row['target_qps']:.0f} completed={row['completed']:4d} "
            f"shed={row['shed']:3d} failed={row['failed_aborts']:3d} "
            f"aborts={row['snapshot_aborts']:4d} "
            f"p50={row['p50_ms']:6.1f}ms p99={row['p99_ms']:6.1f}ms "
            f"occ={row['occupancy']:.2f} "
            f"commits={row['trainer_commits']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="snapshot-serving loop under a committing trainer")
    ap.add_argument("--mode", default="U", choices=SERVE_POLICIES)
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--target-qps", type=float, default=60.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--commit-interval-ms", type=float, default=28.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="short CI-sized run")
    args = ap.parse_args(argv)

    cfg = ServiceConfig(
        mode=args.mode, n_slots=args.slots, max_new=args.max_new,
        target_qps=args.target_qps,
        duration_s=0.8 if args.quick else args.duration,
        commit_interval_s=args.commit_interval_ms / 1e3, seed=args.seed)
    svc = SnapshotService.synthetic(cfg)
    row = svc.run_open_loop()
    print(format_summary(row), flush=True)
    if row["violations"]:
        print(f"TORN READS: {row['violations']}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
