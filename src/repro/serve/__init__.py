"""Snapshot-serving subsystem: continuous batching over MVStore snapshots.

The production shape of the paper's long-running-read claim: a request
queue with admission control (`queue.py`), a continuous-batching
scheduler that keeps a fixed slot pool full and resolves every decode
step at a per-request snapshot clock through ``mv_snapshot``
(`scheduler.py`), streaming tail-latency telemetry (`metrics.py`), and
the service loop + open-loop load generator tying them together
(`service.py`).

    from repro.serve import SnapshotService, ServiceConfig
    svc = SnapshotService.synthetic(ServiceConfig(mode="U"))
    summary = svc.run_open_loop()

``python -m repro.serve --duration 2 --target-qps 50`` runs the same
loop from the CLI; the ``serving`` workload in ``repro.eval`` drives it
across the multiverse / Mode-Q / unversioned serving policies.
"""
from repro.serve.metrics import PercentileReservoir, ServeMetrics
from repro.serve.queue import Admission, Outcome, Request, RequestQueue
from repro.serve.scheduler import (ContinuousBatchingScheduler, SlotExecutor,
                                   StepResult)
from repro.serve.service import (OpenLoopLoadGen, ServiceConfig,
                                 SnapshotService, StoreExecutor,
                                 SyntheticTrainer)

__all__ = [
    "Admission", "Outcome", "Request", "RequestQueue",
    "PercentileReservoir", "ServeMetrics",
    "ContinuousBatchingScheduler", "SlotExecutor", "StepResult",
    "OpenLoopLoadGen", "ServiceConfig", "SnapshotService",
    "StoreExecutor", "SyntheticTrainer",
]
