"""Serving telemetry: streaming percentiles, QPS, occupancy, clock lag.

Everything here is O(1)-ish per event so it can sit inside the decode
loop: percentile distributions go through a fixed-capacity reservoir
(Vitter's Algorithm R — uniform sample of an unbounded stream), QPS
comes from a sliding window of completion timestamps, and slot
occupancy is two counters bumped once per scheduler step.

``ServeMetrics.summary()`` emits one FLAT row in the same shape the
eval subsystem's workloads produce, so ``eval/results.save_results``
can write serving rows next to longread/rwmix rows unchanged.
"""
from __future__ import annotations

import random
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.stats_schema import normalize_stats
from repro.serve.queue import Outcome, Request


class PercentileReservoir:
    """Streaming percentile estimator (Algorithm-R reservoir sample).

    Keeps a uniform sample of ``capacity`` observations; quantiles are
    exact while ``count <= capacity`` (np.percentile over everything)
    and an unbiased estimate past it.  Deterministic under a fixed seed
    — replacement uses its own ``random.Random``, not the global RNG.
    """

    def __init__(self, capacity: int = 4096, seed: int = 0):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._rng = random.Random(seed)
        self._buf: List[float] = []
        self.count = 0

    def add(self, x: float) -> None:
        self.count += 1
        if len(self._buf) < self.capacity:
            self._buf.append(float(x))
            return
        j = self._rng.randrange(self.count)
        if j < self.capacity:
            self._buf[j] = float(x)

    def percentile(self, q: float) -> float:
        """q in [0, 100]; NaN when no samples have been observed."""
        if not self._buf:
            return float("nan")
        return float(np.percentile(np.asarray(self._buf), q))

    def percentiles(self, qs: Sequence[float] = (50, 95, 99)
                    ) -> Dict[str, float]:
        return {f"p{g:g}": self.percentile(g) for g in qs}

    @property
    def mean(self) -> float:
        return float(np.mean(self._buf)) if self._buf else float("nan")


class ServeMetrics:
    """Per-request and per-step telemetry for the serving loop.

    The scheduler calls ``on_step`` once per iteration (occupancy),
    ``on_snapshot_abort`` per failed decode/prefill snapshot read, and
    ``on_complete``/``on_failed`` at end of a request's life.  Torn
    reads (a resolved view mixing parameter versions WITHIN one step —
    the invariant the executor checks) land in ``violations``; the
    unversioned baseline's cross-step version mixing is the separate,
    non-gating ``mixed_version_requests``.
    """

    def __init__(self, reservoir_capacity: int = 4096, seed: int = 0,
                 qps_window_s: float = 2.0):
        mk = lambda i: PercentileReservoir(reservoir_capacity, seed + i)
        self.latency = mk(1)          # request total latency (s)
        self.ttft = mk(2)             # time to first token (s)
        self.queue_wait = mk(3)       # arrival -> dequeued (s)
        self.clock_lag = mk(4)        # store clock - pinned clock at done
        self.completed = 0
        self.failed_aborts = 0        # requests dropped after max aborts
        self.snapshot_aborts = 0      # per-step ok=False events (Mode Q)
        self.prefill_retries = 0
        self.mixed_version_requests = 0
        self.violations = 0           # torn reads — gates the eval CLI
        self.tokens_out = 0
        self.steps = 0
        self.active_slot_steps = 0
        self.total_slot_steps = 0
        self.qps_window_s = qps_window_s
        self._done_ts: deque = deque()
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    # -- scheduler hooks ------------------------------------------------
    def on_step(self, active_slots: int, total_slots: int) -> None:
        self.steps += 1
        self.active_slot_steps += active_slots
        self.total_slot_steps += total_slots

    def on_snapshot_abort(self, n: int = 1) -> None:
        self.snapshot_aborts += n

    def on_prefill_retry(self, n: int = 1) -> None:
        self.prefill_retries += n

    def on_violation(self, n: int = 1) -> None:
        self.violations += n

    def on_complete(self, req: Request, now: Optional[float] = None,
                    store_clock: Optional[int] = None) -> None:
        now = time.perf_counter() if now is None else now
        req.t_done = now
        req.outcome = Outcome.COMPLETED
        self.completed += 1
        self.tokens_out += len(req.tokens) if req.tokens else req.max_new
        self.latency.add(req.latency_s)
        self.ttft.add(req.ttft_s)
        self.queue_wait.add(req.queue_wait_s)
        if store_clock is not None and req.pinned_clock >= 0:
            self.clock_lag.add(store_clock - req.pinned_clock)
        if req.mixed_versions:
            self.mixed_version_requests += 1
        self._t_first = now if self._t_first is None else self._t_first
        self._t_last = now
        self._done_ts.append(now)
        cutoff = now - self.qps_window_s
        while self._done_ts and self._done_ts[0] < cutoff:
            self._done_ts.popleft()

    def on_failed(self, req: Request, now: Optional[float] = None) -> None:
        req.t_done = time.perf_counter() if now is None else now
        req.outcome = Outcome.FAILED_ABORTS
        self.failed_aborts += 1

    # -- derived --------------------------------------------------------
    @property
    def occupancy(self) -> float:
        if self.total_slot_steps == 0:
            return 0.0
        return self.active_slot_steps / self.total_slot_steps

    def rolling_qps(self, now: Optional[float] = None) -> float:
        """Completions per second over the trailing window."""
        if not self._done_ts:
            return 0.0
        now = time.perf_counter() if now is None else now
        window = min(self.qps_window_s,
                     max(now - self._done_ts[0], 1e-9))
        n = sum(1 for t in self._done_ts if t >= now - self.qps_window_s)
        return n / window

    def achieved_qps(self, measured_s: Optional[float] = None) -> float:
        if measured_s and measured_s > 0:
            return self.completed / measured_s
        if self._t_first is None or self._t_last is None \
                or self._t_last <= self._t_first:
            return 0.0
        return self.completed / (self._t_last - self._t_first)

    # -- the results-schema row ----------------------------------------
    def summary(self, measured_s: Optional[float] = None,
                backend: str = "", mode: str = "-") -> Dict:
        """Flat row (eval/results.py-compatible): latency in ms."""
        ms = 1e3
        row = {
            "completed": self.completed,
            "failed_aborts": self.failed_aborts,
            "snapshot_aborts": self.snapshot_aborts,
            "prefill_retries": self.prefill_retries,
            "mixed_version_requests": self.mixed_version_requests,
            "violations": self.violations,
            "tokens_out": self.tokens_out,
            "qps": self.achieved_qps(measured_s),
            "p50_ms": self.latency.percentile(50) * ms,
            "p95_ms": self.latency.percentile(95) * ms,
            "p99_ms": self.latency.percentile(99) * ms,
            "ttft_p50_ms": self.ttft.percentile(50) * ms,
            "ttft_p99_ms": self.ttft.percentile(99) * ms,
            "queue_wait_p50_ms": self.queue_wait.percentile(50) * ms,
            "queue_wait_p99_ms": self.queue_wait.percentile(99) * ms,
            "clock_lag_p50": self.clock_lag.percentile(50),
            "clock_lag_p99": self.clock_lag.percentile(99),
            "occupancy": self.occupancy,
            "scheduler_steps": self.steps,
        }
        # normalized TM-stats projection: a serving row is a reader-side
        # transaction stream — completions commit, snapshot aborts abort
        row["stm_stats"] = normalize_stats(
            {"commits": self.completed,
             "aborts": self.snapshot_aborts + self.prefill_retries,
             "ro_commits": self.completed},
            backend=backend, mode=mode)
        return row
