import sys

from repro.serve.service import main

sys.exit(main())
