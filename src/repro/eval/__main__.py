"""CLI for the eval subsystem: ``python -m repro.eval``.

    python -m repro.eval --workload longread             # all six backends
    python -m repro.eval --workload longread --quick     # CI smoke
    python -m repro.eval --workload structrq --backends multiverse tl2
    python -m repro.eval --list                          # what exists

Writes ``results/eval_<workload>.json`` (see BENCHMARKS.md for the row
schemas) and prints one table line per trial.  Exit status is non-zero
if any completed long read observed an inconsistent snapshot — the CLI
doubles as a correctness gate, not just a stopwatch.
"""
from __future__ import annotations

import argparse
import sys

from repro.eval.driver import durability_headline, longread_headline, \
    reliability_headline, run_eval, rwmix_headline, serving_headline, \
    shardscale_headline, structrq_headline
from repro.eval.workloads import WORKLOADS


def _fmt_row(row: dict) -> str:
    extra = ""
    if "scans_per_sec" in row:
        extra = (f"scans/s={row['scans_per_sec']:8.1f} "
                 f"failed={row['failed_scans']:4d} "
                 f"updates/s={row['updates_per_sec']:8.0f}")
    elif "rqs_per_sec" in row:
        extra = (f"rqs/s={row['rqs_per_sec']:7.1f} "
                 f"failed={row['failed_ops']:4d} "
                 f"rq-vs-scan={row.get('rq_vs_scan', 0.0):5.2f}x")
    elif "kills" in row:
        extra = (f"updates/s={row['updates_per_sec']:8.1f} "
                 f"kills={row['kills']:3d} "
                 f"recovered={row['recoveries']:3d} "
                 f"fwd={row['rolled_forward']:3d} "
                 f"back={row['rolled_back']:3d} "
                 f"violations={row['violations']:3d}")
    elif "n_shards" in row:
        parity = row.get("parity_ok")
        extra = (f"shards={row['n_shards']:2d} "
                 f"updates/s={row['updates_per_sec']:8.1f} "
                 f"failed={row['failed_updates']:4d} "
                 f"checks/s={row['checks_per_sec']:7.1f} "
                 f"violations={row['violations']:3d}"
                 + (f" parity={'ok' if parity else 'FAIL'}"
                    if parity is not None else ""))
    elif "write_words" in row:
        extra = (f"updates/s={row['updates_per_sec']:8.1f} "
                 f"failed={row['failed_updates']:4d} "
                 f"checks/s={row['checks_per_sec']:7.1f} "
                 f"violations={row['violations']:3d}")
    elif "p99_ms" in row:
        extra = (f"qps={row['qps']:6.1f}/{row['target_qps']:<4.0f}"
                 f"p50={row['p50_ms']:6.1f}ms p99={row['p99_ms']:7.1f}ms "
                 f"shed={row['shed']:3d} failed={row['failed_aborts']:3d} "
                 f"aborts={row['snapshot_aborts']:4d}")
    elif "ops_per_sec" in row:
        extra = (f"ops/s={row['ops_per_sec']:8.0f} "
                 f"failed={row['failed_ops']:4d}")
    mode = row["stm_stats"].get("mode", "-")
    return (f"{row['workload']}/{row['variant']:<9s} "
            f"{row['backend']:<10s} {extra} mode={mode}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.eval",
        description="paper-figure evaluation: workloads x backends")
    ap.add_argument("--workload", default="longread",
                    choices=sorted(WORKLOADS))
    ap.add_argument("--backends", nargs="*", default=None,
                    help="registered backend names "
                         "(default: the workload's full set)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", nargs="*", type=int, default=None,
                    help="shardscale only: shard counts to sweep "
                         "(default: 1 2 4, or 1 2 with --quick)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer variants, short windows")
    ap.add_argument("--durable", action="store_true",
                    help="reliability only: journal every commit to an "
                         "fsync'd WAL during the kill/recover trials")
    ap.add_argument("--out", default=None,
                    help="results directory (default: results/)")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="list workloads and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, w in sorted(WORKLOADS.items()):
            variants = ", ".join(s.variant for s in w.variants())
            print(f"{name:<10s} metric={w.metric:<14s} "
                  f"variants: {variants}")
        return 0

    if args.shards:
        WORKLOADS["shardscale"].shards = tuple(args.shards)
    if args.durable:
        WORKLOADS["reliability"].durable = True
    rows, path = run_eval(
        args.workload, backends=args.backends, seed=args.seed,
        quick=args.quick, out_dir=args.out, save=not args.no_save,
        progress=lambda r: print(_fmt_row(r), flush=True))

    violations = sum(r.get("violations", 0) for r in rows)
    if args.workload == "longread":
        h = longread_headline(rows)
        if h:
            verdict = "WINS" if h["multiverse_wins"] else "does NOT win"
            base = ", ".join(f"{b}={v:.1f}" for b, v in
                             h["baseline_scans_per_sec"].items())
            print(f"\nheadline @ scan{h['scan_size']}: multiverse="
                  f"{h['multiverse_scans_per_sec']:.1f} scans/s {verdict} "
                  f"vs [{base}]")
    if args.workload == "rwmix":
        h = rwmix_headline(rows)
        if h:
            verdict = ("within 2x of the best unversioned baseline"
                       if h["within_2x"] else
                       "NOT within 2x of the best unversioned baseline")
            base = ", ".join(f"{b}={v:.1f}" for b, v in
                             h["baseline_updates_per_sec"].items())
            print(f"\nheadline @ w{h['write_words']}: multiverse="
                  f"{h['multiverse_updates_per_sec']:.1f} updates/s "
                  f"({h['ratio_vs_best']:.2f}x of best) — {verdict} "
                  f"[{base}] violations={h['violations']}")
    if args.workload == "shardscale":
        h = shardscale_headline(rows)
        if h:
            verdict = (">=1.6x at 2 shards" if h["scales_1_6x"]
                       else "does NOT reach 1.6x at 2 shards")
            ups = ", ".join(f"s{n}={v:.1f}" for n, v in
                            h["updates_per_sec"].items())
            parity = "ok" if h["parity_ok"] else "FAIL"
            print(f"\nheadline: shardstore [{ups}] updates/s -> "
                  f"{h['ratio_2_shards']:.2f}x ({verdict}) "
                  f"parity@1shard={parity} "
                  f"violations={h['violations']}")
    if args.workload == "serving":
        h = serving_headline(rows)
        if h:
            verdict = ("SUSTAINS target QPS" if h["multiverse_sustains"]
                       else "does NOT sustain target QPS")
            print(f"\nheadline @ qps{h['target_qps']:.0f}: multiverse="
                  f"{h['multiverse_qps']:.1f} qps "
                  f"p99={h['multiverse_p99_ms']:.1f}ms {verdict} "
                  f"(violations={h['violations']})")
            for b, d in sorted(h["baselines"].items()):
                tag = "DEGRADED" if d["degraded"] else "not degraded"
                print(f"  vs {b:<12s} p99={d['p99_ms']:8.1f}ms "
                      f"({d['p99_ratio']:.2f}x) shed={d['shed']} "
                      f"failed={d['failed_aborts']} "
                      f"aborts={d['snapshot_aborts']} "
                      f"mixed-versions={d['mixed_version_requests']} "
                      f"-> {tag}")
    if args.workload == "reliability":
        h = reliability_headline(rows)
        for backend, d in sorted(h.items()):
            verdict = ("recovers within 2x of fault-free" if d["holds"]
                       else "does NOT hold")
            print(f"\nheadline @ kill{d['kill_every']}: {backend} "
                  f"faulted={d['faulted_updates_per_sec']:.1f} vs "
                  f"nofault={d['nofault_updates_per_sec']:.1f} updates/s "
                  f"({d['ratio_vs_nofault']:.2f}x) kills={d['kills']} "
                  f"recovered={d['recoveries']} "
                  f"(fwd={d['rolled_forward']} back={d['rolled_back']}) "
                  f"violations={d['violations']} -> {verdict}")
    if args.workload == "durability":
        h = durability_headline(rows)
        for backend, d in sorted(h.items()):
            verdict = (">=0.5x of in-memory with a clean restart drill"
                       if d["holds"] else "does NOT hold")
            solo = (f" solo={d['solo_ratio_vs_inmem']:.2f}x"
                    if d.get("solo_ratio_vs_inmem") is not None else "")
            print(f"\nheadline [{d['gated_on']}]: {backend} durable="
                  f"{d['durable_updates_per_sec']:.1f} vs inmem="
                  f"{d['inmem_updates_per_sec']:.1f} updates/s "
                  f"({d['ratio_vs_inmem']:.2f}x{solo}) "
                  f"fsyncs={d['fsyncs']} groups={d['commit_groups']} "
                  f"replayed={d['wal_records_replayed']} "
                  f"violations={d['violations']} -> {verdict}")
    if args.workload == "structrq":
        h = structrq_headline(rows)
        for struct, d in sorted(h.items()):
            verdict = ("within 5x of the array scan" if d["within_5x"]
                       else "NOT within 5x of the array scan")
            print(f"\nheadline @ {struct}: multiverse rq="
                  f"{d['rq_solo_per_sec']:.1f}/s vs flat scan of "
                  f"{d['rq_words']} words={d['arrayscan_per_sec']:.1f}/s "
                  f"-> {d['rq_vs_scan']:.2f}x ({verdict})")
    if path:
        print(f"results -> {path}")
    if violations:
        print(f"CONSISTENCY VIOLATIONS: {violations}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
